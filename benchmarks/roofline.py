import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Roofline analysis (EXPERIMENTS.md §Roofline).

Terms per (arch x shape) on the single-pod mesh, per the assignment:

    compute_s    = HLO_FLOPs / (chips x 197e12)         [bf16 peak / chip]
    memory_s     = HLO_bytes / (chips x 819e9)          [HBM bw / chip]
    collective_s = collective_wire_bytes / (chips x 50e9) [ICI / link]

cost_analysis numbers come from the SPMD-partitioned per-device module, so
"/(chips x ...)" is satisfied by using the per-device values directly.

Scan-body correction: XLA's cost model counts a while-loop body ONCE, so a
60-layer scanned stack reports ~1/60 of the real FLOPs.  We therefore lower
each cell at n_groups=1 and n_groups=2, fit the exact linear model
``term(n) = base + slope * n`` (inner chunk loops are statically unrolled,
so they are fully costed), and extrapolate to the full depth.  The full-
depth compile from the dry-run provides memory_analysis + the collective-op
inventory; its (undercounted) raw numbers are retained in the artifact for
comparison.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--cells arch:shape ...]
"""

import argparse
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

ART_DIR = os.path.join("benchmarks", "artifacts", "dryrun")
OUT_PATH = os.path.join("benchmarks", "artifacts", "roofline.json")


def _cfg_with_depth(cfg, n: int):
    """Depth-n variant for differential costing.  The layer scan is unrolled
    (scan_layers=False) and inner chunk loops disabled (attn_chunk_q=0, full
    logits) so every FLOP sits outside any scan body and is fully counted —
    XLA's cost model counts a while-loop body once regardless of trip count.
    The math (and therefore flops/bytes) is identical to the production
    scan+chunk path."""
    kw = {
        "n_groups": n,
        "attn_chunk_q": 0,
        "chunked_loss_chunks": 0,
        "scan_layers": False,
    }
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=n)
    return dataclasses.replace(cfg, **kw)


def measure_cell(arch: str, shape: str) -> Optional[Dict]:
    import jax

    from repro.configs import cell_applicable, get_config, get_shape_cell
    from repro.core.jax_events import compiled_metrics
    from repro.dist import serve as dserve
    from repro.dist import train as dtrain
    from repro.launch.mesh import make_production_mesh
    from repro.models import lm_init
    from repro.optim import adamw

    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skip", "reason": reason}

    mesh = make_production_mesh()

    def metrics_at_depth(n: int) -> Dict[str, float]:
        cfg_n = _cfg_with_depth(cfg, n)
        with mesh:
            if cell.kind == "train":
                compile_for = dtrain.jit_train_step(cfg_n, mesh)
                bs = dtrain.batch_shapes(cfg_n, cell.global_batch, cell.seq_len)
                jitted, (ps, os_, _) = compile_for(bs)
                compiled = jitted.lower(ps, os_, bs).compile()
            elif cell.kind == "prefill":
                jitted, (ps, bs) = dserve.jit_prefill_step(cfg_n, mesh, cell.global_batch, cell.seq_len)
                compiled = jitted.lower(ps, bs).compile()
            else:
                jitted, (ps, cs, ts) = dserve.jit_serve_step(cfg_n, mesh, cell.global_batch, cell.seq_len)
                compiled = jitted.lower(ps, cs, ts).compile()
        return compiled_metrics(compiled)

    m1 = metrics_at_depth(1)
    m2 = metrics_at_depth(2)
    full_n = cfg.n_groups

    def extrapolate(key: str) -> float:
        slope = m2[key] - m1[key]
        base = m1[key] - slope
        return max(base + slope * full_n, 0.0)

    flops = extrapolate("hlo_flops")
    bytes_ = extrapolate("hlo_bytes")
    wire = extrapolate("collective_wire_bytes")

    # model flops: 6ND train / 2ND inference, N_active for MoE
    params = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
    total_params = sum(int(np_.size) for np_ in jax.tree.leaves(params))
    expert_params = 0
    if cfg.moe is not None:
        def count_experts(path, leaf):
            names = [getattr(k, "key", None) for k in path]
            return int(leaf.size) if "experts" in names else 0

        import jax.tree_util as jtu

        expert_params = sum(
            count_experts(p, l) for p, l in jtu.tree_leaves_with_path(params)
        )
        active = total_params - expert_params * (1 - cfg.moe.top_k / cfg.moe.n_experts)
    else:
        active = total_params

    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    model_flops = (6.0 if cell.kind == "train" else 2.0) * active * tokens
    chips = 256
    model_flops_per_chip = model_flops / chips

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    collective_s = wire / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    bound_s = max(compute_s, memory_s, collective_s)
    return {
        "arch": arch,
        "shape": shape,
        "status": "ok",
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_,
        "collective_wire_bytes_per_chip": wire,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_lower_bound_s": bound_s,
        "params_total": total_params,
        "params_active": active,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": model_flops_per_chip / flops if flops else 0.0,
        "roofline_fraction": (model_flops_per_chip / PEAK_FLOPS) / bound_s if bound_s else 0.0,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--cells", nargs="*", default=None, help="arch:shape pairs; default all")
    p.add_argument("--out", default=OUT_PATH)
    ns = p.parse_args(argv)

    from repro.configs import all_cells

    if ns.cells:
        cells = [tuple(c.split(":", 1)) for c in ns.cells]
    else:
        cells = all_cells()

    results: List[Dict] = []
    existing = {}
    if os.path.exists(ns.out):
        with open(ns.out) as fh:
            existing = {(r["arch"], r["shape"]): r for r in json.load(fh)}
    for arch, shape in cells:
        try:
            rec = measure_cell(arch, shape)
        except Exception as exc:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "status": "fail", "error": str(exc)[-500:]}
        existing[(arch, shape)] = rec
        if rec["status"] == "ok":
            print(
                f"{arch:20s} {shape:12s} compute={rec['compute_s']:.3f}s "
                f"memory={rec['memory_s']:.3f}s collective={rec['collective_s']:.3f}s "
                f"dom={rec['dominant']:10s} roofline_frac={rec['roofline_fraction']:.3f}"
            )
        else:
            print(f"{arch:20s} {shape:12s} {rec['status']}: {rec.get('reason', rec.get('error',''))}")
    results = list(existing.values())
    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump(results, fh, indent=1)
    print(f"wrote {ns.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
