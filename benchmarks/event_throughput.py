"""Event-path microbenchmarks (beyond paper; drives §Perf iterations).

Measures, in-process (startup excluded):
  * per-event cost of the two buffer strategies (list vs preallocated numpy)
    — the "C-bindings" engineering decision;
  * per-call beta of each instrumenter via the in-process variant of the
    paper's fit (case2 kernel);
  * sampling-period sweep: beta as a function of the sampling period.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core.buffer import BUFFER_STRATEGIES
from repro.core.overhead import measure_inprocess_beta


def bench_buffers(n_events: int = 200_000, repeats: int = 5) -> Dict[str, float]:
    out = {}
    for name, cls in sorted(BUFFER_STRATEGIES.items()):
        times = []
        for _ in range(repeats):
            buf = cls(thread_id=0, flush_threshold=1 << 20, on_flush=lambda *a: None)
            if name == "list":
                append = buf.events.append
                t0 = time.perf_counter()
                for i in range(n_events):
                    append((0, 5, 123456789, 0))
                t1 = time.perf_counter()
            else:
                append = buf.append
                t0 = time.perf_counter()
                for i in range(n_events):
                    append(0, 5, 123456789, 0)
                t1 = time.perf_counter()
            buf.flush()
            times.append((t1 - t0) / n_events)
        out[name] = float(np.median(times)) * 1e9
        print(f"buffer[{name:6s}]  {out[name]:8.1f} ns/event")
    return out


def bench_instrumenter_beta(repeats: int = 3) -> Dict[str, float]:
    out = {}
    for inst in ["none", "profile", "trace", "sampling", "monitoring"]:
        _, beta = measure_inprocess_beta("case2", inst, ns=[2_000, 20_000], repeats=repeats)
        out[inst] = beta * 1e6
        print(f"beta[{inst:10s}]  {beta * 1e6:8.3f} us/iter (in-process, case2)")
    return out


def bench_sampling_periods(repeats: int = 3) -> Dict[str, float]:
    out = {}
    for period in [1, 10, 100, 1000]:
        _, beta = measure_inprocess_beta(
            "case2", "sampling", ns=[2_000, 20_000], repeats=repeats, sampling_period=period
        )
        out[str(period)] = beta * 1e6
        print(f"beta[sampling p={period:5d}]  {beta * 1e6:8.3f} us/iter")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="benchmarks/artifacts/event_throughput.json")
    p.add_argument("--repeats", type=int, default=3)
    ns = p.parse_args(argv)
    doc = {
        "buffers_ns_per_event": bench_buffers(repeats=ns.repeats),
        "instrumenter_beta_us": bench_instrumenter_beta(ns.repeats),
        "sampling_period_beta_us": bench_sampling_periods(ns.repeats),
    }
    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
