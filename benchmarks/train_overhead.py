"""Monitoring overhead on a real JAX training loop (paper Fig. 3, modernized).

The paper demonstrates tracing a Horovod/TensorFlow app; the JAX-era
question is what the instrumenters cost around a jit-compiled train step
(host work is dispatch + data; device work is opaque to CPython hooks).
Expectation (and the finding the numbers back): once steps are compiled,
Python-event overhead is amortized to ~zero — the value of the bindings is
the structured trace/profile, not free: uncompiled (tracing) steps ARE
Python-heavy and show up clearly.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import numpy as np


def run_loop(instrumenter: str, steps: int = 30, repeats: int = 3) -> Dict[str, float]:
    import jax

    import repro.core as rmon
    from repro.configs import get_smoke_config
    from repro.dist.train import make_train_step
    from repro.models import lm_init
    from repro.optim import adamw
    import jax.numpy as jnp
    import tempfile

    cfg = get_smoke_config("yi-34b")
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, adamw.AdamWConfig()))
    batch = {
        "tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab),
        "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab),
    }
    # warm-up compile outside measurement
    params, opt_state, _ = jax.block_until_ready(step_fn(params, opt_state, batch))

    times = []
    for _ in range(repeats):
        m = None
        if instrumenter != "off":
            m = rmon.init(
                instrumenter=instrumenter,
                run_dir=tempfile.mkdtemp(prefix=f"rm-train-{instrumenter}-"),
                substrates=("profiling",),
            )
        t0 = time.perf_counter()
        p, o = params, opt_state
        for i in range(steps):
            with rmon.region("train_step", module="bench"):
                p, o, stats = step_fn(p, o, batch)
        jax.block_until_ready(stats)
        t1 = time.perf_counter()
        if m is not None:
            rmon.finalize()
        times.append((t1 - t0) / steps)
    return {"per_step_ms": float(np.median(times)) * 1e3}


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--out", default="benchmarks/artifacts/train_overhead.json")
    ns = p.parse_args(argv)
    doc = {}
    base = None
    for inst in ["off", "none", "profile", "trace", "monitoring"]:
        r = run_loop(inst, ns.steps, ns.repeats)
        doc[inst] = r
        if inst == "off":
            base = r["per_step_ms"]
        ovh = (r["per_step_ms"] / base - 1) * 100 if base else 0.0
        print(f"train-loop[{inst:10s}]  {r['per_step_ms']:8.2f} ms/step  (+{ovh:.1f}%)")
    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
