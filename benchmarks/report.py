"""Render the roofline + perf-iteration artifacts as markdown tables
(pasted into EXPERIMENTS.md).

    PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import json
import os

ART = os.path.join("benchmarks", "artifacts")


def roofline_table(path: str) -> str:
    with open(path) as fh:
        recs = json.load(fh)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful-FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAIL | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def perf_table(path: str) -> str:
    if not os.path.exists(path):
        return "(no perf_iterations.json yet)"
    with open(path) as fh:
        groups = json.load(fh)
    out = []
    for g in groups:
        out.append(f"\n**{g['arch']} × {g['shape']}**\n")
        out.append("| variant | compute s | memory s | collective s | bound s | dominant |")
        out.append("|---|---|---|---|---|---|")
        for r in g["iterations"]:
            if r.get("status") != "ok":
                out.append(f"| {r['variant']} | — | — | — | — | {r.get('status')} |")
                continue
            note = f" ({r['note']})" if "note" in r else ""
            out.append(
                f"| {r['variant']}{note} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | {r['bound_s']:.3f} | {r['dominant']} |"
            )
    return "\n".join(out)


def memory_overhead_table(path: str) -> str:
    """Fold benchmarks/memory_overhead.py numbers into the overhead story:
    per-iteration β of the event workload with the memory substrate on/off,
    plus the bare-tracemalloc floor."""
    if not os.path.exists(path):
        return "(no memory_overhead.json yet — run benchmarks/memory_overhead.py)"
    with open(path) as fh:
        doc = json.load(fh)
    out = ["| variant | beta us/iter |", "|---|---|"]
    for label, beta in doc.get("beta_us", {}).items():
        out.append(f"| {label} | {beta:.3f} |")
    for label, beta in doc.get("floor_beta_us", {}).items():
        out.append(f"| {label} (no monitoring) | {beta:.3f} |")
    slowdown = doc.get("memory_slowdown")
    if slowdown:
        out.append("")
        out.append(
            f"Memory substrate slowdown on the event workload: **{slowdown:.2f}x** "
            f"over the instrumented baseline"
            + (" (smoke numbers)" if doc.get("smoke") else "")
        )
    return "\n".join(out)


def governed_overhead_table(path: str) -> str:
    """Fold benchmarks/governed_overhead.py numbers into the overhead story:
    bare/ungoverned/governed β plus the steady-state dilation the budget
    actually governs."""
    if not os.path.exists(path):
        return "(no governed_overhead.json yet — run benchmarks/governed_overhead.py)"
    with open(path) as fh:
        doc = json.load(fh)
    out = ["| variant | beta us/iter | dilation |", "|---|---|---|"]
    dil = doc.get("dilation", {})
    for label, beta in doc.get("beta_us", {}).items():
        d = dil.get(label)
        out.append(f"| {label} | {beta:.3f} | {'' if d is None else f'{d:.2f}x'} |")
    steady = doc.get("steady", {})
    if steady:
        out.append("")
        out.append(
            f"Steady-state governed dilation: **{steady.get('dilation', 0.0):+.3f}x** "
            f"(budget {doc.get('budget', 0.0):.2f}, "
            f"{'converged' if doc.get('converged') else 'NOT converged'})"
            + (" (smoke numbers)" if doc.get("smoke") else "")
        )
    check = doc.get("filter_check", {})
    if check:
        out.append(
            f"Suggested-filter re-run: {check.get('events_filtered', 0)} events vs "
            f"{check.get('events_unfiltered', 0)} unfiltered "
            f"({check.get('actions', 0)} governor action(s), final instrumenter "
            f"{((check.get('final_instrumenter') or {}).get('name', '?'))})"
        )
    return "\n".join(out)


def main() -> int:
    base = os.path.join(ART, "roofline_baseline.json")
    cur = os.path.join(ART, "roofline.json")
    if os.path.exists(base):
        print("### Roofline (paper-faithful baseline configs)\n")
        print(roofline_table(base))
    if os.path.exists(cur) and os.path.realpath(cur) != os.path.realpath(base):
        print("\n### Roofline (optimized)\n")
        print(roofline_table(cur))
    print("\n### Perf iterations\n")
    print(perf_table(os.path.join(ART, "perf_iterations.json")))
    print("\n### Memory-monitoring overhead\n")
    print(memory_overhead_table(os.path.join(ART, "memory_overhead.json")))
    print("\n### Governed overhead (runtime budget enforcement)\n")
    print(governed_overhead_table(os.path.join(ART, "governed_overhead.json")))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
