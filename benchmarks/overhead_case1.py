"""Paper Table 2 / Fig. 4a — test case 1 (loop only).

Measures wall-clock runtime of the paper's Listing-3 kernel under each
instrumenter (subprocess-isolated, exactly as a user launches
``python -m repro.scorep``), fits t = alpha + beta*N on medians with
numpy.polyfit, and reports alpha (one-time enable cost) and beta
(per-iteration cost).

Paper reference values (Haswell, CPython ~3.6): None beta=0.17us;
setprofile alpha=0.58s beta=0.18us; settrace alpha=0.63s beta=0.98us.
The *claims* being reproduced: (1) alpha ~ constant across instrumenters
and dominated by interpreter+measurement startup; (2) setprofile adds ~no
per-iteration cost when no calls occur; (3) settrace pays per executed line.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

from repro.core.overhead import OverheadResult, measure_case

DEFAULT_NS = [10_000, 100_000, 400_000, 1_000_000]
INSTRUMENTERS = [None, "none", "profile", "trace", "sampling"]
if hasattr(sys, "monitoring"):  # PEP 669 rows need Python 3.12+
    INSTRUMENTERS += ["monitoring", "adaptive"]


def run(
    ns: Optional[List[int]] = None,
    repeats: int = 7,
    instrumenters=INSTRUMENTERS,
    case: str = "case1",
) -> List[OverheadResult]:
    ns = ns or DEFAULT_NS
    results = []
    for inst in instrumenters:
        res = measure_case(case, inst, ns, repeats=repeats)
        label = "None(paper)" if inst is None else inst
        print(
            f"{case} {label:12s} alpha={res.alpha:7.3f} s  beta={res.beta * 1e6:8.3f} us/iter  "
            f"medians={['%.3f' % m for m in res.medians]}"
        )
        results.append(res)
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--repeats", type=int, default=7, help="51 for the paper's full protocol")
    p.add_argument("--ns", type=int, nargs="*", default=DEFAULT_NS)
    p.add_argument("--out", default="benchmarks/artifacts/overhead_case1.json")
    ns = p.parse_args(argv)
    results = run(ns.ns, ns.repeats)
    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump([r.__dict__ for r in results], fh, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
