"""Live-agent overhead — what continuous monitoring costs the measured
process.

Three measurements:

1. **Ring throughput** — vectorized publish/drain rate of the shared-memory
   ring (records/s) with a live reader, plus the drop rate under a reader
   that stops draining (the never-block contract: the writer keeps its pace
   and counts whole-batch drops instead of stalling the measured process).
2. **Publish-path dilation** — the same measured workload with the agent on
   vs off; the agent's own cost accounting (``publish_ns`` vs wall time)
   gives the publish fraction the governor charges against the budget.
3. **Governed publish fraction** — with the governor enabled, assert the
   publish path stays under its budget share (the <1% claim ``--smoke``
   gates in CI) with zero ring drops while a live reader follows.

    PYTHONPATH=src python benchmarks/agent_overhead.py           # full
    PYTHONPATH=src python benchmarks/agent_overhead.py --smoke   # CI gate
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict

import numpy as np

from repro.agent.ringbus import (
    RingReader,
    RingWriter,
    decode_records,
    encode_columns,
)
from repro.core.buffer import COLUMNS, EV_ENTER, EV_EXIT

#: --smoke gates: publish fraction of wall time (the <1% claim) and the
#: minimum acceptable ring transport rate (very conservative floor).
SMOKE_MAX_PUBLISH_FRACTION = 0.01
SMOKE_MIN_RECORDS_PER_S = 1e5


def _batch(n_pairs: int) -> np.ndarray:
    kinds = np.tile(np.array([EV_ENTER, EV_EXIT], dtype=COLUMNS[0][1]), n_pairs)
    regions = np.zeros(2 * n_pairs, dtype=COLUMNS[1][1])
    t = np.arange(2 * n_pairs, dtype=COLUMNS[2][1])
    aux = np.zeros(2 * n_pairs, dtype=COLUMNS[3][1])
    return encode_columns({"kind": kinds, "region": regions, "t": t, "aux": aux})


def bench_ring_throughput(batches: int, pairs_per_batch: int) -> Dict[str, float]:
    """Publish/drain rate with a reader keeping pace, in-process."""
    with tempfile.TemporaryDirectory(prefix="repro-agent-bench-") as d:
        ring = os.path.join(d, "agent.ring")
        rec = _batch(pairs_per_batch)
        w = RingWriter(ring, capacity=max(4 * len(rec), 1 << 12))
        r = RingReader(ring)
        drained = 0
        t0 = time.perf_counter()
        for _ in range(batches):
            w.publish(rec)
            drained += len(r.poll())
        dt = time.perf_counter() - t0
        published = batches * len(rec)
        w.close()
        r.close()
    rate = published / dt
    print(f"ring throughput: {rate / 1e6:7.2f} M records/s "
          f"({batches} batches x {len(rec)} records, drained {drained})")
    return {
        "records_per_s": rate,
        "published": published,
        "drained": drained,
        "drop_rate": 0.0 if drained == published else 1 - drained / published,
    }


def bench_slow_reader_drops(batches: int, pairs_per_batch: int) -> Dict[str, float]:
    """A reader that stops draining: the writer never blocks, drops whole
    batches, and counts every lost record."""
    with tempfile.TemporaryDirectory(prefix="repro-agent-bench-") as d:
        ring = os.path.join(d, "agent.ring")
        rec = _batch(pairs_per_batch)
        w = RingWriter(ring, capacity=2 * len(rec) + 8)
        r = RingReader(ring)  # attached, then stops draining
        t0 = time.perf_counter()
        accepted = sum(1 for _ in range(batches) if w.publish(rec))
        dt = time.perf_counter() - t0
        drops = w.drops
        survivors = len(decode_records(r.poll())[0])
        w.close()
        r.close()
    assert drops == (batches - accepted) * len(rec), "drop accounting drifted"
    print(f"slow reader: {accepted}/{batches} batches accepted, "
          f"{drops} records dropped whole-batch in {dt * 1e3:.1f} ms "
          f"({survivors} intact batches readable)")
    return {
        "batches": batches,
        "accepted_batches": accepted,
        "dropped_records": int(drops),
        "drop_rate": drops / (batches * len(rec)),
        "readable_batches": survivors,
    }


def _workload(m, iters: int, flush_threshold: int) -> float:
    """Tight region loop; returns wall seconds."""
    t0 = time.perf_counter()
    ctx = m.region("hot")
    for _ in range(iters):
        with ctx:
            pass
    m.thread_buffer().flush()
    return time.perf_counter() - t0


def bench_publish_dilation(iters: int, flush_threshold: int) -> Dict[str, object]:
    """End-to-end: same workload, agent off vs on (governed), comparing wall
    time and reading the publisher's own cost ledger.

    The workload is the instrumentation worst case — empty user regions at
    ~1 us/visit, every event published — so the raw (ungoverned cold-start)
    publish fraction here is an upper bound, not the steady state the smoke
    gates on (see :func:`bench_governed_fraction`)."""
    from repro.core.measurement import Measurement, MeasurementConfig

    out: Dict[str, object] = {}
    walls = {}
    for label, agent in (("agent_off", False), ("agent_on", True)):
        d = tempfile.mkdtemp(prefix=f"repro-agent-dilation-{label}-")
        cfg = MeasurementConfig(
            instrumenter="none", substrates=("profiling",), run_dir=d,
            flush_threshold=flush_threshold, agent=agent, budget=0.05,
        )
        m = Measurement(cfg)
        m.start()
        try:
            walls[label] = _workload(m, iters, flush_threshold)
            if agent:
                desc = m.agent.describe()
                wall_ns = walls[label] * 1e9
                out["publish_ns"] = desc["publish_ns"]
                out["cold_publish_fraction"] = desc["publish_ns"] / wall_ns
                out["ring_drops"] = desc["drops"]
        finally:
            m.finalize()
        print(f"{label:10s}: {walls[label] * 1e3:8.1f} ms")
    out["wall_s"] = walls
    out["dilation"] = walls["agent_on"] / walls["agent_off"]
    print(f"cold publish fraction: {out['cold_publish_fraction'] * 100:.3f}% "
          f"of wall (worst case; dilation {out['dilation']:.3f}x, "
          f"drops {out['ring_drops']})")
    return out


def bench_governed_fraction(
    flush_threshold: int, warm_s: float = 1.5, measure_s: float = 1.0
) -> Dict[str, object]:
    """Governed steady state: run the worst-case workload long enough for
    the publisher's stride controller to settle, then measure the publish
    fraction over a clean window — the fraction the <1% smoke gate holds."""
    from repro.core.measurement import Measurement, MeasurementConfig

    d = tempfile.mkdtemp(prefix="repro-agent-governed-")
    cfg = MeasurementConfig(
        instrumenter="none", substrates=("profiling",), run_dir=d,
        flush_threshold=flush_threshold, agent=True, budget=0.02,
    )
    m = Measurement(cfg)
    m.start()
    try:
        pub = m.agent.publisher
        pub.adjust_period_ns = int(0.25e9)  # settle fast; same controller
        ctx = m.region("hot")

        def spin(seconds: float) -> float:
            end = time.perf_counter() + seconds
            while time.perf_counter() < end:
                for _ in range(2000):
                    with ctx:
                        pass
            m.thread_buffer().flush()
            return time.perf_counter()

        spin(warm_s)
        p0, t0 = pub.publish_ns, time.perf_counter_ns()
        d0 = pub.writer.drops  # cold-start ramp (stride 1) may legitimately drop
        spin(measure_s)
        fraction = (pub.publish_ns - p0) / (time.perf_counter_ns() - t0)
        desc = m.agent.describe()
        window_drops = pub.writer.drops - d0
    finally:
        m.finalize()
    out = {
        "publish_fraction": fraction,
        "budget": cfg.budget,
        "stride": desc["stride"],
        "thinned_batches": desc["thinned_batches"],
        "thinned_records": desc["thinned_records"],
        "ring_drops": desc["drops"],
        "window_ring_drops": int(window_drops),
    }
    print(f"governed steady state: publish fraction {fraction * 100:.3f}% "
          f"(stride {desc['stride']}, {desc['thinned_batches']} batches "
          f"thinned, window drops {window_drops}, "
          f"total incl. cold ramp {desc['drops']})")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="small sizes + assert the <1%% governed publish "
                        "overhead and ring-throughput floors (CI)")
    p.add_argument("--iters", type=int, default=None,
                   help="workload region iterations")
    p.add_argument("--batches", type=int, default=None,
                   help="ring benchmark batch count")
    p.add_argument("--flush-events", type=int, default=4096)
    p.add_argument("--out", default="benchmarks/artifacts/agent_overhead.json")
    ns = p.parse_args(argv)

    iters = ns.iters or (60_000 if ns.smoke else 400_000)
    batches = ns.batches or (2_000 if ns.smoke else 20_000)

    doc: Dict[str, object] = {"smoke": ns.smoke, "iters": iters, "batches": batches}
    doc["ring"] = bench_ring_throughput(batches, pairs_per_batch=256)
    doc["slow_reader"] = bench_slow_reader_drops(200, pairs_per_batch=256)
    doc["dilation"] = bench_publish_dilation(iters, ns.flush_events)
    doc["governed"] = bench_governed_fraction(ns.flush_events)

    if ns.smoke:
        ring = doc["ring"]
        gov = doc["governed"]
        assert ring["records_per_s"] > SMOKE_MIN_RECORDS_PER_S, (
            f"ring throughput collapsed: {ring['records_per_s']:.0f} records/s"
        )
        assert ring["drop_rate"] == 0.0, "drops with a reader keeping pace"
        assert doc["slow_reader"]["dropped_records"] > 0, (
            "slow-reader scenario produced no drops — overrun path untested"
        )
        assert gov["publish_fraction"] < SMOKE_MAX_PUBLISH_FRACTION, (
            f"governed publish path costs {gov['publish_fraction'] * 100:.2f}% "
            f"of wall time (gate: {SMOKE_MAX_PUBLISH_FRACTION * 100:.0f}%)"
        )
        assert gov["window_ring_drops"] == 0, (
            f"live reader lost {gov['window_ring_drops']} records in the "
            "governed steady-state window"
        )
        print("smoke gates passed: governed publish fraction "
              f"{gov['publish_fraction'] * 100:.3f}% < "
              f"{SMOKE_MAX_PUBLISH_FRACTION * 100:.0f}%, zero drops")

    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"wrote {ns.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
