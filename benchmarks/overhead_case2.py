"""Paper Table 2 / Fig. 4b — test case 2 (function calls).

Same protocol as case 1 over the Listing-4 kernel (one Python function call
per iteration).  Paper reference: None beta=0.3us; setprofile beta=15.0us;
settrace beta=17.9us per iteration.  Claims reproduced: (1) per-call cost
dominates both instrumenters; (2) setprofile < settrace; (3) the ordering
and magnitude gap justify setprofile as the default instrumenter.

Beyond-paper rows: sampling (the paper's future-work suggestion) and
sys.monitoring (PEP 669) quantify how much of the per-call beta is
recoverable — EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List, Optional

from .overhead_case1 import INSTRUMENTERS, run


DEFAULT_NS = [10_000, 50_000, 200_000, 500_000]


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--repeats", type=int, default=7, help="51 for the paper's full protocol")
    p.add_argument("--ns", type=int, nargs="*", default=DEFAULT_NS)
    p.add_argument("--out", default="benchmarks/artifacts/overhead_case2.json")
    ns = p.parse_args(argv)
    results = run(ns.ns, ns.repeats, case="case2")
    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump([r.__dict__ for r in results], fh, indent=1)
    # the paper's headline claim, asserted
    by_name = {r.instrumenter: r for r in results}
    if "profile" in by_name and "trace" in by_name:
        ok = by_name["profile"].beta < by_name["trace"].beta
        print(f"claim(setprofile beta < settrace beta): {'CONFIRMED' if ok else 'REFUTED'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
