"""Paper Table 2 / Fig. 4b — test case 2 (function calls).

Same protocol as case 1 over the Listing-4 kernel (one Python function call
per iteration).  Paper reference: None beta=0.3us; setprofile beta=15.0us;
settrace beta=17.9us per iteration.  Claims reproduced: (1) per-call cost
dominates both instrumenters; (2) setprofile < settrace; (3) the ordering
and magnitude gap justify setprofile as the default instrumenter.

Beyond-paper rows: sampling (the paper's future-work suggestion),
sys.monitoring (PEP 669) and the adaptive PEP 669 epoch sampler quantify how
much of the per-call beta is recoverable — EXPERIMENTS.md §Perf.

Filtered-residual rows (``<inst>+filtered``) run the kernel with
``--filter=exclude:*`` — every region filtered, nothing recorded — so their
beta minus the ``none``-instrumenter baseline is the pure per-call cost of a
*filtered* verdict.  Under ``profile`` that residual is a real per-call
dict-lookup cost; under ``monitoring`` the DISABLE protocol retires filtered
locations after one hit, so the residual must be ~0.  ``--smoke`` (the
3.12+ CI job) asserts exactly that.

    PYTHONPATH=src python -m benchmarks.overhead_case2            # full fit
    PYTHONPATH=src python -m benchmarks.overhead_case2 --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from .overhead_case1 import run
from repro.core.overhead import measure_case

DEFAULT_NS = [10_000, 50_000, 200_000, 500_000]
SMOKE_NS = [50_000, 300_000]

_HAS_MONITORING = hasattr(sys, "monitoring")


def filtered_rows(ns: List[int], repeats: int):
    """``exclude:*`` rows: the kernel under an everything-filtered run."""
    rows = []
    insts = ["profile"] + (["monitoring"] if _HAS_MONITORING else [])
    for inst in insts:
        res = measure_case(
            "case2", inst, ns, repeats=repeats, extra_args=("--filter=exclude:*",)
        )
        res.instrumenter = f"{inst}+filtered"
        print(
            f"case2 {res.instrumenter:20s} alpha={res.alpha:7.3f} s  "
            f"beta={res.beta * 1e6:8.3f} us/iter  "
            f"medians={['%.3f' % m for m in res.medians]}"
        )
        rows.append(res)
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--repeats", type=int, default=7, help="51 for the paper's full protocol")
    p.add_argument("--ns", type=int, nargs="*", default=None)
    p.add_argument("--smoke", action="store_true",
                   help="CI mode: fewer/smaller rows + hard asserts on the "
                        "DISABLE zero-residual claim (needs 3.12+ for the "
                        "monitoring/adaptive rows)")
    p.add_argument("--out", default="benchmarks/artifacts/overhead_case2.json")
    args = p.parse_args(argv)
    ns = args.ns or (SMOKE_NS if args.smoke else DEFAULT_NS)
    repeats = 3 if args.smoke and args.repeats == 7 else args.repeats

    if args.smoke:
        instrumenters = [None, "none", "profile"]
    else:
        instrumenters = [None, "none", "profile", "trace", "sampling"]
    if _HAS_MONITORING:
        instrumenters += ["monitoring", "adaptive"]
    else:
        print("note: monitoring/adaptive rows skipped (sys.monitoring needs 3.12+)")

    results = run(ns, repeats, instrumenters=instrumenters, case="case2")
    results += filtered_rows(ns, repeats)

    by_name = {r.instrumenter: r for r in results}
    base = by_name["none"].beta  # measurement loaded, instrumenter none
    residuals = {
        name: by_name[name].beta - base
        for name in by_name
        if name.endswith("+filtered")
    }
    doc = {
        "ns": ns,
        "repeats": repeats,
        "smoke": args.smoke,
        "rows": [r.__dict__ for r in results],
        "filtered_residual_us": {k: v * 1e6 for k, v in residuals.items()},
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=1)

    # the paper's headline claim, asserted
    if "profile" in by_name and "trace" in by_name:
        ok = by_name["profile"].beta < by_name["trace"].beta
        print(f"claim(setprofile beta < settrace beta): {'CONFIRMED' if ok else 'REFUTED'}")

    res_prof = residuals.get("profile+filtered")
    res_mon = residuals.get("monitoring+filtered")
    if res_prof is not None:
        print(f"filtered residual [profile]    {res_prof * 1e6:8.4f} us/iter")
    if res_mon is not None:
        print(f"filtered residual [monitoring] {res_mon * 1e6:8.4f} us/iter")
        # DISABLE claim: filtered regions cost ~0 per call under monitoring
        # (one hit per location per epoch), vs profile's real per-call
        # filtered fast path.  0.1 us absolute floor absorbs subprocess
        # timing noise in the beta fit at smoke scale.
        zero = res_mon <= max(0.3 * res_prof, 0.1e-6)
        print(f"claim(monitoring filtered residual ~0): "
              f"{'CONFIRMED' if zero else 'REFUTED'}")
        if args.smoke:
            assert zero, (
                f"monitoring filtered residual not ~0: {res_mon * 1e6:.4f} us/iter "
                f"(profile residual {res_prof * 1e6:.4f} us/iter)"
            )
    if "adaptive" in by_name and "monitoring" in by_name:
        b_ad = by_name["adaptive"].beta - base
        b_mon = by_name["monitoring"].beta - base
        print(f"beta-over-none [monitoring] {b_mon * 1e6:8.4f} us/iter, "
              f"[adaptive] {b_ad * 1e6:8.4f} us/iter")
        if args.smoke:
            # The adaptive sampler DISABLEs unsampled calls entirely, so its
            # per-call cost must undercut exhaustive monitoring clearly.
            assert b_ad <= 0.5 * b_mon + 0.1e-6, (
                f"adaptive beta not below monitoring beta: "
                f"{b_ad * 1e6:.4f} vs {b_mon * 1e6:.4f} us/iter"
            )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
