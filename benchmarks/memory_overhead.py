"""Memory-monitoring overhead — the paper's overhead study (§3), extended
to the memory dimension.

The paper fits instrumented runtime as ``t = α + β·N`` per instrumenter;
this benchmark fits the same model for the *memory substrate* riding on the
profile instrumenter, isolating what the heap collector adds at flush
granularity on the event-throughput workload (paper case 2: a tight loop of
Python function calls).  It also measures the raw cost of ``tracemalloc``
itself on the same kernel — the floor any tracemalloc-based collector pays —
and an end-to-end slowdown ratio with the substrate on vs off.

    PYTHONPATH=src python benchmarks/memory_overhead.py           # full fit
    PYTHONPATH=src python benchmarks/memory_overhead.py --smoke   # CI: small + correctness

The ``--smoke`` mode also runs one measured workload with the substrate
enabled and checks the memory.json artifact carries region attribution and
an RSS timeline (the CI-level correctness contract).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
import tracemalloc
from typing import Dict

import numpy as np

from repro.core.overhead import CASES, fit_linear, measure_inprocess_beta

#: (label, substrates) rows of the β table.  "profile+none" is the event
#: path alone; the profiling row is the existing flush-time consumer for
#: scale; the memory rows add the heap collector.
VARIANTS = [
    ("profile+none", ()),
    ("profile+profiling", ("profiling",)),
    ("profile+memory", ("memory",)),
    ("profile+profiling+memory", ("profiling", "memory")),
]


def bench_beta(ns, repeats: int, flush_threshold: int) -> Dict[str, float]:
    out = {}
    for label, substrates in VARIANTS:
        _, beta = measure_inprocess_beta(
            "case2", "profile", ns=ns, repeats=repeats,
            substrates=substrates, flush_threshold=flush_threshold,
        )
        out[label] = beta * 1e6
        print(f"beta[{label:26s}]  {beta * 1e6:8.3f} us/iter")
    return out


def bench_tracemalloc_floor(ns, repeats: int) -> Dict[str, float]:
    """β of the bare case-2 kernel with tracemalloc off vs on — no
    monitoring at all, just the allocator hook every collector pays for."""
    code = compile(CASES["case2"], "<case2>", "exec")

    def run(n: int) -> float:
        argv_saved = sys.argv
        sys.argv = ["case", str(n)]
        try:
            t0 = time.perf_counter()
            exec(code, {"__name__": "__bench__"})
            return time.perf_counter() - t0
        finally:
            sys.argv = argv_saved

    out = {}
    for label, tracing in [("tracemalloc_off", False), ("tracemalloc_on", True)]:
        medians = []
        for n in ns:
            times = []
            for _ in range(repeats):
                if tracing:
                    tracemalloc.start()
                try:
                    times.append(run(n))
                finally:
                    if tracing:
                        tracemalloc.stop()
            medians.append(float(np.median(times)))
        _, beta = fit_linear(list(ns), medians)
        out[label] = beta * 1e6
        print(f"beta[{label:26s}]  {beta * 1e6:8.3f} us/iter")
    return out


def check_artifact(flush_threshold: int) -> Dict[str, object]:
    """Correctness contract: a memory-substrate run attributes regions and
    records an RSS timeline."""
    import repro.core as rmon

    run_dir = tempfile.mkdtemp(prefix="repro-mem-overhead-")
    rmon.init(
        instrumenter="profile", run_dir=run_dir, experiment="mem-overhead",
        substrates=("profiling", "memory"), flush_threshold=flush_threshold,
        memory_period=0.02,
    )

    def churn():
        return [bytearray(1024) for _ in range(256)]

    keep = []
    with rmon.region("churn"):
        for _ in range(64):
            keep.append(churn())
    rmon.finalize()
    with open(os.path.join(run_dir, "memory.json")) as fh:
        doc = json.load(fh)
    regions = doc["heap"]["regions"]
    assert regions, "memory.json carries no region attribution"
    assert doc["series"].get("mem.rss_mb"), "memory.json carries no RSS timeline"
    total_alloc = sum(r["alloc_bytes"] for r in regions.values())
    assert total_alloc > 0, "no allocation bytes attributed"
    return {"run_dir": run_dir, "regions": len(regions), "alloc_bytes": total_alloc}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="small iteration counts + artifact correctness (CI)")
    p.add_argument("--repeats", type=int, default=None)
    p.add_argument("--flush-events", type=int, default=8192)
    p.add_argument("--out", default="benchmarks/artifacts/memory_overhead.json")
    ns_args = p.parse_args(argv)

    ns = [2_000, 20_000] if ns_args.smoke else [10_000, 50_000, 200_000]
    repeats = ns_args.repeats or (2 if ns_args.smoke else 5)

    doc: Dict[str, object] = {"ns": ns, "repeats": repeats, "smoke": ns_args.smoke}
    doc["beta_us"] = bench_beta(ns, repeats, ns_args.flush_events)
    doc["floor_beta_us"] = bench_tracemalloc_floor(ns, repeats)
    artifact = check_artifact(ns_args.flush_events)
    print(f"artifact check: {artifact['regions']} regions, "
          f"{artifact['alloc_bytes'] / 1e6:.1f} MB attributed")
    doc["artifact_check"] = artifact

    base = doc["beta_us"]["profile+none"]
    mem = doc["beta_us"]["profile+memory"]
    doc["memory_slowdown"] = mem / base if base > 0 else None
    if doc["memory_slowdown"]:
        print(f"memory substrate slowdown on the event workload: "
              f"{doc['memory_slowdown']:.2f}x over instrumented baseline")

    os.makedirs(os.path.dirname(ns_args.out), exist_ok=True)
    with open(ns_args.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"wrote {ns_args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
