import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""§Perf hillclimb driver: measure roofline-term deltas for config variants
of a selected (arch x shape) cell.

Per iteration the methodology of EXPERIMENTS.md §Perf applies: state a
hypothesis with napkin math, lower the variant, re-derive the three terms,
confirm/refute.  This driver does the measuring; the narrative lives in
EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.perf_iterations --arch yi-34b \
        --shape train_4k --variants baseline bf16_params flash_analytic
"""

import argparse
import dataclasses
import json
from typing import Dict, Optional

VARIANTS = {
    "baseline": {},
    "bf16_params": {"params_compute_dtype": "bfloat16"},
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": "none"},
    "loss_chunks_32": {"chunked_loss_chunks": 32},
    "fp8_kv": {"kv_cache_dtype": "float8_e4m3fn"},
    "bf16_params+fp8_kv": {"params_compute_dtype": "bfloat16", "kv_cache_dtype": "float8_e4m3fn"},
    "moe_group_1k": {"_moe": {"group_size": 1024}},
    "moe_group_8k": {"_moe": {"group_size": 8192}},
    "moe_cap_1.0": {"_moe": {"capacity_factor": 1.0}},
    # flash_analytic is a post-processing row, handled below
}

OUT = os.path.join("benchmarks", "artifacts", "perf_iterations.json")
PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _apply_overrides(cfg, overrides: Dict):
    moe_over = overrides.pop("_moe", None)
    if moe_over and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


import re as _re

_SHAPE_RE = _re.compile(r"= (?:\()?([a-z0-9]+)\[([0-9,]+)\]")
_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1, "u8": 1}


def quadratic_hlo_bytes(hlo_text: str, min_elems: float) -> float:
    """Sum result bytes of ops with attention-quadratic outputs (>= min_elems
    elements) — the tensors a fused flash kernel never materializes to HBM.
    Write traffic only; the consumer read is approximated as x2 by callers."""
    total = 0.0
    for line in hlo_text.splitlines():
        m = _SHAPE_RE.search(line)
        if not m:
            continue
        dtype, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n >= min_elems:
            total += n * _DT_BYTES.get(dtype, 4)
    return total


def measure_variant(arch: str, shape: str, name: str, overrides: Dict) -> Dict:
    import benchmarks.roofline as rl
    from repro.configs import get_config, get_shape_cell

    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    over = dict(overrides)

    from repro.configs import cell_applicable
    from repro.core.jax_events import compiled_metrics
    from repro.dist import serve as dserve
    from repro.dist import train as dtrain
    from repro.launch.mesh import make_production_mesh
    from repro.models import lm_init

    cfg_v = _apply_overrides(cfg, dict(over))
    ok, reason = cell_applicable(cfg_v, cell)
    if not ok:
        return {"variant": name, "status": "skip", "reason": reason}
    mesh = make_production_mesh()

    # threshold for "attention-quadratic" outputs: a fraction of the
    # per-device score tensor.  The HLO is SPMD-partitioned: batch is /16
    # (data) and the query dim /16 (model, Megatron-SP), so the per-device
    # score block is B/16 x heads x S/16 x T; /8 slack keeps activations and
    # MoE dispatch tensors below the bar.
    s_dim = cell.seq_len if cell.kind != "decode" else 1
    b_dev = max(cell.global_batch // 16, 1)
    min_elems = b_dev * max(cfg.n_heads, 1) * max(s_dim // 16, 1) * cell.seq_len / 8.0

    def metrics_at_depth(n: int) -> Dict[str, float]:
        cfg_n = rl._cfg_with_depth(cfg_v, n)
        with mesh:
            if cell.kind == "train":
                compile_for = dtrain.jit_train_step(cfg_n, mesh)
                bs = dtrain.batch_shapes(cfg_n, cell.global_batch, cell.seq_len)
                jitted, (ps, os_, _) = compile_for(bs)
                compiled = jitted.lower(ps, os_, bs).compile()
            elif cell.kind == "prefill":
                jitted, (ps, bs) = dserve.jit_prefill_step(cfg_n, mesh, cell.global_batch, cell.seq_len)
                compiled = jitted.lower(ps, bs).compile()
            else:
                jitted, (ps, cs, ts) = dserve.jit_serve_step(cfg_n, mesh, cell.global_batch, cell.seq_len)
                compiled = jitted.lower(ps, cs, ts).compile()
        out = compiled_metrics(compiled)
        out["quad_bytes"] = quadratic_hlo_bytes(compiled.as_text(), min_elems)
        return out

    m1, m2 = metrics_at_depth(1), metrics_at_depth(2)
    n = cfg_v.n_groups

    def ex(key):
        slope = m2[key] - m1[key]
        return max(m1[key] - slope + slope * n, 0.0)

    flops, bytes_, wire = ex("hlo_flops"), ex("hlo_bytes"), ex("collective_wire_bytes")
    quad = ex("quad_bytes") * 2.0  # write + one consumer read
    rec = {
        "variant": name,
        "status": "ok",
        "compute_s": flops / 197e12,
        "memory_s": bytes_ / 819e9,
        "collective_s": wire / 50e9,
        "quad_traffic_s": min(quad / 819e9, bytes_ / 819e9),
    }
    rec["bound_s"] = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
    rec["dominant"] = max(
        ("compute", rec["compute_s"]), ("memory", rec["memory_s"]), ("collective", rec["collective_s"]),
        key=lambda kv: kv[1],
    )[0]
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--variants", nargs="+", default=["baseline", "bf16_params"])
    ns = p.parse_args(argv)

    from repro.configs import get_config, get_shape_cell

    results = []
    for name in ns.variants:
        if name == "flash_analytic":
            # post-processing on the measured baseline: subtract the
            # HLO-parsed quadratic (score) traffic — what the validated
            # Pallas flash kernel keeps in VMEM on the TPU target.
            base = next((r for r in results if r["variant"] == "baseline" and r["status"] == "ok"), None)
            if base is None:
                print("flash_analytic needs a baseline row first")
                continue
            rec = dict(base)
            rec["variant"] = "flash_analytic"
            rec["memory_s"] = max(base["memory_s"] - base.get("quad_traffic_s", 0.0), 0.0)
            rec["bound_s"] = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
            rec["dominant"] = max(
                ("compute", rec["compute_s"]), ("memory", rec["memory_s"]),
                ("collective", rec["collective_s"]), key=lambda kv: kv[1])[0]
            rec["note"] = (
                f"-{base.get('quad_traffic_s', 0.0):.3f}s HLO-parsed quadratic traffic "
                "(Pallas flash kernel keeps scores in VMEM)"
            )
        else:
            rec = measure_variant(ns.arch, ns.shape, name, VARIANTS[name])
        results.append(rec)
        if rec["status"] == "ok":
            print(
                f"{ns.arch} {ns.shape} {rec['variant']:20s} compute={rec['compute_s']:.3f}s "
                f"memory={rec['memory_s']:.3f}s collective={rec['collective_s']:.3f}s "
                f"bound={rec['bound_s']:.3f}s dom={rec['dominant']}"
            )
        else:
            print(f"{ns.arch} {ns.shape} {rec['variant']:20s} {rec['status']}")

    existing = []
    if os.path.exists(OUT):
        with open(OUT) as fh:
            existing = json.load(fh)
    existing.append({"arch": ns.arch, "shape": ns.shape, "iterations": results})
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as fh:
        json.dump(existing, fh, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
