"""Governed-overhead benchmark — the paper's §3 study, closed-loop.

The paper fits instrumented runtime as ``t = α + β·N`` and leaves "ways to
control the runtime overhead" as future work (§5).  This benchmark runs the
case-2 kernel (one Python function call per iteration) three ways:

    bare        no measurement at all (the paper's *None* row)
    ungoverned  profile instrumenter, unbounded β
    governed    same instrumenter + ``--budget``: the runtime governor
                calibrates per-event cost, then escalates online (exclude
                hot regions -> raise sampling period -> downgrade
                instrumenter) until the estimated dilation fits the budget

The governor's calibration probe and escalation transient are per-run
constants, so they land in α; the fitted β shows the governed steady state.
Convergence claim: governed β-dilation <= ~1.5x the budget, against an
ungoverned dilation that is orders of magnitude larger.

Also exercised (the artifact contract): ``governor.json``'s suggested
filter spec round-trips through ``Filter.from_spec`` and, applied to an
ungoverned re-run via ``filter_spec``, collapses the event rate.

    PYTHONPATH=src python benchmarks/governed_overhead.py           # full fit
    PYTHONPATH=src python benchmarks/governed_overhead.py --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.filtering import Filter
from repro.core.governor import load_governor
from repro.core.measurement import Measurement, MeasurementConfig
from repro.core.overhead import CASES, fit_linear, measure_inprocess_beta

BUDGET = 0.05
FLUSH = 4096  # small threshold so the governor evaluates early and often


def bench_bare_beta(ns: List[int], repeats: int) -> float:
    code = compile(CASES["case2"], "<case2>", "exec")
    medians = []
    for n in ns:
        times = []
        for _ in range(repeats):
            argv_saved = sys.argv
            sys.argv = ["case", str(n)]
            try:
                t0 = time.perf_counter()
                exec(code, {"__name__": "__bare__"})
                times.append(time.perf_counter() - t0)
            finally:
                sys.argv = argv_saved
        medians.append(float(np.median(times)))
    _, beta = fit_linear(ns, medians)
    return beta


def run_once(
    n: int,
    budget: float = 0.0,
    filter_spec: str = "",
    instrumenter: str = "profile",
) -> Tuple[float, str]:
    """One in-process measured run; returns (seconds, run_dir)."""
    code = compile(CASES["case2"], "<case2>", "exec")
    cfg = MeasurementConfig(
        instrumenter=instrumenter,
        substrates=(),
        run_dir=tempfile.mkdtemp(prefix="repro-governed-"),
        flush_threshold=FLUSH,
        filter_spec=filter_spec,
        budget=budget,
    )
    m = Measurement(cfg)
    argv_saved = sys.argv
    sys.argv = ["case", str(n)]
    try:
        t0 = time.perf_counter()
        m.start()
        exec(code, {"__name__": "__overhead__"})
        m.stop()
        elapsed = time.perf_counter() - t0
    finally:
        sys.argv = argv_saved
        m.finalize()
    return elapsed, m.run_dir


def measure_steady_dilation(n: int, budget: float, repeats: int) -> Dict[str, float]:
    """Converged-state dilation: warm one governed measurement past the
    governor's escalation horizon with a full kernel pass, then time further
    passes inside the *same* measurement.  Best-of-k minima on both sides
    cancel scheduler noise, so this is robust at CI scale where a β fit over
    small N would be dominated by how much of the escalation transient each
    run happens to pay."""
    code = compile(CASES["case2"], "<case2>", "exec")

    def timed_passes() -> float:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            exec(code, {"__name__": "__overhead__"})
            best = min(best, time.perf_counter() - t0)
        return best

    argv_saved = sys.argv
    sys.argv = ["case", str(n)]
    try:
        exec(code, {"__name__": "__bare__"})  # interpreter warm-up
        bare = timed_passes()
        cfg = MeasurementConfig(
            instrumenter="profile", substrates=(),
            run_dir=tempfile.mkdtemp(prefix="repro-governed-"),
            flush_threshold=FLUSH, budget=budget,
        )
        m = Measurement(cfg)
        try:
            m.start()
            exec(code, {"__name__": "__overhead__"})  # converge the governor
            governed = timed_passes()
            m.stop()
        finally:
            m.finalize()
    finally:
        sys.argv = argv_saved
    return {
        "bare_s": bare,
        "governed_s": governed,
        "dilation": (governed - bare) / bare,
    }


def events_flushed(run_dir: str) -> int:
    with open(os.path.join(run_dir, "meta.json")) as fh:
        return int(json.load(fh).get("events_flushed", 0))


def check_suggested_filter(n: int) -> Dict[str, object]:
    """Artifact contract: the suggested spec parses and cuts the event rate."""
    _, gov_dir = run_once(n, budget=BUDGET)
    doc = load_governor(gov_dir)
    assert doc is not None, "governed run wrote no governor.json"
    spec = doc.get("suggested_filter", "")
    flt = Filter.from_spec(spec)  # round-trip: must parse
    assert flt.exclude or flt.runtime_exclude, (
        f"suggested filter has no exclude rules: {spec!r}"
    )
    _, unfiltered_dir = run_once(n)
    _, filtered_dir = run_once(n, filter_spec=spec)
    ev_unfiltered = events_flushed(unfiltered_dir)
    ev_filtered = events_flushed(filtered_dir)
    assert ev_filtered < 0.5 * ev_unfiltered, (
        f"suggested filter did not reduce event rate: "
        f"{ev_filtered} vs {ev_unfiltered} (spec: {spec!r})"
    )
    return {
        "suggested_filter": spec,
        "events_unfiltered": ev_unfiltered,
        "events_filtered": ev_filtered,
        "actions": len(doc.get("actions", [])),
        "final_instrumenter": doc.get("final_instrumenter"),
        "governed_run_dir": gov_dir,
    }


def check_adaptive_rung(n: int, budget: float = BUDGET) -> Dict[str, object]:
    """Ladder check (3.12+): a percent-level budget walks the governor off
    the counting sampler, and with the PEP 669 adaptive rung present it must
    land there — bounded-rate signal retained — instead of going dark at
    ``none``.

    The counting sampler's cost floor is its unsampled per-call base cost
    times the call rate (far above any percent-level budget on this kernel,
    even at the period cap), so exclusions and period raises cannot satisfy
    the budget.  The adaptive sampler's projected cost is capped at its
    target sample rate, which fits with a wide margin."""
    code = compile(CASES["case2"], "<case2>", "exec")
    cfg = MeasurementConfig(
        instrumenter="sampling", substrates=(),
        run_dir=tempfile.mkdtemp(prefix="repro-governed-"),
        flush_threshold=2048, sampling_period=5, adaptive_rate=2000.0,
        budget=budget,
    )
    m = Measurement(cfg)
    argv_saved = sys.argv
    sys.argv = ["case", str(n)]
    try:
        m.start()
        exec(code, {"__name__": "__overhead__"})
        m.stop()
    finally:
        sys.argv = argv_saved
        m.finalize()
    doc = load_governor(m.run_dir)
    assert doc is not None, "governed sampling run wrote no governor.json"
    downgrades = [
        (s.get("from"), s.get("to"))
        for a in doc["actions"] for s in a["steps"]
        if s["kind"] == "downgrade_instrumenter"
    ]
    final = doc["final_instrumenter"]["name"]
    assert ("sampling", "adaptive") in downgrades, (
        f"adaptive rung not exercised: downgrades={downgrades}, final={final}"
    )
    assert final == "adaptive", (
        f"ladder overshot the adaptive rung: final={final}, "
        f"downgrades={downgrades}"
    )
    assert events_flushed(m.run_dir) > 0, "adaptive rung recorded no events"
    return {
        "downgrades": downgrades,
        "final_instrumenter": doc["final_instrumenter"],
        "actions": len(doc["actions"]),
        "events_flushed": events_flushed(m.run_dir),
    }


def check_static_plan(n: int, budget: float = BUDGET) -> Dict[str, object]:
    """Warm-start check (repro.core.staticpass): plan the case-2 kernel
    ahead of run, then run the same governed workload cold and plan-seeded.

    The planner classifies ``add`` as trivial+hot and pre-excludes it, so
    the plan-seeded governor starts with the flood already dammed: it must
    converge with *strictly fewer* escalation steps than the cold run,
    which has to discover the same verdict online (first flush, exclude
    rung) before its projection fits the budget."""
    from repro.core.staticpass import build_plan, save_plan

    tmp = tempfile.mkdtemp(prefix="repro-planbench-")
    # The kernel must exist as a real file under its runtime module name:
    # the plan's exclude patterns carry both the dotted module and the file
    # stem, and both are derived from this path.
    kpath = os.path.join(tmp, "case2_kernel.py")
    with open(kpath, "w") as fh:
        fh.write(CASES["case2"])
    plan = build_plan([kpath])
    plan_path = save_plan(plan, os.path.join(tmp, "static_plan.json"))
    assert any("add" in p for p in plan["filter"]["patterns"]), (
        f"planner did not exclude the hot trivial kernel: "
        f"{plan['filter']['patterns']}"
    )

    def governed(static_plan: str = "") -> Tuple[Dict[str, object], int]:
        code = compile(CASES["case2"], kpath, "exec")
        cfg = MeasurementConfig(
            instrumenter="profile", substrates=(),
            run_dir=tempfile.mkdtemp(prefix="repro-governed-"),
            flush_threshold=FLUSH, budget=budget, static_plan=static_plan,
        )
        m = Measurement(cfg)
        argv_saved = sys.argv
        sys.argv = ["case", str(n)]
        try:
            m.start()
            exec(code, {"__name__": "case2_kernel", "__file__": kpath})
            m.stop()
        finally:
            sys.argv = argv_saved
            m.finalize()
        doc = load_governor(m.run_dir)
        assert doc is not None, "governed run wrote no governor.json"
        steps = sum(len(a["steps"]) for a in doc.get("actions", []))
        return doc, steps

    cold_doc, cold_steps = governed()
    warm_doc, warm_steps = governed(static_plan=plan_path)
    assert warm_doc.get("static_plan"), "plan-seeded run lost its plan section"
    assert not cold_doc.get("static_plan"), "cold run claims a plan"
    assert warm_steps < cold_steps, (
        f"plan-seeded run did not save escalation work: "
        f"{warm_steps} steps warm vs {cold_steps} cold"
    )
    return {
        "plan_patterns": plan["filter"]["patterns"],
        "cold_steps": cold_steps,
        "warm_steps": warm_steps,
        "cold_actions": len(cold_doc.get("actions", [])),
        "warm_actions": len(warm_doc.get("actions", [])),
        "warm_final": warm_doc.get("final_instrumenter"),
        "cold_final": cold_doc.get("final_instrumenter"),
    }


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="small iteration counts + loose convergence asserts (CI)")
    p.add_argument("--budget", type=float, default=BUDGET)
    p.add_argument("--repeats", type=int, default=None)
    p.add_argument("--static-plan", action="store_true", dest="static_plan",
                   help="also run the plan-seeded (repro.core.staticpass) "
                        "vs cold warm-start comparison (always on in --smoke)")
    p.add_argument("--out", default="benchmarks/artifacts/governed_overhead.json")
    args = p.parse_args(argv)

    # Full-mode ns start high enough that every governed run outlives the
    # governor's convergence horizon (first flush + a watchdog correction,
    # tens of ms): the escalation transient is then a constant across N and
    # lands in α, leaving β the governed steady state.
    ns = [10_000, 50_000] if args.smoke else [200_000, 600_000, 1_600_000]
    repeats = args.repeats or (3 if args.smoke else 5)
    budget = args.budget

    beta_bare = bench_bare_beta(ns, repeats)
    _, beta_ungov = measure_inprocess_beta(
        "case2", "profile", ns=ns, repeats=repeats, flush_threshold=FLUSH
    )
    _, beta_gov = measure_inprocess_beta(
        "case2", "profile", ns=ns, repeats=repeats, flush_threshold=FLUSH,
        budget=budget,
    )
    dil_ungov = (beta_ungov - beta_bare) / beta_bare
    dil_gov = (beta_gov - beta_bare) / beta_bare
    # A few hundred ms per pass keeps scheduler noise small relative to the
    # budget being checked; one re-measure before judging absorbs a single
    # load spike crossing the whole first measurement.
    steady_n = max(ns[-1], 400_000)
    steady = measure_steady_dilation(steady_n, budget, max(repeats, 5))
    if steady["dilation"] > 1.5 * budget:
        retry = measure_steady_dilation(steady_n, budget, max(repeats, 5))
        if retry["dilation"] < steady["dilation"]:
            steady = retry
    converged = steady["dilation"] <= 1.5 * budget
    print(f"beta[bare]       {beta_bare * 1e6:8.4f} us/iter")
    print(f"beta[ungoverned] {beta_ungov * 1e6:8.4f} us/iter  dilation {dil_ungov:8.2f}x")
    print(f"beta[governed]   {beta_gov * 1e6:8.4f} us/iter  dilation {dil_gov:8.3f}x "
          f"(fit includes escalation transient)")
    print(f"steady-state governed dilation at N={steady_n}: {steady['dilation']:+.3f}x "
          f"(budget {budget:.2f}, converged: {converged})")

    artifact = check_suggested_filter(ns[-1])
    print(f"governor actions: {artifact['actions']}, final instrumenter "
          f"{artifact['final_instrumenter']}")
    print(f"suggested filter: {artifact['suggested_filter']}")
    print(f"event rate with suggested filter: {artifact['events_filtered']} vs "
          f"{artifact['events_unfiltered']} unfiltered")

    static_plan = None
    if args.static_plan or args.smoke:
        static_plan = check_static_plan(ns[-1], budget)
        print(f"static plan warm start: {static_plan['warm_steps']} escalation "
              f"steps vs {static_plan['cold_steps']} cold "
              f"(plan pre-excluded {len(static_plan['plan_patterns'])} pattern(s))")

    adaptive_rung = None
    if hasattr(sys, "monitoring"):
        adaptive_rung = check_adaptive_rung(max(ns[-1], 120_000), budget)
        print(f"adaptive rung: downgrades {adaptive_rung['downgrades']}, "
              f"final {adaptive_rung['final_instrumenter']}, "
              f"{adaptive_rung['events_flushed']} events recorded")
    else:
        print("adaptive rung check skipped (sys.monitoring needs 3.12+)")

    doc = {
        "ns": ns, "repeats": repeats, "budget": budget, "smoke": args.smoke,
        "beta_us": {
            "bare": beta_bare * 1e6,
            "ungoverned": beta_ungov * 1e6,
            "governed": beta_gov * 1e6,
        },
        "dilation": {"ungoverned": dil_ungov, "governed": dil_gov},
        "steady": steady,
        "converged": bool(converged),
        "filter_check": artifact,
        "static_plan": static_plan,
        "adaptive_rung": adaptive_rung,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"wrote {args.out}")

    # Convergence asserts — on the steady state, which is what the budget
    # governs.  β_bare on this kernel is tens of ns/iter, so even best-of-k
    # minima keep a few percent of scheduler noise on a loaded CI box; smoke
    # adds an absolute slack on top and keeps a relative fallback (the
    # governor must kill >=95% of the unbounded dilation).
    slack = 0.10 if args.smoke else 0.05
    assert (
        steady["dilation"] <= 1.5 * budget + slack
        or steady["dilation"] <= 0.05 * dil_ungov
    ), (
        f"governed steady state did not converge: dilation "
        f"{steady['dilation']:.3f} (budget {budget}, ungoverned {dil_ungov:.2f})"
    )
    assert beta_gov < beta_ungov, "governed beta not below ungoverned beta"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
