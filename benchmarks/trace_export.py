"""Trace-export benchmark — naive per-event exporter vs the streaming engine.

The historical Chrome export built one Python dict per event and handed the
whole list to ``json.dump`` (kept below as ``_export_naive``, the reference
implementation).  The streaming engine (``repro.core.export``) encodes events
in numpy bulk operations, chunk by chunk.  This benchmark writes a synthetic
multi-stream run directory (~2M span events by default), exports it through
both paths, verifies the span content is equivalent, and reports events/s,
output bytes, and the peak Python-allocation footprint of each exporter
(tracemalloc; numpy buffers are traced too) — the naive path peaks O(total
events), the engine O(chunk).

    PYTHONPATH=src python benchmarks/trace_export.py            # full run, asserts >=10x
    PYTHONPATH=src python benchmarks/trace_export.py --smoke    # small, correctness only
"""

from __future__ import annotations

import argparse
import json
import os
import time
import tracemalloc
from typing import Optional

import numpy as np

from repro.core.buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT
from repro.core.export import export_run
from repro.core.substrates.tracing import load_run


def make_synthetic_run(
    run_dir: str,
    n_events: int = 2_000_000,
    n_regions: int = 64,
    n_streams: int = 4,
    seed: int = 0,
) -> str:
    """Materialize a trace run dir with ``n_events`` balanced B/E events.

    Streams are written uncompressed (np.savez) so both exporters pay the
    same negligible load cost and the benchmark isolates export throughput.
    """
    os.makedirs(run_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    regions = [
        {"name": f"pkg.mod_{i % 7}:func_{i}", "module": f"pkg.mod_{i % 7}"}
        for i in range(n_regions)
    ]
    per_stream = n_events // n_streams
    pairs = per_stream // 2
    streams = {}
    epoch_perf = 1_000_000
    for s in range(n_streams):
        tid = 1000 + s
        rids = rng.integers(0, n_regions, pairs).astype(np.int32)
        kind_enter = np.where(rng.random(pairs) < 0.25, EV_C_ENTER, EV_ENTER)
        kind_exit = np.where(kind_enter == EV_C_ENTER, EV_C_EXIT, EV_EXIT)
        kinds = np.empty(pairs * 2, dtype=np.uint8)
        kinds[0::2] = kind_enter
        kinds[1::2] = kind_exit
        region = np.repeat(rids, 2).astype(np.int32)
        t = (
            epoch_perf + np.cumsum(rng.integers(40, 900, pairs * 2))
        ).astype(np.uint64)
        aux = np.zeros(pairs * 2, dtype=np.uint32)
        path = os.path.join(run_dir, f"stream_t{tid}.npz")
        np.savez(path, kind=kinds, region=region, t=t, aux=aux)
        streams[str(tid)] = {"file": os.path.basename(path), "events": pairs * 2}
    defs = {
        "meta": {
            "rank": 0,
            "topology": {"rank": 0, "world_size": 1, "local_rank": 0, "mesh_shape": []},
            "experiment": "bench",
            "epoch_time_ns": 1_700_000_000_000_000_000,
            "epoch_perf_ns": epoch_perf,
        },
        "streams": streams,
        "regions": regions,
    }
    with open(os.path.join(run_dir, "defs.json"), "w") as fh:
        json.dump(defs, fh)
    series_t = (epoch_perf + np.arange(200) * 1_000_000).tolist()
    with open(os.path.join(run_dir, "metrics.json"), "w") as fh:
        json.dump(
            {"series": {"bench.step_ms": [[int(t), float(i % 17)] for i, t in enumerate(series_t)]}},
            fh,
        )
    return run_dir


def _export_naive(run_dir: str, out_path: Optional[str] = None) -> str:
    """Reference exporter: the historical per-event pure-Python path
    (one dict per event, whole trace in memory, single json.dump)."""
    defs, streams = load_run(run_dir)
    regions = defs["regions"]
    pid = defs["meta"].get("rank", 0)
    events = []
    for tid, cols in streams.items():
        kinds, rids, ts = cols["kind"], cols["region"], cols["t"]
        for i in range(len(kinds)):
            k = int(kinds[i])
            if k in (EV_ENTER, EV_C_ENTER):
                ph = "B"
            elif k in (EV_EXIT, EV_C_EXIT):
                ph = "E"
            else:
                continue
            r = regions[int(rids[i])]
            events.append(
                {
                    "name": r["name"],
                    "cat": r["module"],
                    "ph": ph,
                    "ts": int(ts[i]) / 1000.0,  # chrome expects microseconds
                    "pid": pid,
                    "tid": tid,
                }
            )
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    out_path = out_path or os.path.join(run_dir, "trace_naive.json")
    with open(out_path, "w") as fh:
        json.dump(doc, fh)
    return out_path


def _strict_load(path: str):
    def _reject(token):
        raise ValueError(f"non-strict JSON constant {token!r} in {path}")

    with open(path) as fh:
        return json.load(fh, parse_constant=_reject)


def check_equivalence(engine_path: str, naive_path: str) -> int:
    """Spans from both exporters must carry byte-equivalent event content
    (canonical re-serialization; the engine additionally emits metadata and
    counter events, which the naive path never had)."""
    engine = _strict_load(engine_path)["traceEvents"]
    naive = _strict_load(naive_path)["traceEvents"]
    spans = [e for e in engine if e["ph"] in ("B", "E")]
    if len(spans) != len(naive):
        raise AssertionError(f"span count mismatch: {len(spans)} != {len(naive)}")
    for a, b in zip(spans, naive):
        ca = json.dumps(a, sort_keys=True)
        cb = json.dumps(b, sort_keys=True)
        if ca != cb:
            raise AssertionError(f"event content mismatch:\n  engine {ca}\n  naive  {cb}")
    if not any(e["ph"] == "M" for e in engine):
        raise AssertionError("engine output missing metadata events")
    if not any(e["ph"] == "C" for e in engine):
        raise AssertionError("engine output missing counter events")
    return len(spans)


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def _traced_peak(fn, *args) -> int:
    tracemalloc.start()
    try:
        fn(*args)
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    return peak


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--events", type=int, default=2_000_000)
    p.add_argument("--smoke", action="store_true",
                   help="small trace, correctness checks only (CI)")
    p.add_argument("--no-mem", action="store_true", help="skip the tracemalloc pass")
    p.add_argument("--run-dir", default=None)
    p.add_argument("--out", default="benchmarks/artifacts/trace_export.json")
    ns = p.parse_args(argv)

    n_events = 40_000 if ns.smoke else ns.events
    import tempfile

    run_dir = ns.run_dir or tempfile.mkdtemp(prefix="repro-trace-export-")
    print(f"generating synthetic run: {n_events} events -> {run_dir}")
    make_synthetic_run(run_dir, n_events=n_events)

    engine_path = os.path.join(run_dir, "trace.json")
    naive_path = os.path.join(run_dir, "trace_naive.json")

    t_engine = _timed(export_run, run_dir, engine_path)
    t_naive = _timed(_export_naive, run_dir, naive_path)
    n_spans = check_equivalence(engine_path, naive_path)

    engine_eps = n_spans / t_engine
    naive_eps = n_spans / t_naive
    ratio = engine_eps / naive_eps
    print(f"engine : {t_engine:8.3f}s  {engine_eps:12,.0f} events/s")
    print(f"naive  : {t_naive:8.3f}s  {naive_eps:12,.0f} events/s")
    print(f"speedup: {ratio:8.2f}x   ({n_spans} span events, content equivalent)")

    doc = {
        "n_span_events": n_spans,
        "engine_s": t_engine,
        "naive_s": t_naive,
        "engine_events_per_s": engine_eps,
        "naive_events_per_s": naive_eps,
        "speedup": ratio,
        "smoke": ns.smoke,
    }
    if not ns.no_mem:
        peak_engine = _traced_peak(export_run, run_dir, engine_path)
        peak_naive = _traced_peak(_export_naive, run_dir, naive_path)
        doc["peak_bytes_engine"] = peak_engine
        doc["peak_bytes_naive"] = peak_naive
        print(f"peak python allocations: engine {peak_engine / 1e6:,.1f} MB "
              f"vs naive {peak_naive / 1e6:,.1f} MB "
              f"({peak_naive / max(peak_engine, 1):.1f}x)")

    os.makedirs(os.path.dirname(ns.out), exist_ok=True)
    with open(ns.out, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"wrote {ns.out}")

    if not ns.smoke:
        assert ratio >= 10.0, (
            f"streaming engine speedup {ratio:.1f}x below the 10x floor"
        )
        assert doc.get("peak_bytes_engine", 0) <= doc.get("peak_bytes_naive", 1), (
            "engine peak memory exceeds naive peak"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
