"""Benchmark orchestrator — one entry per paper table/figure + framework
perf artifacts.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # quick protocol
    PYTHONPATH=src REPRO_BENCH_FULL=1 python -m benchmarks.run   # 51 reps

Rows:
  overhead_case1/* : paper Table 2 col 1 (Fig 4a) — alpha/beta per instrumenter
  overhead_case2/* : paper Table 2 col 2 (Fig 4b)
  event_buffer/*   : beyond-paper buffer-strategy cost (ns/event -> us)
  beta_inproc/*    : in-process per-call beta per instrumenter
  train_loop/*     : monitoring overhead around a jit train step
  roofline/*       : summary rows from benchmarks/artifacts/roofline.json
                     (produced by `python -m benchmarks.roofline`; cached)
"""

from __future__ import annotations

import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]


def _rows_overhead(full: bool) -> List[Row]:
    from .overhead_case1 import run as run_case

    rows: List[Row] = []
    repeats = 51 if full else 5
    ns1 = [10_000, 200_000, 1_000_000] if not full else [10_000, 100_000, 400_000, 1_000_000]
    ns2 = [10_000, 50_000, 200_000] if not full else [10_000, 50_000, 200_000, 500_000]
    for case, ns in (("case1", ns1), ("case2", ns2)):
        results = run_case(ns, repeats, case=case)
        for r in results:
            rows.append(
                (
                    f"overhead_{case}/{r.instrumenter}",
                    r.beta * 1e6,
                    f"alpha_s={r.alpha:.3f}",
                )
            )
    return rows


def _rows_event_throughput() -> List[Row]:
    from .event_throughput import bench_buffers, bench_instrumenter_beta

    rows: List[Row] = []
    for name, ns_per_ev in bench_buffers(n_events=100_000, repeats=3).items():
        rows.append((f"event_buffer/{name}", ns_per_ev / 1e3, "per-event-append"))
    for name, beta_us in bench_instrumenter_beta(repeats=3).items():
        rows.append((f"beta_inproc/{name}", beta_us, "case2-in-process"))
    return rows


def _rows_train_overhead() -> List[Row]:
    from .train_overhead import run_loop

    rows: List[Row] = []
    base = None
    for inst in ["off", "profile", "monitoring"]:
        r = run_loop(inst, steps=20, repeats=3)
        if inst == "off":
            base = r["per_step_ms"]
        pct = (r["per_step_ms"] / base - 1) * 100 if base else 0.0
        rows.append((f"train_loop/{inst}", r["per_step_ms"] * 1e3, f"overhead_pct={pct:.1f}"))
    return rows


def _rows_roofline() -> List[Row]:
    path = os.path.join("benchmarks", "artifacts", "roofline.json")
    rows: List[Row] = []
    if not os.path.exists(path):
        return [("roofline/missing", 0.0, "run `python -m benchmarks.roofline` first")]
    with open(path) as fh:
        recs = json.load(fh)
    for r in recs:
        if r.get("status") != "ok":
            continue
        rows.append(
            (
                f"roofline/{r['arch']}/{r['shape']}",
                r["step_lower_bound_s"] * 1e6,
                f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}",
            )
        )
    return rows


def main() -> None:
    full = os.environ.get("REPRO_BENCH_FULL") == "1"
    rows: List[Row] = []
    rows += _rows_overhead(full)
    rows += _rows_event_throughput()
    rows += _rows_train_overhead()
    rows += _rows_roofline()
    print()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.4f},{derived}")


if __name__ == "__main__":
    main()
