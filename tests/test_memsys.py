"""Memory monitoring subsystem (repro.core.memsys) + shared replay tests."""

import gc
import json
import os

import pytest

import repro.core as rmon
from repro.core.analysis import (
    MissingArtifact,
    diff_memory,
    load_memory_doc,
    memory_hotspots,
    render_memory,
    render_memory_diff,
    render_merge_summary,
)
from repro.core.buffer import (
    EV_C_ENTER,
    EV_C_EXIT,
    EV_ENTER,
    EV_EXIT,
    columns_from_events,
)
from repro.core.measurement import MeasurementConfig
from repro.core.memsys import (
    GcWatcher,
    HeapCollector,
    SystemPoller,
    open_fd_count,
    rss_bytes,
)
from repro.core.merge import merge_runs
from repro.core.replay import ReplayState, replay, unwind


# -- sysinfo probes -----------------------------------------------------------

def test_rss_and_fd_probes():
    rss = rss_bytes()
    assert rss > 1 << 20  # a live CPython process is at least a megabyte
    fds = open_fd_count()
    assert fds is None or fds > 0


# -- shared replay ------------------------------------------------------------

def test_replay_balanced_stream_tracks_live_region():
    state = ReplayState()
    replay(state, [EV_ENTER, EV_ENTER], [3, 5], [10, 20])
    assert state.live_region() == 5
    assert state.live_stack() == [3, 5]
    replay(state, [EV_EXIT, EV_EXIT], [5, 3], [30, 40])
    assert not state.stack
    assert state.live_region() == -1
    assert state.orphan_exits == 0 and state.mismatched_exits == 0


def test_replay_close_callback_durations():
    closed = []
    state = ReplayState()
    replay(
        state,
        [EV_ENTER, EV_ENTER, EV_EXIT, EV_EXIT],
        [1, 2, 2, 1],
        [0, 10, 30, 100],
        on_close=lambda rid, et, xt, child: closed.append((rid, xt - et, child)),
    )
    # inner: 20ns with no children; outer: 100ns with 20ns of child time
    assert closed == [(2, 20, 0), (1, 100, 20)]


def test_replay_unwind_closes_open_frames():
    state = ReplayState()
    closed = []
    replay(state, [EV_ENTER, EV_ENTER], [1, 2], [0, 10])
    unwind(state, on_close=lambda rid, et, xt, child: closed.append((rid, xt - et)))
    assert not state.stack
    assert closed == [(2, 0), (1, 10)]  # closed at last seen timestamp (10)


# -- profiling substrate bookkeeping (satellite: orphan / mismatched exits) ---

def test_profiling_orphan_exit_bookkeeping():
    from repro.core.substrates.profiling import ProfilingSubstrate

    sub = ProfilingSubstrate()
    sub.open("/tmp", {})
    # exit with no enter at all, then a normal pair
    sub.on_flush(0, columns_from_events([
        (EV_EXIT, 7, 5, 0),
        (EV_ENTER, 1, 10, 0),
        (EV_EXIT, 1, 30, 0),
    ]))
    state = sub.threads[0]
    assert state.orphan_exits == 1
    assert state.mismatched_exits == 0
    assert not state.stack
    node = state.root.children[1]
    assert node.visits == 1 and node.incl_ns == 20


def test_profiling_interleaved_c_python_exit_closes_inner_frame():
    from repro.core.substrates.profiling import ProfilingSubstrate

    sub = ProfilingSubstrate()
    sub.open("/tmp", {})
    # Python enter -> C enter, then the Python EXIT arrives while the C
    # frame is still open (its c_return was lost): the inner C frame must
    # be closed implicitly, not counted as a mismatch.
    sub.on_flush(0, columns_from_events([
        (EV_ENTER, 1, 0, 0),
        (EV_C_ENTER, 2, 10, 0),
        (EV_EXIT, 1, 50, 0),
    ]))
    state = sub.threads[0]
    assert state.orphan_exits == 0
    assert state.mismatched_exits == 0
    assert not state.stack
    outer = state.root.children[1]
    inner = outer.children[2]
    assert inner.visits == 1 and inner.incl_ns == 40  # closed at the outer exit
    assert outer.visits == 1 and outer.incl_ns == 50
    assert outer.excl_ns == 10  # the implicit close still feeds child time


def test_profiling_mismatched_exit_counted_and_stack_recovers():
    from repro.core.substrates.profiling import ProfilingSubstrate

    sub = ProfilingSubstrate()
    sub.open("/tmp", {})
    # Exit names a region that is neither the open frame nor its parent:
    # counted as mismatched, and the open frame is popped anyway so the
    # stack does not wedge.
    sub.on_flush(0, columns_from_events([
        (EV_ENTER, 1, 0, 0),
        (EV_ENTER, 2, 10, 0),
        (EV_C_EXIT, 9, 20, 0),
        (EV_EXIT, 1, 40, 0),
    ]))
    state = sub.threads[0]
    assert state.mismatched_exits == 1
    assert state.orphan_exits == 0
    assert not state.stack
    assert state.root.children[1].visits == 1


# -- heap collector -----------------------------------------------------------

def test_heap_collector_attributes_to_batch_regions():
    collector = HeapCollector()
    collector.open()
    try:
        keep = bytearray(8 << 20)  # 8 MB allocated while region 0 is "live"
        cols = columns_from_events([(EV_ENTER, 0, 0, 0), (EV_EXIT, 0, 1000, 0)])
        collector.on_flush(0, cols)
    finally:
        collector.close()
    table = collector.region_table([{"module": "m", "name": "alloc"}])
    row = table["regions"]["m:alloc"]
    assert row["alloc_bytes"] >= 8 << 20
    assert row["alloc_blocks"] >= 1
    assert keep  # keep the buffer alive through the flush
    threads = collector.thread_table()
    assert threads["0"]["flushes"] == 1
    assert threads["0"]["peak_heap_bytes"] >= 8 << 20


def test_heap_collector_clips_weights_to_batch_span():
    from repro.core.buffer import EV_LINE

    collector = HeapCollector()
    collector.open()
    try:
        # Batch 1: `outer` (rid 0) opens and stays open; the LINE event
        # advances the thread clock so the batch span ends at t=990.
        collector.on_flush(0, columns_from_events([
            (EV_ENTER, 0, 0, 0), (EV_LINE, 0, 990, 0),
        ]))
        keep = bytearray(8 << 20)  # the delta observed by batch 2's flush
        # Batch 2: `outer` closes 10ns in, then `hot` (rid 1) runs for the
        # remaining 8990ns.  outer's lifetime (1000ns) must NOT be its
        # weight — only its 10ns inside this batch.
        collector.on_flush(0, columns_from_events([
            (EV_EXIT, 0, 1000, 0),
            (EV_ENTER, 1, 1010, 0), (EV_EXIT, 1, 10000, 0),
        ]))
    finally:
        collector.close()
    table = collector.region_table(
        [{"module": "m", "name": "outer"}, {"module": "m", "name": "hot"}]
    )["regions"]
    assert keep
    assert table["m:hot"]["alloc_bytes"] >= int((8 << 20) * 0.9)
    assert table["m:outer"]["alloc_bytes"] < table["m:hot"]["alloc_bytes"] // 100


def test_heap_collector_drops_stale_child_baselines():
    # An inherited frame closes early in the batch; a new frame then
    # reoccupies its stack depth.  The new frame must start from a zero
    # child-time baseline, not the inherited frame's snapshot.
    collector = HeapCollector()
    collector.open()
    try:
        # Batch 1: enter A(t=0), enter B(t=10), exit B(t=110) -> A carries
        # child_ns=100 into the next batch.
        collector.on_flush(0, columns_from_events([
            (EV_ENTER, 0, 0, 0), (EV_ENTER, 1, 10, 0), (EV_EXIT, 1, 110, 0),
        ]))
        keep = bytearray(4 << 20)
        # Batch 2: exit A(t=120) (10ns in-batch), then C runs 20ns at A's
        # old depth.  C's weight must be 20, not 120 (= 20 - (0 - 100)).
        collector.on_flush(0, columns_from_events([
            (EV_EXIT, 0, 120, 0),
            (EV_ENTER, 2, 130, 0), (EV_EXIT, 2, 150, 0),
        ]))
    finally:
        collector.close()
    table = collector.region_table(
        [{"module": "m", "name": "A"}, {"module": "m", "name": "B"},
         {"module": "m", "name": "C"}]
    )["regions"]
    assert keep
    a = table.get("m:A", {}).get("alloc_bytes", 0)
    c = table.get("m:C", {}).get("alloc_bytes", 0)
    # weights in batch 2: A=10, C=20 -> C gets ~2/3 of the delta, not ~92%
    assert 0 < c < (4 << 20)
    assert abs(c - 2 * a) < (4 << 20) * 0.2


def test_heap_collector_topn_cut():
    collector = HeapCollector()
    collector.open()
    try:
        for rid in range(4):
            collector.on_flush(0, columns_from_events([
                (EV_ENTER, rid, rid * 100, 0), (EV_EXIT, rid, rid * 100 + 50, 0),
            ]))
    finally:
        collector.close()
    regions = [{"module": "m", "name": f"r{i}"} for i in range(4)]
    table = collector.region_table(regions, topn=2)
    assert len(table["regions"]) == 2
    assert table["dropped_regions"] >= 1


# -- poller / gc watcher ------------------------------------------------------

def test_system_poller_samples_and_decimates():
    poller = SystemPoller(period_s=0.01, max_samples=16)
    for _ in range(20):
        poller.sample()
    assert poller.peak_rss > 0
    assert poller.n_samples == 20
    assert len(poller.rss) < 20  # decimated at max_samples
    assert poller.period_s > 0.01


def test_gc_watcher_records_pauses():
    watcher = GcWatcher()
    watcher.install()
    try:
        junk = [[i] for i in range(1000)]
        del junk
        gc.collect()
    finally:
        watcher.uninstall()
    assert watcher.collections >= 1
    assert watcher.pause_ns_total >= 0
    assert watcher.per_generation
    assert watcher._callback not in gc.callbacks


# -- memory substrate end to end ----------------------------------------------

def _memory_run(tmp_path, name, n_alloc, world=1, rank=0):
    d = str(tmp_path / name)
    rmon.init(
        instrumenter="profile",
        run_dir=d,
        experiment="mem",
        substrates=("profiling", "tracing", "metrics", "memory"),
        flush_threshold=256,
        memory_period=0.01,
        topology=rmon.ProcessTopology(rank=rank, world_size=world),
    )
    keep = []
    with rmon.region("alloc_phase"):
        for _ in range(n_alloc):
            keep.append(bytearray(64 << 10))
    rmon.metric("steps", 1.0)
    rmon.finalize()
    return d


def test_memory_substrate_end_to_end(tmp_path):
    out = _memory_run(tmp_path, "m1", 100)
    doc = load_memory_doc(out)
    # per-region attribution with real bytes
    regions = doc["heap"]["regions"]
    assert regions
    assert sum(r["alloc_bytes"] for r in regions.values()) >= 100 * (64 << 10) // 2
    # RSS timeline + peak
    assert doc["rss"]["peak_bytes"] > 1 << 20
    assert doc["series"]["mem.rss_mb"]
    assert doc["rss"]["source"] in ("statm", "getrusage")
    # per-thread peaks and replay bookkeeping
    threads = doc["heap"]["threads"]
    assert threads and all("peak_heap_bytes" in t for t in threads.values())
    # gc section present (collections may be zero on a quiet run)
    assert "collections" in doc["gc"]
    # hotspot helpers
    top = memory_hotspots(out, top=5)
    assert top and top[0][1]["alloc_bytes"] > 0
    text = render_memory(doc)
    assert "alloc_mb" in text and "rss:" in text


def test_memory_counter_tracks_in_chrome_export(tmp_path):
    out = _memory_run(tmp_path, "m2", 50)
    with open(os.path.join(out, "trace.json")) as fh:
        doc = json.load(fh)
    counters = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
    assert "mem.rss_mb" in counters
    assert "mem.heap_mb" in counters
    assert "steps" in counters  # metrics.json series still exported


def test_memory_env_roundtrip():
    env = {
        "REPRO_MONITOR_MEMORY": "1",
        "REPRO_MONITOR_MEMORY_PERIOD": "0.5",
        "REPRO_MONITOR_MEMORY_TOPN": "7",
    }
    cfg = MeasurementConfig.from_env(env)
    assert "memory" in cfg.substrates
    assert cfg.memory_period == 0.5
    assert cfg.memory_topn == 7
    # round trip: to_env -> from_env preserves the memory settings
    cfg2 = MeasurementConfig.from_env(cfg.to_env())
    assert "memory" in cfg2.substrates
    assert cfg2.substrates.count("memory") == 1  # no duplicate append
    assert cfg2.memory_period == 0.5 and cfg2.memory_topn == 7
    # disabled by default
    assert "memory" not in MeasurementConfig.from_env({}).substrates


def test_memory_substrate_constructed_with_config_knobs(tmp_path):
    m = rmon.init(
        instrumenter="none",
        run_dir=str(tmp_path / "knobs"),
        substrates=("memory",),
        memory_period=0.03,
        memory_topn=3,
    )
    sub = m.substrate("memory")
    assert sub.period == 0.03 and sub.topn == 3
    rmon.finalize()


def test_merge_reports_cross_rank_memory(tmp_path):
    a = _memory_run(tmp_path, "rank0", 20, world=2, rank=0)
    b = _memory_run(tmp_path, "rank1", 300, world=2, rank=1)
    out = str(tmp_path / "merged.json")
    summary = merge_runs([a, b], out)
    mem = summary["memory"]
    assert len(mem["ranks"]) == 2
    peak = mem["peak_rss"]
    assert peak["max_bytes"] >= peak["min_bytes"] > 0
    assert peak["imbalance"] is None or peak["imbalance"] >= 1.0
    assert mem["ranks"][0]["top_regions"]
    text = render_merge_summary(summary)
    assert "imbalance" in text and "peak RSS" in text


def test_merge_without_memory_artifacts_has_no_section(tmp_path):
    d = str(tmp_path / "plain")
    rmon.init(instrumenter="none", run_dir=d, substrates=("tracing",))
    with rmon.region("r"):
        pass
    rmon.finalize()
    summary = merge_runs([d], str(tmp_path / "m.json"))
    assert "memory" not in summary


# -- analysis CLI -------------------------------------------------------------

def test_analysis_memory_cli(tmp_path, capsys):
    from repro.core.analysis import main

    a = _memory_run(tmp_path, "cli-a", 20)
    b = _memory_run(tmp_path, "cli-b", 200)
    assert main(["memory", a, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "region" in out and "rss:" in out
    assert main(["memory-diff", a, b, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "delta_mb" in out


def test_analysis_memory_diff_rows(tmp_path):
    a = _memory_run(tmp_path, "d-a", 20)
    b = _memory_run(tmp_path, "d-b", 200)
    rows = diff_memory(a, b)
    assert rows
    total_delta = sum(r["delta_bytes"] for r in rows)
    assert total_delta > 0  # B allocates 10x more
    assert render_memory_diff(rows)


def test_analysis_top_missing_profile_actionable_error(tmp_path, capsys):
    from repro.core.analysis import main

    d = str(tmp_path / "tracing-only")
    os.makedirs(d)
    rc = main(["top", d])
    assert rc == 2
    err = capsys.readouterr().err
    assert "profile.json" in err and "profiling" in err
    # memory subcommand gets the same actionable treatment
    rc = main(["memory", d])
    assert rc == 2
    assert "memory.json" in capsys.readouterr().err


def test_analysis_diff_min_ns_flag(tmp_path, capsys):
    from repro.core.analysis import main

    a = _memory_run(tmp_path, "mn-a", 5)
    b = _memory_run(tmp_path, "mn-b", 5)
    # an absurdly high floor filters every region out, leaving the header
    assert main(["diff", a, b, "--min-ns", str(10**15)]) == 0
    out = capsys.readouterr().out.strip().splitlines()
    assert len(out) == 1 and "region" in out[0]


def test_load_memory_doc_missing_raises(tmp_path):
    with pytest.raises(MissingArtifact):
        load_memory_doc(str(tmp_path))
