"""Additional coverage: bootstrap env composition, merge CLI, region reuse,
compressed-DP numerics edge cases, sharding batch rules."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as rmon
from repro.core.bootstrap import build_parser, compose_environment
from repro.core.measurement import ENV_PREFIX, MeasurementConfig


def test_compose_environment_roundtrip():
    ns = build_parser().parse_args(
        ["--instrumenter=sampling", "--sampling-period=13", "--filter=exclude:numpy.*",
         "--xla-flags=--xla_foo=1", "--mpp=jax", "app.py", "--", "--x"]
    )
    env = compose_environment(ns, {"XLA_FLAGS": "--xla_bar=2", "REPRO_MONITOR_RANK": "3"})
    assert env[ENV_PREFIX + "INSTRUMENTER"] == "sampling"
    assert env[ENV_PREFIX + "SAMPLING_PERIOD"] == "13"
    assert env[ENV_PREFIX + "FILTER"] == "exclude:numpy.*"
    assert env[ENV_PREFIX + "RANK"] == "3"
    assert env[ENV_PREFIX + "MPP"] == "jax"
    assert env["XLA_FLAGS"] == "--xla_bar=2 --xla_foo=1"  # merged, not clobbered
    # config reconstructs identically from that env
    cfg = MeasurementConfig.from_env(env)
    assert cfg.instrumenter == "sampling" and cfg.sampling_period == 13 and cfg.rank == 3


def test_measurement_config_env_roundtrip():
    cfg = MeasurementConfig(instrumenter="trace", substrates=("metrics",),
                            flush_threshold=123, buffer_strategy="numpy", rank=7)
    cfg2 = MeasurementConfig.from_env(cfg.to_env())
    assert cfg2.instrumenter == "trace"
    assert cfg2.substrates == ("metrics",)
    assert cfg2.flush_threshold == 123
    assert cfg2.buffer_strategy == "numpy"
    assert cfg2.rank == 7


def test_merge_cli_main(tmp_path):
    # two tiny runs, then the module-level CLI
    for rank in (0, 1):
        rmon.init(instrumenter="profile", run_dir=str(tmp_path / f"m-r{rank}"),
                  experiment="m", rank=rank)

        def work():
            return rank

        work()
        rmon.finalize()
    from repro.core.merge import main

    rc = main([str(tmp_path), "--experiment", "m"])
    assert rc == 0
    assert os.path.exists(tmp_path / "merged_trace.json")


def test_region_context_is_reusable():
    rmon.init(instrumenter="none", run_dir=None, out_dir="/tmp/repro-ctx",
              substrates=("profiling",), experiment="ctx")
    try:
        m = rmon.active()
        ctx = m.region("loop_phase")
        for _ in range(5):
            with ctx:
                pass
    finally:
        out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    assert prof["flat"]["user:loop_phase"]["visits"] == 5


def test_monitoring_api_noops_when_inactive():
    assert rmon.active() is None
    with rmon.region("nothing"):
        rmon.metric("x", 1.0)
    # decorator path
    @rmon.instrument
    def fn(x):
        return x + 1

    assert fn(1) == 2
    assert rmon.finalize() is None


def test_int8_quantize_extremes():
    from repro.dist.compression import int8_dequantize, int8_quantize

    # zeros stay zeros, huge values survive with relative precision
    q, s = int8_quantize(jnp.zeros((16,)))
    assert float(jnp.max(jnp.abs(int8_dequantize(q, s)))) == 0.0
    g = jnp.array([1e6, -1e6, 1.0])
    q, s = int8_quantize(g)
    back = int8_dequantize(q, s)
    np.testing.assert_allclose(np.asarray(back[:2]), np.asarray(g[:2]), rtol=1e-2)


def test_batch_spec_non_divisible_batch_falls_back():
    from repro.dist import sharding as shd

    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    # batch of 1 with a >1 mesh axis elsewhere: rule must not shard
    spec = shd.batch_spec(mesh, (1, 128))
    assert spec[0] in (None, "data")  # data axis size 1 -> trivially fine

    # divisibility guard on a fake 2-wide axis
    mesh2 = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec2 = shd.batch_spec(mesh2, (3, 8))
    assert spec2[0] in (None, "data")


def test_adamw_schedule_and_clip():
    from repro.optim import adamw

    sched = adamw.cosine_schedule(warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    cfg = adamw.AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    big = {"w": jnp.full((4,), 100.0)}
    new_params, state, stats = adamw.update(cfg, big, state, params)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)
    # clipped: effective grad norm 1 -> adam step magnitude ~1 per coord
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 1.5
