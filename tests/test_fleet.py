"""Fleet-scale regression service: population verdicts, stats-kernel
properties, byte-determinism, and the CI perf gate.

The synthetic populations come from the checked-in fixture driver
(tests/fixtures/fleet/generate.py) over repro.core.fleet.synth — the same
generator ``analysis fleet --smoke`` uses, so the contract asserted here
is the contract the smoke self-check enforces in CI.
"""

import importlib.util
import json
import math
import os
import random
import shutil

import pytest

from repro.core.fleet import (
    ARTIFACT,
    EFFECT_LARGE,
    EFFECT_MEDIUM,
    append_snapshot,
    build_fleet_summary,
    cliffs_delta,
    compare_windows,
    gate_summary,
    ingest,
    load_fleet_summary,
    mann_whitney,
    metric_direction,
    save_fleet_summary,
    sign_test_p,
)
from repro.core.fleet.stats import finite, mad, median, slope_per_second
from repro.core.schema import MissingArtifact

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

_GEN_PATH = os.path.join(os.path.dirname(__file__), "fixtures", "fleet", "generate.py")


def _load_generator():
    spec = importlib.util.spec_from_file_location("fleet_fixture_generate", _GEN_PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


generate = _load_generator()


@pytest.fixture(scope="module")
def populations(tmp_path_factory):
    """All four canonical populations plus their analyzed summaries."""
    out = str(tmp_path_factory.mktemp("fleet-pops"))
    roots = generate.materialize(out)
    docs = {kind: build_fleet_summary([root]) for kind, root in roots.items()}
    return roots, docs


# -- fixture generator --------------------------------------------------------


def test_generator_writes_real_schemas_deterministically(tmp_path, capsys):
    assert generate.main([str(tmp_path / "a"), "--kind", "stable", "--runs", "3"]) == 0
    assert "stable:" in capsys.readouterr().out
    root = tmp_path / "a" / "stable"
    runs = sorted(os.listdir(root))
    assert len(runs) == 3
    for name in ("meta.json", "profile.json", "memory.json"):
        doc = json.loads((root / runs[0] / name).read_text())
        assert doc["report_schema_version"] >= 1, name
    profile = json.loads((root / runs[0] / "profile.json").read_text())
    assert set(profile["flat"]) == set(generate.synth.REGIONS)
    memory = json.loads((root / runs[0] / "memory.json").read_text())
    assert set(memory["heap"]["regions"]) == set(generate.synth.ALLOC)
    assert memory["series"]["mem.rss_mb"]

    # Seeded: a regeneration is byte-identical, a different seed is not.
    generate.materialize(str(tmp_path / "b"), kind="stable", runs=3)
    generate.materialize(str(tmp_path / "c"), kind="stable", runs=3, seed=7)
    a = (root / runs[0] / "profile.json").read_bytes()
    assert (tmp_path / "b" / "stable" / runs[0] / "profile.json").read_bytes() == a
    assert (tmp_path / "c" / "stable" / runs[0] / "profile.json").read_bytes() != a


# -- population verdicts ------------------------------------------------------


def test_stable_population_is_clean(populations):
    _, docs = populations
    doc = docs["stable"]
    assert doc["verdict"] == "ok"
    assert doc["findings_total"] == 0
    assert doc["time"]["findings"] == []
    assert doc["alloc"]["findings"] == []
    assert doc["leaks"]["region_leaks"] == 0
    assert all(sig["verdict"] != "leak" for sig in doc["leaks"]["process"].values())


def test_step_population_flags_the_stepped_region(populations):
    _, docs = populations
    doc = docs["step"]
    regressions = [f for f in doc["time"]["findings"] if f["verdict"] == "regression"]
    assert regressions, doc["time"]
    top = regressions[0]
    assert top["region"] == "app:transform"
    assert top["effect_size"] >= EFFECT_LARGE  # +60% step: stochastic dominance
    assert top["method"] == "mann-whitney"
    assert top["p"] is not None and top["p"] <= 0.05
    assert top["candidate"]["median"] > top["baseline"]["median"]
    assert "regressed" in doc["verdict"]
    # The flagged region's sparkline series rides along for the report.
    assert "app:transform" in doc["series"]["time"]


def test_drift_population_flags_the_drifting_region(populations):
    _, docs = populations
    doc = docs["drift"]
    regressions = [f for f in doc["time"]["findings"] if f["verdict"] == "regression"]
    assert regressions and regressions[0]["region"] == "app:decode", doc["time"]
    assert abs(regressions[0]["effect_size"]) >= EFFECT_MEDIUM
    # 3.5%/run compounding: the candidate window is unambiguously above.
    assert regressions[0]["rel_change"] > 0.05


def test_leak_population_produces_region_and_process_verdicts(populations):
    _, docs = populations
    doc = docs["leak"]
    leak_rows = [r for r in doc["leaks"]["regions"] if r["verdict"] == "leak"]
    assert leak_rows and leak_rows[0]["region"] == "app:cache_fill", doc["leaks"]
    row = leak_rows[0]
    assert row["reclaim_rate"] < 0.5
    assert row["p"] <= 0.05
    assert row["net_median_bytes"] > 0
    # Whole-process heap timelines climb in every run -> process verdict.
    assert doc["leaks"]["process"]["heap"]["verdict"] == "leak"
    assert doc["leaks"]["process"]["heap"]["median_slope_bytes_s"] > 0
    assert "leaking" in doc["verdict"]
    # The healthy allocators must not be dragged in.
    assert all(r["verdict"] != "leak" for r in doc["leaks"]["regions"]
               if r["region"] != "app:cache_fill")


def test_ingest_dedups_exact_duplicate_runs(populations, tmp_path):
    roots, _ = populations
    root = tmp_path / "dup"
    shutil.copytree(roots["stable"], root)
    runs, dropped = ingest([str(root)])
    n = len(runs)
    assert dropped == []
    # A re-discovered copy of an existing run (same experiment/rank/epoch)
    # must be dropped, not double-counted.
    src = os.path.join(str(root), sorted(os.listdir(root))[0])
    shutil.copytree(src, os.path.join(str(root), "zz-copy"))
    runs2, dropped2 = ingest([str(root)])
    assert len(runs2) == n
    assert len(dropped2) == 1 and "zz-copy" in dropped2[0]["run_dir"]


# -- determinism --------------------------------------------------------------


def test_summary_bytes_independent_of_ingestion_order(populations, tmp_path):
    roots, _ = populations
    run_dirs = sorted(
        os.path.join(roots["leak"], d) for d in os.listdir(roots["leak"])
    )
    rng = random.Random(42)
    paths = []
    for i in range(3):
        shuffled = list(run_dirs)
        rng.shuffle(shuffled)
        doc = build_fleet_summary(shuffled)
        paths.append(save_fleet_summary(doc, str(tmp_path / f"s{i}.json")))
    blobs = [open(p, "rb").read() for p in paths]
    assert blobs[0] == blobs[1] == blobs[2]
    # Repeat invocation on the same order is also byte-identical (no
    # wall-clock, pids, or dict-order effects in the artifact).
    again = save_fleet_summary(build_fleet_summary(run_dirs), str(tmp_path / "again.json"))
    assert open(again, "rb").read() == blobs[0]


def test_save_load_round_trip_and_error_contract(populations, tmp_path):
    _, docs = populations
    out_dir = tmp_path / "out"
    path = save_fleet_summary(docs["stable"], str(out_dir) + os.sep)
    assert os.path.basename(path) == ARTIFACT
    assert load_fleet_summary(str(out_dir)) == docs["stable"]  # dir form
    assert load_fleet_summary(path) == docs["stable"]
    with pytest.raises(MissingArtifact):
        load_fleet_summary(str(tmp_path / "nope.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(MissingArtifact):
        load_fleet_summary(str(bad))
    with pytest.raises(MissingArtifact):
        ingest([str(tmp_path / "no-such-root")])
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(MissingArtifact):
        ingest([str(empty)])


# -- statistics kernel: properties --------------------------------------------

_DEGENERATE = [
    [],
    [0.0],
    [5.0],
    [float("nan")],
    [float("inf"), float("-inf")],
    [float("nan"), 1.0, float("inf")],
    [3.0] * 10,
    [0.0] * 7,
    [1e308, -1e308, 1e308],
    [1e-320, 0.0, -1e-320],
    list(range(5)),
]


def _assert_kernel_invariants(a, b):
    d = cliffs_delta(a, b)
    assert -1.0 <= d <= 1.0 and math.isfinite(d)
    assert d == -cliffs_delta(b, a)  # exact antisymmetry
    _, p = mann_whitney(a, b)
    assert 0.0 <= p <= 1.0 and math.isfinite(p)
    _, p_swap = mann_whitney(b, a)
    assert abs(p - p_swap) < 1e-12  # two-sided: symmetric under swap
    for hib in (True, False):
        out = compare_windows(b, a, higher_is_worse=hib)
        assert out["verdict"] in ("regression", "improvement", "stable", "insufficient")
        json.dumps(out, allow_nan=False)  # JSON-ready and NaN/inf-free throughout


def test_stats_kernel_survives_degenerate_inputs():
    """Every kernel function accepts empty / constant / single-element /
    non-finite inputs without raising and never emits NaN or inf."""
    for a in _DEGENERATE:
        assert all(math.isfinite(v) for v in finite(a))
        assert math.isfinite(median(a))
        assert math.isfinite(mad(a))
        for b in _DEGENERATE:
            _assert_kernel_invariants(a, b)
    for k, n in ((0, 0), (0, 5), (5, 5), (7, 5), (-3, 5), (3, 1000)):
        p = sign_test_p(k, n)
        assert 0.0 <= p <= 1.0
    assert slope_per_second([]) == 0.0
    assert slope_per_second([[0, 1.0]]) == 0.0
    assert slope_per_second([[10**9, 2.0], [10**9, 9.0]]) == 0.0  # one distinct t
    assert slope_per_second([[0, 0.0], [10**9, 3.0]]) == pytest.approx(3.0)


def test_stats_kernel_manual_fuzz():
    """Seeded random battery — the always-on fallback for environments
    without hypothesis (the @given generalisation below runs when it is
    installed, mirroring test_property_core.py)."""
    rng = random.Random(20260808)
    specials = [float("nan"), float("inf"), float("-inf"), 0.0, -0.0, 1e300, -1e300]
    for _ in range(200):
        def window():
            n = rng.randrange(0, 12)
            return [
                rng.choice(specials) if rng.random() < 0.15
                else rng.gauss(rng.choice([0.0, 100.0]), 10.0)
                for _ in range(n)
            ]
        _assert_kernel_invariants(window(), window())


def test_compare_windows_detects_injected_shift():
    rng = random.Random(7)
    base = [rng.gauss(100.0, 4.0) for _ in range(20)]
    cand = [rng.gauss(160.0, 4.0) for _ in range(8)]
    out = compare_windows(base, cand)
    assert out["verdict"] == "regression"
    assert out["effect_size"] >= EFFECT_LARGE
    assert out["confidence"] in ("medium", "high")
    # Swapping windows turns the same shift into an improvement...
    assert compare_windows(cand, base)["verdict"] == "improvement"
    # ...and flipping the metric direction does too.
    assert compare_windows(base, cand, higher_is_worse=False)["verdict"] == "improvement"
    # A sub-threshold nudge stays stable (min_rel floor).
    near = [v * 1.01 for v in base]
    assert compare_windows(base, near, min_rel=0.05)["verdict"] == "stable"


def test_compare_windows_mad_fallback_for_single_candidate():
    base = [10.0, 10.1, 9.9, 10.05, 10.02, 9.95]
    out = compare_windows(base, [20.0])
    assert out["method"] == "mad-outlier"
    assert out["verdict"] == "regression"
    assert out["p"] is None and out["confidence"] == "heuristic"
    assert out["mad_z"] > 3.0
    assert compare_windows(base, [10.03])["verdict"] == "stable"


if HAVE_HYPOTHESIS:
    finite_or_not = st.floats(allow_nan=True, allow_infinity=True, width=64)
    windows = st.lists(finite_or_not, min_size=0, max_size=20)

    @given(windows, windows)
    @settings(max_examples=120, deadline=None)
    def test_kernel_properties_hypothesis(a, b):
        _assert_kernel_invariants(a, b)

    @given(
        st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=4, max_size=20),
        st.floats(min_value=1.0, max_value=1e6),
    )
    @settings(max_examples=80, deadline=None)
    def test_cliffs_delta_detects_dominant_shift_hypothesis(base, shift):
        # Shift everything above the baseline's max: full stochastic
        # dominance, so delta must be exactly +1.
        cand = [max(base) + shift + i for i in range(3)]
        assert cliffs_delta(cand, base) == 1.0
else:  # keep the skip visible/explained in -rs output
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_kernel_properties_hypothesis():
        pass


# -- CI perf gate -------------------------------------------------------------


def _write_artifact(path, beta_us, per_s, extra=None):
    doc = {"beta_us": beta_us, "records_per_s": per_s, "sizes": [1, 2, 3],
           "report_schema_version": 1}
    doc.update(extra or {})
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh)


def _seed_trajectory(traj, n, beta=10.0, per_s=5000.0, jitter=0.01):
    rng = random.Random(99)
    for i in range(n):
        _write_artifact(
            os.path.join(traj, f"{i:05d}", "bench.json"),
            beta * rng.gauss(1.0, jitter),
            per_s * rng.gauss(1.0, jitter),
        )


def test_metric_direction_classification():
    assert metric_direction("bench.beta_us") == 1
    assert metric_direction("agent.publish_p50_us") == 1
    assert metric_direction("bench.records_per_s") == -1  # throughput, not a _s timing
    assert metric_direction("merge.wall_s") == 1  # bare _s leaf is a timing
    assert metric_direction("bench.sizes") == 0
    assert metric_direction("config.world") == 0


def test_gate_seeds_then_passes_then_catches_regression(tmp_path):
    traj = str(tmp_path / "traj")
    _seed_trajectory(traj, 2)
    doc = gate_summary(traj)
    assert doc["verdict"] == "seeding"  # baseline shorter than min_baseline
    assert doc["findings"] == []

    _seed_trajectory(traj, 6)  # overwrite + extend to 6 healthy snapshots
    doc = gate_summary(traj)
    assert doc["verdict"] == "ok"
    assert doc["metrics_watched"] >= 2
    assert doc["findings_total"] == 0

    # A candidate snapshot with 2x beta: the single-sample MAD path fires.
    _write_artifact(os.path.join(traj, "00006", "bench.json"), 20.0, 5000.0)
    doc = gate_summary(traj)
    assert doc["verdict"] == "regressed"
    metrics = [f["metric"] for f in doc["findings"] if f["verdict"] == "regression"]
    assert metrics == ["bench.beta_us"]
    top = doc["findings"][0]
    assert top["method"] == "mad-outlier" and top["direction"] == 1
    assert doc["series"]["bench.beta_us"][-1] == 20.0


def test_gate_throughput_drop_and_improvement_directions(tmp_path):
    traj = str(tmp_path / "traj")
    _seed_trajectory(traj, 6)
    # Throughput halves -> regression even though the value went *down*.
    _write_artifact(os.path.join(traj, "00006", "bench.json"), 10.0, 2500.0)
    doc = gate_summary(traj)
    assert doc["verdict"] == "regressed"
    assert [f["metric"] for f in doc["findings"]
            if f["verdict"] == "regression"] == ["bench.records_per_s"]
    assert doc["findings"][0]["direction"] == -1

    # beta_us halves -> an improvement finding, but the gate stays green.
    _write_artifact(os.path.join(traj, "00006", "bench.json"), 5.0, 5000.0)
    doc = gate_summary(traj)
    assert doc["verdict"] == "ok"
    assert doc["findings_total"] == 0
    assert any(f["verdict"] == "improvement" for f in doc["findings"])


def test_append_snapshot_numbering_labels_and_errors(tmp_path):
    traj = str(tmp_path / "traj")
    src = tmp_path / "artifacts"
    src.mkdir()
    with pytest.raises(MissingArtifact):
        append_snapshot(traj, str(src))  # no *.json yet
    _write_artifact(str(src / "bench.json"), 10.0, 5000.0)
    assert append_snapshot(traj, str(src)) == "00000"
    assert append_snapshot(traj, str(src), label="abc1234") == "00001-abc1234"
    # Labels are sanitized into the [A-Za-z0-9_.-] alphabet.
    assert append_snapshot(traj, str(src), label="pr #7/x") == "00002-pr--7-x"
    assert os.path.exists(os.path.join(traj, "00002-pr--7-x", "bench.json"))
    # Stray entries don't confuse the numbering; corrupt snapshots fail loud.
    os.makedirs(os.path.join(traj, "not-a-snapshot"))
    assert append_snapshot(traj, str(src)) == "00003"
    with open(os.path.join(traj, "00003", "bench.json"), "w") as fh:
        fh.write("{truncated")
    with pytest.raises(MissingArtifact):
        gate_summary(traj)


# -- CLI ----------------------------------------------------------------------


def test_fleet_cli_analyze_show_and_exit_codes(populations, tmp_path, capsys):
    from repro.core.analysis import main

    roots, _ = populations
    out_dir = str(tmp_path / "fleetout")
    # Shorthand form (`fleet ROOT`), clean population -> 0; a directory
    # --out resolves to fleet_summary.json inside.
    assert main(["fleet", roots["stable"], "--out", out_dir + os.sep]) == 0
    assert "verdict: ok" in capsys.readouterr().out
    out = os.path.join(out_dir, ARTIFACT)
    assert json.loads(open(out).read())["verdict"] == "ok"
    # Confirmed findings -> 1, with the region named on stdout.
    assert main(["fleet", "analyze", roots["step"]]) == 1
    captured = capsys.readouterr()
    assert "app:transform" in captured.out
    assert "confirmed finding" in captured.err
    assert main(["fleet", roots["leak"]]) == 1
    assert "app:cache_fill" in capsys.readouterr().out
    # show renders a previously saved summary.
    assert main(["fleet", "show", str(out)]) == 0
    assert "verdict: ok" in capsys.readouterr().out
    # No roots and no --smoke -> usage error on the uniform contract.
    assert main(["fleet", "analyze"]) == 2
    assert capsys.readouterr().err.startswith("error:")


def test_fleet_gate_cli_seeding_and_regression(tmp_path, capsys):
    from repro.core.analysis import main

    traj = str(tmp_path / "traj")
    src = tmp_path / "artifacts"
    src.mkdir()
    _write_artifact(str(src / "bench.json"), 10.0, 5000.0)
    # First run: --append seeds snapshot 00000, gate passes, summary lands
    # in the trajectory dir (the CI cache round-trips both together).
    assert main(["fleet", "gate", traj, "--append", str(src), "--label", "seed"]) == 0
    out = capsys.readouterr().out
    assert "appended snapshot 00000-seed" in out
    assert "verdict: seeding" in out
    assert os.path.exists(os.path.join(traj, ARTIFACT))

    _seed_trajectory(traj, 6)
    _write_artifact(str(src / "bench.json"), 30.0, 5000.0)
    assert main(["fleet", "gate", traj, "--append", str(src)]) == 1
    captured = capsys.readouterr()
    assert "bench.beta_us" in captured.out
    assert "confirmed regression" in captured.err
    assert json.loads(
        open(os.path.join(traj, ARTIFACT)).read()
    )["verdict"] == "regressed"
