"""core/merge.py coverage: find_runs experiment filtering + merge_runs clock
alignment across synthetic run dirs with skewed epochs (no live measurement —
the run dirs are written by hand so the clock math is fully controlled)."""

import json
import os

import numpy as np

from repro.core.buffer import EV_ENTER, EV_EXIT
from repro.core.merge import find_runs, merge_runs

MS = 1_000_000  # ns


def _write_run(root, name, rank, epoch_time_ns, epoch_perf_ns, events, world_size=2):
    """Materialize a minimal trace run dir (defs.json + one stream)."""
    run_dir = os.path.join(str(root), name)
    os.makedirs(run_dir)
    cols = np.asarray(events, dtype=np.uint64)
    np.savez_compressed(
        os.path.join(run_dir, "stream_t0.npz"),
        kind=cols[:, 0].astype(np.uint8),
        region=cols[:, 1].astype(np.int32),
        t=cols[:, 2],
        aux=cols[:, 3].astype(np.uint32),
    )
    defs = {
        "meta": {
            "rank": rank,
            "topology": {"rank": rank, "world_size": world_size,
                         "local_rank": rank, "mesh_shape": []},
            "epoch_time_ns": epoch_time_ns,
            "epoch_perf_ns": epoch_perf_ns,
        },
        "streams": {"0": {"file": "stream_t0.npz", "events": len(events)}},
        "regions": [{"name": f"rank{rank}_work", "module": "test"}],
    }
    with open(os.path.join(run_dir, "defs.json"), "w") as fh:
        json.dump(defs, fh)
    return run_dir


def test_find_runs_filters_by_experiment(tmp_path):
    a = _write_run(tmp_path, "expA-1-r0", 0, 0, 0, [(EV_ENTER, 0, 10, 0)])
    _write_run(tmp_path, "expB-1-r0", 0, 0, 0, [(EV_ENTER, 0, 10, 0)])
    os.makedirs(tmp_path / "expA-not-a-run")  # dir without defs.json: ignored
    (tmp_path / "expA-file").write_text("plain file, also ignored")

    assert find_runs(str(tmp_path)) == sorted(
        [a, str(tmp_path / "expB-1-r0")]
    )
    assert find_runs(str(tmp_path), "expA") == [a]
    assert find_runs(str(tmp_path), "expC") == []


def test_merge_runs_aligns_skewed_epochs(tmp_path):
    """Two ranks whose perf_counter epochs differ wildly but whose wall
    clocks interleave: merge must order events by aligned wall time, i.e.
    epoch_time_ns + (t - epoch_perf_ns)."""
    # rank 0: perf epoch 500ns at wall 1_000ms; events at wall +0ms, +4ms
    run0 = _write_run(
        tmp_path, "skew-r0", 0,
        epoch_time_ns=1_000 * MS, epoch_perf_ns=500,
        events=[(EV_ENTER, 0, 500, 0), (EV_EXIT, 0, 500 + 4 * MS, 0)],
    )
    # rank 1: perf epoch 900_000ns at wall 1_002ms; events at wall +0ms, +6ms
    run1 = _write_run(
        tmp_path, "skew-r1", 1,
        epoch_time_ns=1_002 * MS, epoch_perf_ns=900_000,
        events=[(EV_ENTER, 0, 900_000, 0), (EV_EXIT, 0, 900_000 + 6 * MS, 0)],
    )
    out = str(tmp_path / "merged.json")
    summary = merge_runs([run0, run1], out)

    assert summary["total_events"] == 4
    assert summary["world_size"] == 2
    assert {r["rank"] for r in summary["ranks"]} == {0, 1}
    assert all(r["topology"]["world_size"] == 2 for r in summary["ranks"])

    with open(out) as fh:
        events = json.load(fh)["traceEvents"]
    spans = [e for e in events if e["ph"] in ("B", "E")]
    # expected wall-clock order (chrome ts is in microseconds):
    #   r0 enter @1000ms, r1 enter @1002ms, r0 exit @1004ms, r1 exit @1008ms
    assert [(e["pid"], e["ph"]) for e in spans] == [
        (0, "B"), (1, "B"), (0, "E"), (1, "E"),
    ]
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)
    np.testing.assert_allclose(ts, [1_000_000.0, 1_002_000.0, 1_004_000.0, 1_008_000.0])
