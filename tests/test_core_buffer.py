"""Unit tests for event buffers (the per-event fast path)."""

import numpy as np
import pytest

from repro.core.buffer import (
    BUFFER_STRATEGIES,
    EV_ENTER,
    EV_EXIT,
    ListEventBuffer,
    NumpyEventBuffer,
    columns_from_events,
)


@pytest.mark.parametrize("strategy", sorted(BUFFER_STRATEGIES))
def test_flush_delivers_columns(strategy):
    batches = []
    buf = BUFFER_STRATEGIES[strategy](
        thread_id=7, flush_threshold=1024, on_flush=lambda tid, cols: batches.append((tid, cols))
    )
    if strategy == "list":
        for i in range(10):
            buf.events.append((EV_ENTER, i, 1000 + i, 0))
    else:
        for i in range(10):
            buf.append(EV_ENTER, i, 1000 + i, 0)
    assert len(buf) == 10
    buf.flush()
    assert len(buf) == 0
    (tid, cols), = batches
    assert tid == 7
    np.testing.assert_array_equal(cols["region"], np.arange(10))
    np.testing.assert_array_equal(cols["t"], 1000 + np.arange(10))
    assert cols["kind"].dtype == np.uint8
    assert buf.n_flushed == 10


def test_list_buffer_preserves_list_identity_across_flush():
    # Instrumenter closures bind events.append once; flush must keep the
    # same list object alive.
    buf = ListEventBuffer(thread_id=0, flush_threshold=4, on_flush=lambda *_: None)
    append = buf.events.append
    events_obj = buf.events
    append((EV_ENTER, 1, 1, 0))
    buf.flush()
    assert buf.events is events_obj
    append((EV_EXIT, 1, 2, 0))
    assert len(buf) == 1  # append after flush still lands in the live buffer


def test_numpy_buffer_auto_flush_at_threshold():
    batches = []
    buf = NumpyEventBuffer(thread_id=0, flush_threshold=8, on_flush=lambda tid, c: batches.append(c))
    for i in range(20):
        buf.append(EV_ENTER, i, i, 0)
    assert len(batches) == 2
    assert all(len(b["kind"]) == 8 for b in batches)
    assert len(buf) == 4


def test_flush_reentrancy_guard():
    # A flush callback that appends (as real substrates' C calls can while
    # instrumentation is live) must not recurse forever.
    buf = ListEventBuffer(thread_id=0, flush_threshold=2, on_flush=None)

    def on_flush(tid, cols):
        buf.events.append((EV_ENTER, 99, 99, 0))
        buf.flush()  # re-entrant: must be a no-op

    buf.on_flush = on_flush
    buf.events.append((EV_ENTER, 1, 1, 0))
    buf.events.append((EV_EXIT, 1, 2, 0))
    buf.flush()
    assert len(buf.events) == 1  # the event appended during flush survives


def test_numpy_buffer_append_during_flush_grows_instead_of_crashing():
    # Regression: appends issued while a flush is in progress (re-entrancy
    # guard active) used to march the cursor past the preallocated capacity
    # and the next append raised IndexError.  Now the columns grow.
    buf = NumpyEventBuffer(thread_id=0, flush_threshold=4, on_flush=None)

    def on_flush(tid, cols):
        for i in range(6):  # more than a full buffer's worth, mid-flush
            buf.append(EV_ENTER, 100 + i, i, 0)

    buf.on_flush = on_flush
    for i in range(4):  # 4th append triggers the flush -> re-entrant appends
        buf.append(EV_ENTER, i, i, 0)
    assert len(buf) == 6  # survived past flush_threshold without flushing
    assert buf.capacity >= 6
    assert buf.n_dropped == 0
    buf.on_flush = lambda tid, cols: None
    buf.flush()
    assert buf.n_flushed == 10
    assert len(buf) == 0


def test_numpy_buffer_drops_at_growth_ceiling():
    buf = NumpyEventBuffer(thread_id=0, flush_threshold=2, on_flush=None)
    buf._flushing = True  # simulate a wedged flush: nothing ever drains
    limit = 2 * NumpyEventBuffer.MAX_GROWTH
    for i in range(limit + 5):
        buf.append(EV_ENTER, i, i, 0)
    assert len(buf) == limit
    assert buf.n_dropped == 5  # bounded memory: excess events are dropped


def test_columns_from_empty():
    cols = columns_from_events([])
    assert all(len(v) == 0 for v in cols.values())
