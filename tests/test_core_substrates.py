"""Substrate tests: tracing round-trip, chrome export, metrics, threads."""

import json
import os
import threading

import numpy as np

import repro.core as rmon
from repro.core.substrates.tracing import load_run, to_chrome


def test_tracing_roundtrip_and_chrome(tmp_path):
    d = str(tmp_path / "trace-run")
    rmon.init(instrumenter="profile", run_dir=d, experiment="rt")

    def f():
        return 42

    with rmon.region("phase"):
        f()
    out = rmon.finalize()

    defs, streams = load_run(out)
    assert defs["meta"]["experiment"] == "rt"
    assert len(streams) == 1
    cols = list(streams.values())[0]
    assert set(cols) == {"kind", "region", "t", "aux"}
    # timestamps are monotone non-decreasing within a stream
    assert np.all(np.diff(cols["t"].astype(np.int64)) >= 0)
    # every recorded region id resolves in the table
    assert int(cols["region"].max()) < len(defs["regions"])

    chrome_path = os.path.join(out, "trace.json")
    assert os.path.exists(chrome_path)
    with open(chrome_path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"]
    spans = [e for e in events if e["ph"] in ("B", "E")]
    assert spans
    names = {e["name"] for e in spans}
    assert "phase" in names
    # the streaming exporter names processes/threads via metadata events
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in events)
    # B/E balance per (pid, tid, name)
    bal = {}
    for e in spans:
        key = (e["pid"], e["tid"], e["name"])
        bal[key] = bal.get(key, 0) + (1 if e["ph"] == "B" else -1)
    assert all(v == 0 for v in bal.values())


def test_metrics_substrate_aggregation(tmp_path):
    d = str(tmp_path / "metrics-run")
    rmon.init(instrumenter="none", run_dir=d, substrates=("metrics",))
    for v in [1.0, 2.0, 3.0, 10.0]:
        rmon.metric("step.ms", v)
    out = rmon.finalize()
    with open(os.path.join(out, "metrics.json")) as fh:
        doc = json.load(fh)
    agg = doc["metrics"]["step.ms"]
    assert agg["count"] == 4
    assert agg["sum"] == 16.0
    assert agg["min"] == 1.0 and agg["max"] == 10.0
    assert agg["median"] == 2.5
    assert doc["series"]["step.ms"][0][1] == 1.0


def test_multithreaded_streams(tmp_path):
    d = str(tmp_path / "mt-run")
    rmon.init(instrumenter="profile", run_dir=d)

    def worker():
        def leaf():
            return 7

        for _ in range(20):
            leaf()

    threads = [threading.Thread(target=worker) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    out = rmon.finalize()
    defs, streams = load_run(out)
    # main thread + 3 workers each get their own stream
    assert len(streams) >= 4
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    leaf_visits = sum(
        v["visits"] for k, v in prof["flat"].items() if k.endswith("worker.<locals>.leaf")
    )
    assert leaf_visits == 60


def test_profile_text_rendering(tmp_path):
    d = str(tmp_path / "txt-run")
    rmon.init(instrumenter="profile", run_dir=d)

    def hot():
        return sum(range(100))

    for _ in range(10):
        hot()
    out = rmon.finalize()
    with open(os.path.join(out, "profile.txt")) as fh:
        text = fh.read()
    assert "hotspots" in text
    assert "hot" in text
