"""Pallas kernel validation (interpret=True on CPU) against pure-jnp oracles.

Shape/dtype sweeps + hypothesis property tests per the assignment.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,kh,d,causal,window",
    [
        (1, 128, 128, 4, 4, 64, True, None),  # MHA causal
        (2, 128, 128, 8, 2, 64, True, None),  # GQA 4:1
        (1, 256, 256, 4, 1, 64, True, None),  # MQA
        (1, 128, 128, 2, 2, 64, False, None),  # bidirectional
        (1, 256, 256, 4, 2, 64, True, 64),  # sliding window
        (2, 128, 128, 4, 4, 128, True, None),  # head_dim 128
    ],
)
def test_flash_attention_vs_ref(b, s, t, h, kh, d, causal, window, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (b, s, h, d), dtype)
    k = _rand(k2, (b, t, kh, d), dtype)
    v = _rand(k3, (b, t, kh, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    rtol, atol = (2e-2, 2e-2) if dtype == jnp.bfloat16 else (1e-5, 1e-5)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=rtol, atol=atol
    )


def test_flash_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(k1, (1, 256, 4, 64))
    k = _rand(k2, (1, 256, 2, 64))
    v = _rand(k3, (1, 256, 2, 64))
    a = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    b = ops.flash_attention(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@given(
    s=st.sampled_from([64, 128, 192]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(s, h, g, d, causal):
    kh = h
    hq = h * g
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s + hq + d), 3)
    q = _rand(k1, (1, s, hq, d))
    k = _rand(k2, (1, s, kh, d))
    v = _rand(k3, (1, s, kh, d))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)
    # attention outputs are convex combinations of v rows
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


# ----------------------------------------------------------------------------
# RG-LRU scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,s,n,block_t,block_n",
    [(1, 64, 128, 16, 128), (2, 128, 256, 16, 128), (1, 48, 128, 8, 64), (3, 32, 384, 32, 128)],
)
def test_rg_lru_vs_ref(b, s, n, block_t, block_n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.uniform(k1, (b, s, n), minval=0.5, maxval=0.999)
    bx = _rand(k2, (b, s, n), scale=0.5)
    out = ops.rg_lru_scan(a, bx, block_t=block_t, block_n=block_n)
    expect = ref.rg_lru_scan_ref(a, bx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_rg_lru_matches_associative_scan():
    """Kernel (linear scan) vs the model's associative_scan path."""
    from repro.models.rglru import rglru_scan_ref

    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a = jax.random.uniform(k1, (2, 64, 128), minval=0.8, maxval=0.999)
    bx = _rand(k2, (2, 64, 128))
    np.testing.assert_allclose(
        np.asarray(ops.rg_lru_scan(a, bx)),
        np.asarray(rglru_scan_ref(a, bx)),
        rtol=1e-5,
        atol=1e-5,
    )


@given(
    s=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([128, 256]),
    decay=st.floats(min_value=0.1, max_value=0.999),
)
@settings(max_examples=10, deadline=None)
def test_rg_lru_property_bounded(s, n, decay):
    # with |a|<1 and bounded inputs, the state stays bounded by |bx|/(1-a)
    key = jax.random.PRNGKey(int(decay * 1000) + s + n)
    a = jnp.full((1, s, n), decay)
    bx = jax.random.uniform(key, (1, s, n), minval=-1.0, maxval=1.0)
    h = ops.rg_lru_scan(a, bx)
    assert float(jnp.max(jnp.abs(h))) <= 1.0 / (1.0 - decay) + 1e-3
    expect = ref.rg_lru_scan_ref(a, bx)
    np.testing.assert_allclose(np.asarray(h), np.asarray(expect), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# SSD chunk scan
# ----------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 64, 2, 32, 1, 16, 16),
        (2, 128, 4, 64, 1, 32, 32),
        (1, 64, 4, 32, 2, 16, 16),  # grouped B/C
        (1, 256, 2, 64, 1, 128, 64),  # larger state
    ],
)
def test_ssd_kernel_vs_sequential_ref(b, s, h, p, g, n, chunk):
    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    x = _rand(keys[0], (b, s, h, p), scale=0.5)
    dt = jax.random.uniform(keys[1], (b, s, h), minval=0.01, maxval=0.2)
    a = -jnp.exp(jax.random.uniform(keys[2], (h,), minval=-2.0, maxval=1.0))
    b_in = _rand(keys[3], (b, s, g, n), scale=0.5)
    c_in = _rand(keys[4], (b, s, g, n), scale=0.5)
    y, _ = ops.ssd_chunk_scan(x, dt, a, b_in, c_in, chunk=chunk)
    y_ref, _ = ref.ssd_scan_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_ssd_model_chunked_vs_sequential_ref():
    """models.ssd.ssd_chunked_ref (the train path) vs token-by-token scan."""
    from repro.models.ssd import ssd_chunked_ref

    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    b, s, h, p, g, n = 2, 128, 4, 32, 1, 32
    x = _rand(keys[0], (b, s, h, p), scale=0.5)
    dt = jax.random.uniform(keys[1], (b, s, h), minval=0.01, maxval=0.2)
    a = -jnp.exp(jax.random.uniform(keys[2], (h,), minval=-2.0, maxval=1.0))
    b_in = _rand(keys[3], (b, s, g, n), scale=0.5)
    c_in = _rand(keys[4], (b, s, g, n), scale=0.5)
    y_chunk, h_chunk = ssd_chunked_ref(x, dt, a, b_in, c_in, chunk=32)
    y_seq, h_seq = ref.ssd_scan_ref(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq), rtol=2e-4, atol=2e-4)


def test_ssd_chunk_size_independence():
    keys = jax.random.split(jax.random.PRNGKey(6), 5)
    b, s, h, p, g, n = 1, 128, 2, 32, 1, 16
    x = _rand(keys[0], (b, s, h, p), scale=0.5)
    dt = jax.random.uniform(keys[1], (b, s, h), minval=0.01, maxval=0.2)
    a = -jnp.exp(jax.random.uniform(keys[2], (h,), minval=-1.0, maxval=1.0))
    b_in = _rand(keys[3], (b, s, g, n), scale=0.5)
    c_in = _rand(keys[4], (b, s, g, n), scale=0.5)
    y16, _ = ops.ssd_chunk_scan(x, dt, a, b_in, c_in, chunk=16)
    y64, _ = ops.ssd_chunk_scan(x, dt, a, b_in, c_in, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), rtol=2e-4, atol=2e-4)
