"""Property-based tests (hypothesis) for monitoring-core invariants."""

import json
import os

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.buffer import EV_ENTER, EV_EXIT, columns_from_events
from repro.core.overhead import fit_linear
from repro.core.substrates.profiling import ProfilingSubstrate


# -- random balanced call trees -> profile invariants -------------------------

@st.composite
def balanced_events(draw, max_regions=6, max_depth=5, max_children=4):
    """Generate a balanced ENTER/EXIT event stream with monotone timestamps."""
    clock = {"t": 0}

    def tick():
        clock["t"] += draw(st.integers(min_value=1, max_value=1000))
        return clock["t"]

    events = []

    def emit_tree(depth):
        rid = draw(st.integers(min_value=0, max_value=max_regions - 1))
        events.append((EV_ENTER, rid, tick(), 0))
        if depth < max_depth:
            for _ in range(draw(st.integers(min_value=0, max_value=max_children))):
                if draw(st.booleans()):
                    emit_tree(depth + 1)
        events.append((EV_EXIT, rid, tick(), 0))

    for _ in range(draw(st.integers(min_value=1, max_value=4))):
        emit_tree(0)
    return events


@given(balanced_events())
@settings(max_examples=50, deadline=None)
def test_profile_invariants_on_random_trees(events):
    sub = ProfilingSubstrate()
    sub.open("/tmp", {})
    sub.on_flush(0, columns_from_events(events))
    state = sub.threads[0]
    # Balanced stream: shadow stack empty, no orphans/mismatches.
    assert not state.stack
    assert state.orphan_exits == 0
    assert state.mismatched_exits == 0

    total_span = sum(1 for k, *_ in events if k == EV_ENTER)

    def check(node, depth):
        child_incl = 0
        visits = 0
        for ch in node.children.values():
            ci, cv = check(ch, depth + 1)
            child_incl += ci
            visits += cv
        if node.region >= 0:
            # inclusive >= exclusive >= 0; inclusive == exclusive + children
            assert node.incl_ns >= node.excl_ns >= 0
            assert node.incl_ns == node.excl_ns + child_incl
            assert node.visits >= 1
            return node.incl_ns, visits + node.visits
        return child_incl, visits

    _, tree_visits = check(state.root, 0)
    assert tree_visits == total_span  # every ENTER became a visit


@given(
    st.lists(st.integers(min_value=1, max_value=10**6), min_size=2, max_size=6, unique=True),
    st.floats(min_value=0.0, max_value=10.0),
    st.floats(min_value=1e-9, max_value=1e-3),
)
@settings(max_examples=50, deadline=None)
def test_fit_linear_property(ns, alpha, beta):
    ns = sorted(ns)
    medians = [alpha + beta * n for n in ns]
    a, b = fit_linear(ns, medians)
    assert a == np.testing.assert_allclose(a, alpha, rtol=1e-4, atol=1e-6) or True
    np.testing.assert_allclose(b, beta, rtol=1e-4)


@given(st.lists(st.tuples(
    st.integers(min_value=0, max_value=5),
    st.integers(min_value=0, max_value=100),
    st.integers(min_value=0, max_value=2**40),
    st.integers(min_value=0, max_value=2**31 - 1),
), max_size=100))
@settings(max_examples=30, deadline=None)
def test_columns_roundtrip(events):
    cols = columns_from_events(events)
    assert len(cols["kind"]) == len(events)
    for i, (k, r, t, a) in enumerate(events):
        assert int(cols["kind"][i]) == k
        assert int(cols["region"][i]) == r
        assert int(cols["t"][i]) == t
        assert int(cols["aux"][i]) == a


# ---------------------------------------------------------------------------
# filter spec round-trip (repro.core.filtering + staticpass plan merging)
# ---------------------------------------------------------------------------

# Pattern alphabet avoids the spec grammar's separators (';' between
# clauses, ',' between patterns, ':' after the clause keyword) but keeps
# fnmatch metacharacters — globs must survive the round trip too.
_pattern = st.text(
    alphabet="abcdefgzXY019._*?", min_size=1, max_size=12
).filter(lambda s: s.strip())
_name = st.text(alphabet="abcdefgz019_", min_size=1, max_size=8)
_module = st.lists(_name, min_size=1, max_size=3).map(".".join)


@given(
    st.lists(_pattern, max_size=4),
    st.lists(_pattern, max_size=4),
    st.lists(_pattern, max_size=4),
    st.lists(st.tuples(_module, _name), min_size=1, max_size=8),
)
@settings(max_examples=200, deadline=None)
def test_filter_spec_round_trip_preserves_verdicts(inc, exc, rexc, probes):
    """``Filter.from_spec(f.to_spec())`` preserves every decide() verdict —
    including absolute ``exclude!`` rules (governor/static-plan channel),
    across all rule-combination semantics (allow-list, mixed, exclude-only).
    This is the contract that makes static_plan.json filter specs and
    governor suggested filters safe to paste into ``--filter``."""
    from repro.core.filtering import Filter

    f = Filter(include=inc, exclude=exc, runtime_exclude=rexc)
    g = Filter.from_spec(f.to_spec())
    assert g.to_spec() == f.to_spec()  # idempotent serialization
    for module, func in probes:
        file = module.replace(".", "/") + ".py"
        assert f.decide(module, func, file) == g.decide(module, func, file), (
            f.to_spec(), module, func,
        )


@given(
    st.lists(_pattern, max_size=3),
    st.lists(_pattern, max_size=3),
    st.lists(_pattern, min_size=1, max_size=4),
    st.lists(st.tuples(_module, _name), min_size=1, max_size=8),
)
@settings(max_examples=200, deadline=None)
def test_plan_merged_filter_only_tightens_and_round_trips(inc, exc, plan_pats, probes):
    """Merging plan patterns via add_runtime_excludes can only remove
    regions (never re-admit), and the merged filter still round-trips."""
    from repro.core.filtering import Filter

    base = Filter(include=list(inc), exclude=list(exc))
    merged = Filter(include=list(inc), exclude=list(exc))
    merged.add_runtime_excludes(plan_pats)
    g = Filter.from_spec(merged.to_spec())
    for module, func in probes:
        file = module.replace(".", "/") + ".py"
        before = base.decide(module, func, file)
        after = merged.decide(module, func, file)
        assert after == g.decide(module, func, file)
        if not before:
            assert not after  # merging never re-admits


# -- static concurrency analyzer: total on arbitrary modules ------------------

_IDENT = st.sampled_from(["f", "g", "h", "worker", "run", "drain", "poll"])
_LOCK = st.sampled_from(["_lock", "_mu", "LOCK"])


@st.composite
def concurrency_modules(draw):
    """Random-but-valid modules built from the constructs the concurrency
    analyzer models: lock defs/acquires, thread+executor spawns with every
    join/daemon combination, async defs, fork, global writes, plus calls
    between them.  The analyzer must be total over all of it."""
    lock = draw(_LOCK)
    lines = ["import os", "import threading", "import time",
             "from concurrent import futures", f"{lock} = threading.Lock()",
             "counter = 0"]
    n_funcs = draw(st.integers(min_value=1, max_value=5))
    names = []
    for i in range(n_funcs):
        name = f"{draw(_IDENT)}_{i}"
        names.append(name)
        is_async = draw(st.booleans())
        lines.append(f"{'async ' if is_async else ''}def {name}():")
        body = []
        declared_global = False
        for _ in range(draw(st.integers(min_value=1, max_value=3))):
            kind = draw(st.integers(min_value=0, max_value=6))
            if kind == 0:
                body += [f"    with {lock}:", "        pass"]
            elif kind == 1:
                daemon = draw(st.booleans())
                join = draw(st.booleans())
                target = draw(st.sampled_from(names))
                body.append(
                    f"    t = threading.Thread(target={target}, "
                    f"daemon={daemon})"
                )
                body.append("    t.start()")
                if join:
                    body.append("    t.join()")
            elif kind == 2:
                # the global decl must precede the first assignment and
                # appear at most once per function (SyntaxError otherwise)
                if not declared_global:
                    body.append("    global counter")
                    declared_global = True
                body.append("    counter += 1")
            elif kind == 3:
                body.append("    time.sleep(0.01)")
            elif kind == 4:
                body.append("    os.fork()")
            elif kind == 5:
                managed = draw(st.booleans())
                if managed:
                    body += [
                        "    with futures.ThreadPoolExecutor() as ex:",
                        f"        ex.submit({draw(st.sampled_from(names))})",
                    ]
                else:
                    body.append("    ex = futures.ThreadPoolExecutor()")
                    body.append(
                        f"    ex.submit({draw(st.sampled_from(names))})"
                    )
            else:
                body.append(f"    {draw(st.sampled_from(names))}()")
        lines += body
    return "\n".join(lines) + "\n"


@given(concurrency_modules())
@settings(max_examples=60, deadline=None)
def test_concurrency_analyzer_total_on_valid_modules(tmp_path_factory, src):
    """analyze_paths never raises on valid modules and every finding it
    emits is well-formed (known rule, real location, witness present)."""
    from repro.core.staticpass import CONCURRENCY_RULES, analyze_paths
    from repro.core.staticpass.scanner import clear_scan_cache

    compile(src, "<gen>", "exec")  # strategy sanity: the module is valid
    d = tmp_path_factory.mktemp("conc")
    p = d / "m.py"
    p.write_text(src)
    clear_scan_cache()  # same path, fresh content each example
    model, findings = analyze_paths([str(p)])
    assert model.errors == []
    for f in findings:
        assert f["rule"] in CONCURRENCY_RULES
        assert f["file"] == str(p) and f["line"] >= 1
        assert isinstance(f.get("witness"), list)
    doc_findings = json.loads(json.dumps(findings))  # JSON-serializable
    assert len(doc_findings) == len(findings)


@given(st.text(max_size=200))
@settings(max_examples=60, deadline=None)
def test_concurrency_analyzer_tolerates_arbitrary_text(tmp_path_factory, src):
    """Garbage in, errors-list out: unparseable files are recorded in
    model.errors, never raised through the CLI."""
    from repro.core.staticpass import analyze_paths
    from repro.core.staticpass.scanner import clear_scan_cache

    d = tmp_path_factory.mktemp("junk")
    p = d / "m.py"
    p.write_text(src, errors="replace")
    clear_scan_cache()
    model, findings = analyze_paths([str(p)])
    if model.errors:
        assert findings == []
