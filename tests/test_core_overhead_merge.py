"""Overhead-fit methodology (paper §3) + multi-process merge tests."""

import json
import os

import numpy as np
import pytest

import repro.core as rmon
from repro.core.merge import find_runs, merge_runs
from repro.core.overhead import (
    CASE1_SRC,
    CASE2_SRC,
    fit_linear,
    measure_inprocess_beta,
)


def test_fit_linear_recovers_alpha_beta():
    # synthetic t = 0.5 + 2e-6 * N
    ns = [1000, 10000, 100000, 1000000]
    medians = [0.5 + 2e-6 * n for n in ns]
    alpha, beta = fit_linear(ns, medians)
    assert alpha == pytest.approx(0.5, rel=1e-6)
    assert beta == pytest.approx(2e-6, rel=1e-6)


def test_case_sources_execute():
    for src in (CASE1_SRC, CASE2_SRC):
        glb = {"__name__": "__case__"}
        import sys

        argv = sys.argv
        sys.argv = ["case", "100"]
        try:
            exec(compile(src, "<case>", "exec"), glb)
        finally:
            sys.argv = argv
        assert glb["result"] == 100


needs_sys_monitoring = pytest.mark.skipif(
    not hasattr(__import__("sys"), "monitoring"),
    reason="sys.monitoring (PEP 669) needs Python 3.12+",
)


@pytest.mark.parametrize(
    "instrumenter",
    ["none", "profile", pytest.param("monitoring", marks=needs_sys_monitoring)],
)
def test_inprocess_beta_positive_and_ordered(instrumenter):
    # Small Ns keep this fast; we only check basic sanity here — the real
    # numbers come from benchmarks/overhead_case*.py.
    alpha, beta = measure_inprocess_beta("case2", instrumenter, ns=[200, 2000], repeats=3)
    assert np.isfinite(alpha) and np.isfinite(beta)


def test_paper_claim_profile_beta_below_trace_beta():
    """Paper Table 2: per-iteration cost of settrace > setprofile (case 1,
    where settrace additionally pays per-line events).

    Deflaked (was load-sensitive under parallel CI): best-of-k — each
    attempt measures both betas back to back and passes as soon as the
    ordering holds; after k attempts the *minimum* betas (robust to
    descheduling spikes, which only ever inflate) are compared with a small
    tolerance.  The real magnitude gap (~5x on this kernel) is measured in
    benchmarks/overhead_case1.py; this is a smoke-level ordering check.
    """
    best_profile = float("inf")
    best_trace = float("inf")
    for _ in range(4):
        _, beta_profile = measure_inprocess_beta(
            "case1", "profile", ns=[2000, 20000], repeats=3
        )
        _, beta_trace = measure_inprocess_beta(
            "case1", "trace", ns=[2000, 20000], repeats=3
        )
        best_profile = min(best_profile, beta_profile)
        best_trace = min(best_trace, beta_trace)
        if beta_trace > beta_profile:
            return
    assert best_trace > 0.9 * best_profile


def _make_run(tmp_path, rank, name):
    d = str(tmp_path / f"{name}-r{rank}")
    rmon.init(instrumenter="profile", run_dir=d, experiment=name, rank=rank)

    def ranked_work():
        return rank

    with rmon.region(f"rank{rank}_phase"):
        ranked_work()
    return rmon.finalize()


def test_merge_runs(tmp_path):
    run0 = _make_run(tmp_path, 0, "mrg")
    run1 = _make_run(tmp_path, 1, "mrg")
    out = str(tmp_path / "merged.json")
    summary = merge_runs([run0, run1], out)
    assert summary["total_events"] > 0
    assert {r["rank"] for r in summary["ranks"]} == {0, 1}
    with open(out) as fh:
        doc = json.load(fh)
    spans = [e for e in doc["traceEvents"] if e["ph"] in ("B", "E")]
    pids = {e["pid"] for e in spans}
    assert pids == {0, 1}
    names = {e["name"] for e in spans}
    assert "rank0_phase" in names and "rank1_phase" in names
    # merged span stream is globally time-sorted
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)


def test_find_runs(tmp_path):
    _make_run(tmp_path, 0, "findme")
    runs = find_runs(str(tmp_path), "findme")
    assert len(runs) == 1
