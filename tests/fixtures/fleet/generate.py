"""Materialize the canonical synthetic run populations for fleet tests.

The population shapes (stable / step / drift / leak) and the real-schema
artifact writer live in :mod:`repro.core.fleet.synth` so that
``analysis fleet --smoke`` and the unit tests exercise the *same*
generator.  This module is the checked-in driver: import
:func:`materialize` from tests, or run it directly to inspect a
population by hand::

    PYTHONPATH=src python tests/fixtures/fleet/generate.py /tmp/fleet-pops
    PYTHONPATH=src python -m repro.core.analysis fleet /tmp/fleet-pops/step

Everything is seeded — the same ``seed`` always yields byte-identical
artifacts, which the determinism tests rely on.
"""

from __future__ import annotations

import argparse
from typing import Dict, Optional

from repro.core.fleet import synth

#: The canonical population names, in spec order.
POPULATIONS = tuple(synth.CANONICAL)


def materialize(out_dir: str, kind: Optional[str] = None, runs: Optional[int] = None,
                seed: int = 0) -> Dict[str, str]:
    """Write population(s) under ``out_dir`` and return ``{kind: root}``.

    ``kind=None`` writes all four canonical populations; otherwise just
    the named one (optionally overriding its run count).
    """
    if kind is None:
        return synth.write_all(out_dir, seed=seed)
    return {kind: synth.write_population(out_dir, kind, runs=runs, seed=seed)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out_dir", help="directory to write populations under")
    ap.add_argument("--kind", choices=POPULATIONS, default=None,
                    help="one population only (default: all four)")
    ap.add_argument("--runs", type=int, default=None,
                    help="override the population's run count")
    ap.add_argument("--seed", type=int, default=0)
    ns = ap.parse_args(argv)
    roots = materialize(ns.out_dir, kind=ns.kind, runs=ns.runs, seed=ns.seed)
    for kind, root in sorted(roots.items()):
        print(f"{kind}: {root}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
