"""Synthetic fleet run-population fixtures (see generate.py)."""
