"""Deliberate concurrency misuse — one violation per SP4xx rule.

The companion of bad.py: tests/test_staticpass.py asserts each SP401–SP405
rule fires exactly once across this directory.  Keep one rule per function
and join every thread that is not the SP405 demonstration.
"""

import os
import threading
import time

A = threading.Lock()
B = threading.Lock()
counter = 0


def ab_path():
    with A:
        with B:  # order A -> B
            pass


def ba_path():
    with B:
        with A:  # order B -> A: SP401 cycle with ab_path
            pass


def drive_inversion():
    t = threading.Thread(target=ab_path)
    t.start()
    ba_path()
    t.join()


def racer():
    global counter
    counter += 1  # SP402: written from thread + main, no common lock


def spawn_racers():
    t = threading.Thread(target=racer)
    t.start()
    t.join()
    racer()


async def lazy_poll():
    time.sleep(0.5)  # SP403: parks the event loop, not just this coroutine


def forker():
    t = threading.Thread(target=racer)
    t.start()
    pid = os.fork()  # SP404: fork while a thread is running
    t.join()
    return pid


def leaker():
    worker = threading.Thread(target=print)
    worker.start()  # SP405: never joined on any path
