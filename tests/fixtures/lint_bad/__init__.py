"""Fixture package for the measurement-API linter tests.

Every module here is deliberately wrong; tests/test_staticpass.py asserts
each lint rule fires exactly once over this package.  Never import this
package — it is scanned, not executed.
"""
