"""Deliberate measurement-API misuse — one violation per lint rule.

The line of each violation is asserted in tests/test_staticpass.py; keep
one rule per function and do not add calls that would double-fire a rule.
"""

import sys
import threading
import time

import repro.core as rmon


def leaked_region():
    rmon.region("leaked")  # SP101: created but never entered


def early_worker():
    t = threading.Thread(target=print)
    t.start()  # SP202: started before the instrumenter installs
    rmon.init(instrumenter="profile")  # SP102: module never finalizes
    t.join()  # joined, so SP405 stays quiet — SP202 is this function's rule


def foreign_hook():
    sys.setprofile(print)  # SP201: collides with the active instrumenter


def hot_poll(n):
    for _ in range(n):
        with rmon.region("poll"):
            time.sleep(0.01)  # SP301: blocking call charged to a hot region
