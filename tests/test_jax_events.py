"""JAX integration tests: HLO collective parsing, compiled metrics,
step instrumentation."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as rmon
from repro.core.jax_events import (
    collective_stats,
    compiled_metrics,
    instrument_step,
    record_compiled,
)

HLO_SAMPLE = """
  %all-reduce.2 = f32[4,128]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add
  %all-gather.1 = bf16[8,256]{1,0} all-gather(%p), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %reduce-scatter.3 = f32[2,64]{1,0} reduce-scatter(%q), channel_id=3, replica_groups=[1,8]<=[8], to_apply=%add
  %collective-permute.1 = f32[16]{0} collective-permute(%r), channel_id=4, source_target_pairs={{0,1},{1,0}}
  %notacollective = f32[4]{0} add(%a, %b)
"""


def test_collective_stats_parsing():
    stats = collective_stats(HLO_SAMPLE)
    ar = stats["all-reduce"]
    assert ar["count"] == 1
    assert ar["result_bytes"] == 4 * 128 * 4
    # group size 2 -> ring factor 2*(2-1)/2 = 1.0
    assert ar["wire_bytes"] == pytest.approx(4 * 128 * 4 * 1.0)
    ag = stats["all-gather"]
    assert ag["count"] == 1 and ag["result_bytes"] == 8 * 256 * 2
    # group size 4 -> (4-1)/4
    assert ag["wire_bytes"] == pytest.approx(8 * 256 * 2 * 0.75)
    rs = stats["reduce-scatter"]
    assert rs["count"] == 1 and rs["wire_bytes"] == pytest.approx(2 * 64 * 4 * 7 / 8)
    cp = stats["collective-permute"]
    assert cp["count"] == 1 and cp["wire_bytes"] == 16 * 4


# Async-ified collective forms, as XLA emits them post-SPMD: the *-start op
# carries the transfer (tuple-shaped result for all-gather/collective-permute)
# and the paired *-done op must not double count.
HLO_ASYNC_SAMPLE = """
  %all-reduce-start.1 = f32[1024]{0} all-reduce-start(f32[1024]{0} %p), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %all-reduce-done.1 = f32[1024]{0} all-reduce-done(f32[1024]{0} %all-reduce-start.1)
  %all-gather-start.2 = (f32[8,128]{1,0}, f32[32,128]{1,0}) all-gather-start(f32[8,128]{1,0} %q), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %all-gather-done.2 = f32[32,128]{1,0} all-gather-done((f32[8,128]{1,0}, f32[32,128]{1,0}) %all-gather-start.2)
  %collective-permute-start.3 = (f32[64]{0}, f32[64]{0}, u32[], u32[]) collective-permute-start(f32[64]{0} %r), channel_id=3, source_target_pairs={{0,1},{1,0}}
  %collective-permute-done.3 = f32[64]{0} collective-permute-done((f32[64]{0}, f32[64]{0}, u32[], u32[]) %collective-permute-start.3)
"""


def test_collective_stats_async_forms_counted_once():
    stats = collective_stats(HLO_ASYNC_SAMPLE)
    ar = stats["all-reduce"]
    assert ar["count"] == 1  # start counted, done deduped
    assert ar["result_bytes"] == 1024 * 4
    # group size 4 -> ring factor 2*(4-1)/4
    assert ar["wire_bytes"] == pytest.approx(1024 * 4 * 1.5)
    ag = stats["all-gather"]
    assert ag["count"] == 1
    # tuple result (input, output): the gathered output is the byte count
    assert ag["result_bytes"] == 32 * 128 * 4
    assert ag["wire_bytes"] == pytest.approx(32 * 128 * 4 * 0.75)
    cp = stats["collective-permute"]
    assert cp["count"] == 1
    assert cp["result_bytes"] == 64 * 4 and cp["wire_bytes"] == 64 * 4


def test_collective_stats_reduce_scatter_start_uses_scattered_result():
    # reduce-scatter's async tuple is (input, output) with the *smaller*
    # scattered output as the real result — max() over the tuple would
    # overcount by the group-size factor.
    hlo = """
  %reduce-scatter-start.1 = (f32[800]{0}, f32[100]{0}) reduce-scatter-start(f32[800]{0} %p), channel_id=1, replica_groups=[1,8]<=[8], to_apply=%add
  %reduce-scatter-done.1 = f32[100]{0} reduce-scatter-done((f32[800]{0}, f32[100]{0}) %reduce-scatter-start.1)
"""
    rs = collective_stats(hlo)["reduce-scatter"]
    assert rs["count"] == 1
    assert rs["result_bytes"] == 100 * 4
    assert rs["wire_bytes"] == pytest.approx(100 * 4 * 7 / 8)


def test_collective_stats_sync_and_async_mixed():
    stats = collective_stats(HLO_SAMPLE + HLO_ASYNC_SAMPLE)
    assert stats["all-reduce"]["count"] == 2
    assert stats["all-gather"]["count"] == 2
    # operand references to %all-reduce-start must not be miscounted
    assert stats["reduce-scatter"]["count"] == 1


def test_compiled_metrics_on_real_lowering():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    m = compiled_metrics(compiled)
    # matmul flops = 2*64*128*256 (plus epilogue)
    assert m["hlo_flops"] >= 2 * 64 * 128 * 256
    assert m["hlo_bytes"] > 0
    assert m["collective_wire_bytes"] == 0.0  # single device


def test_record_compiled_feeds_metrics(tmp_path):
    rmon.init(instrumenter="none", substrates=("metrics",), run_dir=str(tmp_path / "m"))
    try:
        compiled = jax.jit(lambda x: x * 2).lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
        metrics = record_compiled("step", compiled)
        assert "hlo_flops" in metrics
    finally:
        out = rmon.finalize()
    with open(os.path.join(out, "metrics.json")) as fh:
        doc = json.load(fh)
    assert "step.hlo_flops" in doc["metrics"]


def test_instrument_step_blocks_and_times(tmp_path):
    rmon.init(instrumenter="none", substrates=("metrics", "profiling"), run_dir=str(tmp_path / "s"))
    try:
        fn = instrument_step(jax.jit(lambda x: x @ x.T), "mystep")
        x = jnp.ones((64, 64))
        for _ in range(3):
            out = fn(x)
        assert out.shape == (64, 64)
    finally:
        run = rmon.finalize()
    with open(os.path.join(run, "metrics.json")) as fh:
        doc = json.load(fh)
    assert doc["metrics"]["mystep.ms"]["count"] == 3
    with open(os.path.join(run, "profile.json")) as fh:
        prof = json.load(fh)
    assert prof["flat"]["jax.step:mystep"]["visits"] == 3
