"""Instrumenter behaviour tests — verifies the event coverage of paper Table 1.

| event       | setprofile | settrace | sampling | monitoring |
| call/return |     x      |    x     | sampled  |     x      |
| c_call/ret  |     x      |    -     |    -     |     -      |
| line        |     -      |    x     |    -     |     -      |
| exception   |     -      |    x     |    -     |     -      |
"""

import json
import os
import sys
import threading
import time

import pytest

import repro.core as rmon


def _run_workload(instrumenter, tmp_path, n=50, **cfg):
    d = str(tmp_path / f"run-{instrumenter}")
    rmon.init(instrumenter=instrumenter, run_dir=d, experiment="t", **cfg)

    def inner(x):
        return x + len("ab")  # len() -> c_call

    def outer():
        total = 0
        for i in range(3):
            total = inner(total)
        return total

    def boom():
        # raised inside a frame entered *after* install, so sys.settrace's
        # local trace function observes the exception event
        raise ValueError("boom")

    try:
        with rmon.region("phase"):
            for _ in range(n):
                outer()
        try:
            boom()
        except ValueError:
            pass
    finally:
        out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        return json.load(fh)


def _flat(prof):
    return prof["flat"]


def _thread0(prof):
    return list(prof["threads"].values())[0]


def test_profile_instrumenter_counts(tmp_path):
    prof = _run_workload("profile", tmp_path)
    flat = _flat(prof)
    # qualname-keyed function regions with exact visit counts
    outer = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.outer")]
    inner = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.inner")]
    assert outer and outer[0]["visits"] == 50
    assert inner and inner[0]["visits"] == 150
    # c_call coverage: len() from non-filtered caller
    lens = [v for k, v in flat.items() if k == "builtins:len"]
    assert lens and lens[0]["visits"] == 150
    assert _thread0(prof)["orphan_exits"] == 0
    assert _thread0(prof)["mismatched_exits"] == 0
    # inclusive >= exclusive everywhere
    for v in flat.values():
        assert v["incl_ns"] >= v["excl_ns"] >= 0


def test_trace_instrumenter_lines_and_exceptions(tmp_path):
    prof = _run_workload("trace", tmp_path)
    flat = _flat(prof)
    outer = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.outer")]
    assert outer and outer[0]["visits"] == 50
    t0 = _thread0(prof)
    assert sum(t0["lines_executed"].values()) > 0  # line events observed
    assert t0["exceptions"] >= 1  # exception event observed
    # settrace must NOT see C functions (paper Table 1)
    assert not any(k.startswith("builtins:") for k in flat)


def test_sampling_instrumenter_subsamples(tmp_path):
    prof = _run_workload("sampling", tmp_path, sampling_period=10)
    flat = _flat(prof)
    inner = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.inner")]
    total_sampled = sum(v["visits"] for v in flat.values())
    # 200 python calls in the workload, period 10 -> ~20 samples (+/- region noise)
    assert 0 < total_sampled < 60
    if inner:
        assert inner[0]["visits"] < 150
    t0 = _thread0(prof)
    assert t0["orphan_exits"] == 0 and t0["mismatched_exits"] == 0  # balanced


@pytest.mark.skipif(
    not hasattr(__import__("sys"), "monitoring"),
    reason="sys.monitoring (PEP 669) needs Python 3.12+",
)
def test_monitoring_instrumenter_counts(tmp_path):
    prof = _run_workload("monitoring", tmp_path)
    flat = _flat(prof)
    outer = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.outer")]
    inner = [v for k, v in flat.items() if k.endswith(":_run_workload.<locals>.inner")]
    assert outer and outer[0]["visits"] == 50
    assert inner and inner[0]["visits"] == 150
    assert not any(k.startswith("builtins:") for k in flat)  # no C events


def test_none_instrumenter_user_regions_only(tmp_path):
    prof = _run_workload("none", tmp_path)
    flat = _flat(prof)
    assert "user:phase" in flat and flat["user:phase"]["visits"] == 1
    assert not any(".outer" in k for k in flat)  # no automatic events


def test_user_region_nesting_under_profile(tmp_path):
    d = str(tmp_path / "nest")
    rmon.init(instrumenter="profile", run_dir=d)

    def work():
        return 1

    with rmon.region("outer_phase"):
        with rmon.region("inner_phase"):
            work()
    out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    tree = _thread0(prof)["calltree"]

    def find(node, name):
        if node["name"].endswith(name):
            return node
        for ch in node["children"]:
            got = find(ch, name)
            if got:
                return got
        return None

    outer = find(tree, "user:outer_phase")
    assert outer is not None
    inner = find(outer, "user:inner_phase")
    assert inner is not None, "inner region must nest under outer"
    assert find(inner, ":work") or find(inner, "work")
    assert outer["incl_ns"] >= inner["incl_ns"]


@pytest.mark.parametrize("instrumenter", ["profile", "sampling"])
def test_stale_worker_thread_callback_self_removes(tmp_path, instrumenter):
    """Regression: uninstall only clears the hook on the calling thread
    (``sys.setprofile(None)``); a worker thread that outlives the
    measurement used to keep its closure and append into already-drained
    buffers.  The generation flag makes stale callbacks self-remove."""
    d = str(tmp_path / f"stale-{instrumenter}")
    m = rmon.init(instrumenter=instrumenter, run_dir=d, sampling_period=1)
    stop = threading.Event()
    hooks = []

    def worker():
        def tick():
            return 1

        while not stop.is_set():
            tick()
            hooks.append(sys.getprofile())
            time.sleep(0.001)

    th = threading.Thread(target=worker)
    th.start()
    try:
        deadline = time.time() + 10
        while time.time() < deadline and not any(h is not None for h in hooks):
            time.sleep(0.005)
        assert any(h is not None for h in hooks), "worker never got the hook"

        rmon.finalize()

        # the stale callback must self-remove on the worker's next event
        while time.time() < deadline and (not hooks or hooks[-1] is not None):
            time.sleep(0.005)
        assert hooks and hooks[-1] is None, "stale callback survived finalize"

        # and buffers must stop growing (no appends into drained buffers,
        # no threshold flushes into closed substrates)
        sizes = [len(b) for b in m._buffers]
        time.sleep(0.05)
        assert [len(b) for b in m._buffers] == sizes
    finally:
        stop.set()
        th.join()


def test_sampling_enter_path_flushes_at_threshold(tmp_path):
    """Regression: the sampled-enter branch must honor flush_threshold too.

    It used to flush only on exits, so an enter-heavy phase (deep recursion:
    hundreds of enters before the first return) grew the live buffer far past
    the threshold — unbounded memory on pathological call shapes."""
    d = str(tmp_path / "flushsym")
    m = rmon.init(
        instrumenter="sampling",
        run_dir=d,
        sampling_period=1,
        flush_threshold=64,
        # no substrates: a 600-deep call tree is a buffer-bound test, not a
        # profile-replay one (tree_dict would recurse past the stack limit)
        substrates=(),
    )
    peak = []

    def deep(k):
        if k == 0:
            # at the recursion base ~600 sampled enters have been appended
            # with zero exits in between
            peak.append(max(len(b) for b in m._buffers))
            return 0
        return deep(k - 1) + 1

    try:
        assert deep(600) == 600
    finally:
        rmon.finalize()
    assert peak and peak[0] <= 64 + 8  # bounded by the threshold, not ~600


def test_generator_balance_under_profile(tmp_path):
    # setprofile fires return on yield and call on resume; profiles must stay
    # balanced through generator suspension.
    d = str(tmp_path / "gen")
    rmon.init(instrumenter="profile", run_dir=d)

    def gen():
        for i in range(5):
            yield i

    assert sum(gen()) == 10
    out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    t0 = _thread0(prof)
    assert t0["mismatched_exits"] == 0
    g = [v for k, v in _flat(prof).items() if k.endswith(".gen")]
    assert g and g[0]["visits"] == 6  # 5 yields + final StopIteration return
