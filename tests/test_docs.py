"""Documentation surface tests — docs can't drift from the code.

Three gates:
  * docs/CLI.md must be byte-identical to a fresh render of the live
    argparse parsers (repro.core.clidoc).
  * every public name in ``repro.core.__all__`` must carry a real
    docstring (or, for plain data objects, live in a documented module).
  * README.md / docs/ARTIFACTS.md must keep documenting the artifacts and
    flows they advertise (artifact names, schema-version policy, the
    quickstart command CI executes).
"""

import inspect
import os

import pytest

import repro.core as rmon

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(*parts):
    path = os.path.join(REPO, *parts)
    assert os.path.exists(path), f"missing documentation file {path}"
    with open(path, encoding="utf-8") as fh:
        return fh.read()


# -- generated CLI docs -------------------------------------------------------


def test_cli_md_in_sync():
    pytest.importorskip("jax")  # the launch parsers import jax at module level
    from repro.core.clidoc import generate

    on_disk = _read("docs", "CLI.md")
    assert on_disk == generate(), (
        "docs/CLI.md is stale — regenerate with "
        "`PYTHONPATH=src python -m repro.core.clidoc`"
    )


# -- docstring coverage on the public API -------------------------------------


def test_public_api_docstrings():
    missing = []
    for name in rmon.__all__:
        obj = getattr(rmon, name)
        if inspect.isclass(obj) or inspect.isfunction(obj):
            doc = inspect.getdoc(obj)
            if not doc or len(doc) < 20:
                missing.append(name)
        else:
            # Plain data objects (registries, constants) can't carry their
            # own docstring — the package module exposing them must be
            # documented instead (repro.core always is; this guards against
            # future undocumented data exports).
            if not (rmon.__doc__ or "").strip():
                missing.append(name)
    assert not missing, f"public API names lacking docstrings: {missing}"


def test_artifact_contract_module_docstrings():
    """The modules owning artifact schemas must state their contracts."""
    import repro.core.analysis
    import repro.core.governor
    import repro.core.measurement
    import repro.core.memsys.substrate
    import repro.core.merge
    import repro.core.report
    import repro.core.schema
    import repro.core.substrates

    for module, needle in [
        (repro.core.measurement, "region"),
        (repro.core.substrates, "profile.json"),
        (repro.core.memsys.substrate, "memory.json"),
        (repro.core.governor, "governor.json"),
        (repro.core.merge, "merge"),
        (repro.core.report, "report"),
        (repro.core.schema, "report_schema_version"),
        (repro.core.analysis, "exit code 2"),
    ]:
        doc = module.__doc__ or ""
        assert len(doc) > 100, f"{module.__name__} needs a contract docstring"
        assert needle in doc, f"{module.__name__} docstring must mention {needle!r}"


# -- hand-written docs keep their promises ------------------------------------


def test_artifacts_md_documents_every_artifact():
    doc = _read("docs", "ARTIFACTS.md")
    for artifact in (
        "profile.json",
        "memory.json",
        "metrics.json",
        "governor.json",
        "meta.json",
        "defs.json",
        "merged_trace_summary.json",
        "static_plan.json",
        "report.html",
        "report_schema_version",
    ):
        assert artifact in doc, f"docs/ARTIFACTS.md must document {artifact}"
    from repro.core.schema import REPORT_SCHEMA_VERSION

    assert f"version is **{REPORT_SCHEMA_VERSION}**" in doc, (
        "docs/ARTIFACTS.md must state the current report_schema_version "
        "(update the doc when bumping repro.core.schema.REPORT_SCHEMA_VERSION)"
    )


def test_readme_advertises_executable_flows():
    readme = _read("README.md")
    # The quickstart command CI actually executes, verbatim.
    assert "examples/quickstart.py" in readme
    assert "repro.scorep" in readme
    assert "analysis report" in readme
    # Links into the docs tree.
    assert "docs/ARTIFACTS.md" in readme and "docs/CLI.md" in readme
