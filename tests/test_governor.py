"""Overhead-governor tests: calibration, escalation ladder, verdict
invalidation, live period mutation, instrumenter swap, artifact contract,
and the cross-rank merge section."""

import json
import os

import pytest

import repro.core as rmon
from repro.core.analysis import (
    render_governor,
    render_merge_summary,
    suggest_filter_from_profile,
)
from repro.core.filtering import Filter
from repro.core.governor import Calibration, calibrate, load_governor
from repro.core.measurement import Measurement, MeasurementConfig
from repro.core.merge import governor_summary


def _hot(n):
    def inner(x):
        return x + 1

    x = 0
    for _ in range(n):
        x = inner(x)
    return x


def _governed_run(tmp_path, name, budget=0.02, n=120_000, filter_spec=""):
    d = str(tmp_path / name)
    cfg = MeasurementConfig(
        instrumenter="profile",
        substrates=("profiling",),
        run_dir=d,
        flush_threshold=2048,
        budget=budget,
        filter_spec=filter_spec,
    )
    m = Measurement(cfg)
    m.start()
    try:
        _hot(n)
    finally:
        m.finalize()
    return m


# -- calibration -------------------------------------------------------------


def test_calibration_shape_and_cache():
    cal = calibrate("profile", calls=500, repeats=2, use_cache=False)
    assert isinstance(cal, Calibration)
    assert cal.cost_full_ns >= 0 and cal.cost_filtered_ns >= 0
    assert cal.sampling_sampled_ns >= cal.sampling_base_ns >= 0
    assert cal.adaptive_sample_ns >= 0  # 0.0 when sys.monitoring is absent
    assert cal.probe_s > 0
    # second call with the same key hits the process-wide cache
    again = calibrate("profile", calls=500, repeats=2)
    assert again is calibrate("profile", calls=500, repeats=2)


def test_calibration_none_is_free():
    cal = calibrate("none", calls=100, repeats=1, use_cache=False)
    assert cal.cost_full_ns == 0.0 and cal.sampling_base_ns == 0.0


# -- instrumenter hooks ------------------------------------------------------


def test_downgrade_ladder_declared():
    import sys

    from repro.core.instrumenters import INSTRUMENTERS

    assert INSTRUMENTERS["trace"].downgrade_to == "profile"
    assert INSTRUMENTERS["profile"].downgrade_to == "sampling"
    assert INSTRUMENTERS["monitoring"].downgrade_to == "sampling"
    # the adaptive rung needs PEP 669; without it the sampler drops to none
    if hasattr(sys, "monitoring"):
        assert INSTRUMENTERS["sampling"].downgrade_to == "adaptive"
    else:
        assert INSTRUMENTERS["sampling"].downgrade_to == "none"
    assert INSTRUMENTERS["adaptive"].downgrade_to == "none"
    assert INSTRUMENTERS["none"].downgrade_to is None
    # the zero-cost filtered tier is the PEP 669 family only
    assert INSTRUMENTERS["monitoring"].zero_cost_filtered
    assert INSTRUMENTERS["adaptive"].zero_cost_filtered
    assert not INSTRUMENTERS["profile"].zero_cost_filtered
    assert not INSTRUMENTERS["trace"].zero_cost_filtered
    assert not INSTRUMENTERS["sampling"].zero_cost_filtered


def test_sampling_set_period_live(tmp_path):
    """set_period must reach already-built per-thread callbacks (the period
    lives in a shared cell read on countdown reset)."""
    d = str(tmp_path / "period")
    m = rmon.init(
        instrumenter="sampling", sampling_period=2, run_dir=d,
        substrates=("profiling",),
    )
    _hot(2000)
    assert m.instrumenter.set_period(10**6) is True
    assert m.instrumenter.cost_multiplier() == float(10**6)
    before = sum(len(b) for b in m._buffers) + sum(b.n_flushed for b in m._buffers)
    _hot(2000)
    after = sum(len(b) for b in m._buffers) + sum(b.n_flushed for b in m._buffers)
    rmon.finalize()
    # at period 2 the first loop sampled ~1000 calls; at period 1e6 the
    # second loop may sample at most a couple
    assert before > 500
    assert after - before < 10


def test_non_sampling_instrumenters_reject_set_period():
    from repro.core.instrumenters import make_instrumenter

    for name in ("profile", "trace", "none"):
        assert make_instrumenter(name).set_period(10) is False


def test_swap_instrumenter_mid_run(tmp_path):
    d = str(tmp_path / "swap")
    cfg = MeasurementConfig(
        instrumenter="profile", substrates=("profiling",), run_dir=d,
    )
    m = Measurement(cfg)
    m.start()
    try:
        _hot(100)
        m.swap_instrumenter("sampling", period=5)
        assert m.instrumenter.name == "sampling"
        assert m.config.instrumenter == "sampling"
        _hot(100)
    finally:
        m.finalize()
    with open(os.path.join(d, "meta.json")) as fh:
        assert json.load(fh)["instrumenter"] == "sampling"


# -- governed measurement (end to end) ---------------------------------------


def test_governed_run_excludes_hot_region_and_roundtrips(tmp_path):
    m = _governed_run(tmp_path, "gov")
    doc = load_governor(str(tmp_path / "gov"))
    assert doc is not None
    assert doc["actions"], "governor took no action on a hot loop"
    kinds = {s["kind"] for a in doc["actions"] for s in a["steps"]}
    assert "exclude_regions" in kinds
    # the hot inner function was excluded and its cached verdict invalidated
    excluded = [
        r for a in doc["actions"] for s in a["steps"]
        if s["kind"] == "exclude_regions" for r in s["regions"]
    ]
    assert any("_hot.<locals>.inner" in r for r in excluded)
    # suggested filter round-trips and keeps the hot region out
    spec = doc["suggested_filter"]
    flt = Filter.from_spec(spec)
    assert flt.exclude or flt.runtime_exclude
    assert not flt.decide(_hot.__module__, "_hot.<locals>.inner", __file__)
    # applying the suggested spec to a re-run reduces the event rate
    m2 = _governed_run(tmp_path, "ungov", budget=0.0, n=20_000)
    m3 = _governed_run(tmp_path, "filtered", budget=0.0, n=20_000, filter_spec=spec)
    with open(os.path.join(m2.run_dir, "meta.json")) as fh:
        ev_plain = json.load(fh)["events_flushed"]
    with open(os.path.join(m3.run_dir, "meta.json")) as fh:
        ev_filtered = json.load(fh)["events_flushed"]
    assert ev_filtered < 0.5 * ev_plain
    # estimate + calibration sections are present and well-formed
    assert doc["estimate"]["elapsed_ns"] > 0
    assert doc["calibration"]["instrumenter"] == "profile"
    assert isinstance(doc["estimate"]["under_budget"], bool)


def test_governed_run_never_excludes_user_regions(tmp_path):
    d = str(tmp_path / "user")
    cfg = MeasurementConfig(
        instrumenter="profile", substrates=("profiling",), run_dir=d,
        flush_threshold=1024, budget=0.001,
    )
    m = Measurement(cfg)
    m.start()
    try:
        for _ in range(20_000):
            with m.region("tiny_step"):
                pass
    finally:
        m.finalize()
    doc = load_governor(d)
    excluded = [
        r for a in doc["actions"] for s in a["steps"]
        if s["kind"] == "exclude_regions" for r in s["regions"]
    ]
    assert not any("tiny_step" in r for r in excluded)
    assert "tiny_step" not in doc["suggested_filter"]


def test_budget_env_and_cli_roundtrip():
    cfg = MeasurementConfig(budget=0.07)
    env = cfg.to_env()
    assert env["REPRO_MONITOR_BUDGET"] == "0.07"
    back = MeasurementConfig.from_env(env)
    assert back.budget == 0.07
    from repro.core.bootstrap import build_parser

    ns = build_parser().parse_args(["--budget", "0.05", "target.py"])
    assert ns.budget == 0.05


def test_adaptive_rate_env_and_cli_roundtrip():
    cfg = MeasurementConfig(adaptive_rate=1234.0)
    env = cfg.to_env()
    assert env["REPRO_MONITOR_ADAPTIVE_RATE"] == "1234.0"
    back = MeasurementConfig.from_env(env)
    assert back.adaptive_rate == 1234.0
    from repro.core.bootstrap import build_parser

    ns = build_parser().parse_args(
        ["--instrumenter", "adaptive", "--adaptive-rate", "800", "target.py"]
    )
    assert ns.instrumenter == "adaptive"
    assert ns.adaptive_rate == 800.0


def test_budget_zero_disables_governor(tmp_path):
    m = _governed_run(tmp_path, "off", budget=0.0, n=1000)
    assert m.governor is None
    assert not os.path.exists(os.path.join(m.run_dir, "governor.json"))


# -- analysis / merge --------------------------------------------------------


def test_render_governor_and_suggest_filter(tmp_path):
    _governed_run(tmp_path, "render")
    doc = load_governor(str(tmp_path / "render"))
    text = render_governor(doc)
    assert "budget" in text and "final instrumenter" in text
    # profile-based heuristic (no governor artifact needed)
    profile = {
        "flat": {
            "app:hot_leaf": {"visits": 100_000, "excl_ns": 50_000_000},
            "app:long_phase": {"visits": 3, "excl_ns": 9_000_000_000},
            "user:step": {"visits": 100_000, "excl_ns": 1_000_000},
            "train:tok": {"visits": 100_000, "excl_ns": 1_000_000, "kind": "user"},
        }
    }
    spec = suggest_filter_from_profile(profile)
    flt = Filter.from_spec(spec)
    assert not flt.decide("app", "hot_leaf", "x.py")  # hot+short: filtered
    assert flt.decide("app", "long_phase", "x.py")  # long: kept
    assert flt.decide("user", "step", "x.py")  # user regions: kept
    assert flt.decide("train", "tok", "x.py")  # user kind under any module: kept


def test_merge_governor_summary(tmp_path):
    docs = [
        {
            "budget": 0.05,
            "actions": [{"steps": [{"kind": "exclude_regions"}]}],
            "final_instrumenter": {"name": "sampling", "period": 194},
            "estimate": {"overhead_fraction": 0.03, "under_budget": True},
            "suggested_filter": "exclude:app.hot",
        },
        {
            "budget": 0.05,
            "actions": [
                {"steps": [{"kind": "exclude_regions"}]},
                {"steps": [{"kind": "downgrade_instrumenter"}]},
            ],
            "final_instrumenter": {"name": "none", "period": None},
            "estimate": {"overhead_fraction": 0.09, "under_budget": False},
            "suggested_filter": "exclude:app.hot,app.other",
        },
    ]
    entries = []
    for rank, doc in enumerate(docs):
        d = tmp_path / f"r{rank}"
        d.mkdir()
        with open(d / "governor.json", "w") as fh:
            json.dump(doc, fh)
        entries.append({"pid": rank, "run_dir": str(d)})
    summary = governor_summary(entries)
    assert summary["actions_total"] == 3
    assert summary["ranks_over_budget"] == 1
    merged = Filter.from_spec(summary["suggested_filter"]).exclude
    assert set(merged) == {"app.hot", "app.other"}
    assert summary["ranks"][0]["final_instrumenter"] == "sampling/p194"
    # renders without KeyError and mentions the governor section
    text = render_merge_summary({"ranks": [], "governor": summary})
    assert "governor:" in text
    # absent governor.json -> section omitted
    empty = tmp_path / "nogov"
    empty.mkdir()
    assert governor_summary([{"pid": 0, "run_dir": str(empty)}]) is None
