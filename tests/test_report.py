"""Unified HTML report (repro.core.report) tests.

Covers the acceptance contract: a run dir yields one self-contained
report.html (no network references) joining time + memory + governor
sections, ``--diff`` renders regression deltas, and the embedded JSON
payload round-trips byte-exactly against the data model.
"""

import json
import os

import pytest

import repro.core as rmon
from repro.core.analysis import MissingArtifact, main as analysis_main
from repro.core.report import (
    REPORT_SCHEMA_VERSION,
    build_report,
    extract_payload,
    render_report,
    write_report,
)
from repro.core.schema import SCHEMA_KEY
from repro.core.topology import ProcessTopology


def _leaf(n):
    return sum(range(n))


def _work(iters):
    for _ in range(iters):
        _leaf(400)


def _make_run(tmp_path, name, iters=30, rank=None, world=1, **cfg):
    d = str(tmp_path / name)
    kwargs = dict(
        instrumenter="profile",
        substrates=("profiling", "tracing", "metrics", "memory"),
        run_dir=d,
        experiment=name,
        memory_period=0.01,
    )
    if rank is not None:
        kwargs["topology"] = ProcessTopology(rank=rank, world_size=world)
    kwargs.update(cfg)
    rmon.init(**kwargs)
    with rmon.region("phase"):
        _work(iters)
    rmon.metric("test.value", float(iters))
    rmon.finalize()
    return d


# -- data model ---------------------------------------------------------------


def test_artifacts_carry_schema_version(tmp_path):
    run = _make_run(tmp_path, "stamped")
    for artifact in ("profile.json", "memory.json", "metrics.json", "meta.json"):
        with open(os.path.join(run, artifact)) as fh:
            doc = json.load(fh)
        assert doc[SCHEMA_KEY] == REPORT_SCHEMA_VERSION, artifact


def test_build_report_joins_time_and_memory(tmp_path):
    run = _make_run(tmp_path, "joined")
    doc = build_report(run)
    assert doc[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
    by_name = {r["region"]: r for r in doc["regions"]}
    leaf = next(r for n, r in by_name.items() if "_leaf" in n)
    # time columns from profile.json
    assert leaf["visits"] > 0 and leaf["excl_ns"] > 0
    # memory columns joined from memory.json (attribution may land on any
    # region, but the columns must be populated for at least one row)
    assert any(
        r["alloc_bytes"] is not None and r["alloc_bytes"] > 0
        for r in doc["regions"]
    )
    assert doc["memory"]["rss_peak_bytes"] > 0
    assert "test.value" in doc["metrics"]
    assert any(k.startswith("mem.") for k in doc["timelines"])
    # no governor ran
    assert doc["governor"] is None and doc["merge"] is None and doc["diff"] is None


def test_build_report_missing_dir_raises(tmp_path):
    empty = tmp_path / "nothing"
    empty.mkdir()
    with pytest.raises(MissingArtifact):
        build_report(str(empty))


# -- rendering ----------------------------------------------------------------


def test_report_payload_roundtrip(tmp_path):
    run = _make_run(tmp_path, "roundtrip")
    doc = build_report(run)
    page = render_report(doc)
    # byte-exact after a JSON normalization pass (tuples -> lists etc.)
    assert extract_payload(page) == json.loads(json.dumps(doc))


def test_report_self_contained(tmp_path):
    run = _make_run(tmp_path, "selfcontained")
    page = open(write_report(run)).read()
    for needle in ("https://", "http://", "cdn.", "@import", 'src="//'):
        assert needle not in page
    # joined sections actually rendered
    assert "Regions" in page and "Timelines" in page
    assert page.count("<svg") >= 1
    assert 'table class="sortable"' in page


def test_report_escapes_hostile_region_names(tmp_path):
    d = str(tmp_path / "hostile")
    rmon.init(instrumenter="none", substrates=("profiling",), run_dir=d,
              experiment="hostile")
    with rmon.region('</script><b>x'):
        _leaf(10)
    rmon.finalize()
    page = open(write_report(d)).read()
    # The hostile name must appear nowhere unescaped — neither in the HTML
    # body nor inside the embedded JSON payload.
    assert "</script><b>x" not in page
    assert extract_payload(page)  # payload still parses


def test_governor_section(tmp_path):
    run = _make_run(tmp_path, "governed", substrates=("profiling",), budget=0.5)
    doc = build_report(run)
    assert doc["governor"] is not None
    assert doc["governor"]["budget"] == 0.5
    page = render_report(doc)
    assert "Overhead governor" in page


# -- diff mode ----------------------------------------------------------------


def test_report_diff_mode(tmp_path):
    base = _make_run(tmp_path, "base", iters=5)
    cur = _make_run(tmp_path, "cur", iters=400)
    doc = build_report(cur, diff_base=base)
    rows = doc["diff"]["profile"]
    assert rows, "diff must produce rows"
    top = rows[0]
    assert top["delta_ns"] > 0  # cur is slower
    page = render_report(doc)
    assert "Run-vs-run diff" in page
    assert extract_payload(page)["diff"]["base"] == base


# -- merge root ---------------------------------------------------------------


def test_report_merge_root_heatmap(tmp_path):
    from repro.core.merge import merge_runs

    a = _make_run(tmp_path, "exp-r0", iters=10, rank=0, world=2)
    b = _make_run(tmp_path, "exp-r1", iters=80, rank=1, world=2)
    summary = merge_runs([a, b], str(tmp_path / "merged_trace.json"))
    assert summary[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
    profile = summary["profile"]
    assert profile["ranks"] == [0, 1]
    assert profile["regions"] and len(profile["excl_ns"]) == len(profile["regions"])
    assert profile["imbalance"], "two unequal ranks must show imbalance"
    with open(tmp_path / "merged_trace_summary.json", "w") as fh:
        json.dump(summary, fh)
    page = open(write_report(str(tmp_path))).read()
    assert "Cross-rank view" in page
    assert "Per-region exclusive time by rank" in page
    payload = extract_payload(page)
    assert payload["merge"]["profile"]["ranks"] == [0, 1]


# -- CLI + finalize wiring ----------------------------------------------------


def test_analysis_report_cli(tmp_path, capsys):
    run = _make_run(tmp_path, "cli")
    out = str(tmp_path / "custom.html")
    assert analysis_main(["report", run, "--out", out]) == 0
    assert os.path.exists(out)
    assert analysis_main(["report", str(tmp_path / "missing")]) == 2
    assert "error:" in capsys.readouterr().err


def test_analysis_report_smoke(tmp_path):
    out = str(tmp_path / "smoke.html")
    assert analysis_main(["report", "--smoke", "--out", out]) == 0
    assert os.path.exists(out)


def test_measurement_report_flag(tmp_path):
    run = _make_run(tmp_path, "atfinalize", report=True)
    path = os.path.join(run, "report.html")
    assert os.path.exists(path)
    payload = extract_payload(open(path).read())
    assert payload["regions"]


def test_report_config_env_roundtrip():
    from repro.core import MeasurementConfig

    cfg = MeasurementConfig(report=True)
    env = cfg.to_env()
    assert env["REPRO_MONITOR_REPORT"] == "1"
    assert MeasurementConfig.from_env(env).report is True
    assert MeasurementConfig.from_env({}).report is False


def test_launch_train_report_flag(tmp_path, monkeypatch):
    """`launch.train --report` outside a scorep session starts its own
    measurement and emits report.html at finalize (training stubbed out —
    the glue, not the model, is under test)."""
    pytest.importorskip("jax")
    import repro.launch.train as lt

    monkeypatch.setattr(lt, "train", lambda cfg, **kw: {"final_loss": 1.0})
    monkeypatch.setattr(lt, "get_smoke_config", lambda arch: object())
    monkeypatch.chdir(tmp_path)
    assert lt.main(["--arch", "stub", "--smoke", "--report"]) == 0
    runs = list((tmp_path / "repro-traces").glob("train-*"))
    assert runs, "launcher must have created its own run dir"
    assert (runs[0] / "report.html").exists()


def test_launch_train_report_flag_under_scorep(tmp_path, monkeypatch):
    """`launch.train --report` inside an active measurement (the scorep
    bootstrap case) flips the active config's report flag instead of
    nesting a second measurement."""
    pytest.importorskip("jax")
    import repro.launch.train as lt

    monkeypatch.setattr(lt, "train", lambda cfg, **kw: {"final_loss": 1.0})
    monkeypatch.setattr(lt, "get_smoke_config", lambda arch: object())
    d = str(tmp_path / "outer")
    rmon.init(instrumenter="profile", substrates=("profiling",), run_dir=d,
              experiment="outer")
    try:
        assert lt.main(["--arch", "stub", "--smoke", "--report"]) == 0
        assert rmon.active() is not None, "launcher must not finalize a measurement it doesn't own"
        assert rmon.active().config.report is True
    finally:
        rmon.finalize()
    assert os.path.exists(os.path.join(d, "report.html"))


def test_decimate_never_exceeds_cap():
    from repro.core.report.model import decimate

    for n in (479, 480, 481, 960, 1000):
        series = [[i, float(i)] for i in range(n)]
        out = decimate(series, max_points=240)
        assert len(out) <= 240, n
        assert out[-1] == series[-1], "final point must survive decimation"
        assert out[0] == series[0]


def test_newer_schema_version_is_reported(tmp_path):
    import warnings as warnings_mod

    run = _make_run(tmp_path, "fromfuture")
    prof_path = os.path.join(run, "profile.json")
    with open(prof_path) as fh:
        doc = json.load(fh)
    doc[SCHEMA_KEY] = REPORT_SCHEMA_VERSION + 1
    with open(prof_path, "w") as fh:
        json.dump(doc, fh)
    with warnings_mod.catch_warnings(record=True) as caught:
        warnings_mod.simplefilter("always")
        build_report(run)
    assert any("newer than this reader" in str(w.message) for w in caught)


def test_diff_mode_without_profiling_substrate(tmp_path):
    """Diff mode degrades per-half: runs recorded without profiling still
    report, with the profile half null and the memory half populated."""

    def mem_run(name):
        d = str(tmp_path / name)
        rmon.init(instrumenter="none", substrates=("metrics", "memory"),
                  run_dir=d, experiment=name, memory_period=0.01)
        _work(20)
        rmon.finalize()
        return d

    base, cur = mem_run("mbase"), mem_run("mcur")
    doc = build_report(cur, diff_base=base)
    assert doc["diff"]["profile"] is None
    assert doc["diff"]["memory"] is not None
    render_report(doc)  # must not raise


def test_all_nan_series_does_not_claim_timeline_slot(tmp_path):
    d = str(tmp_path / "nans")
    rmon.init(instrumenter="none", substrates=("metrics",), run_dir=d,
              experiment="nans")
    for _ in range(4):
        rmon.metric("bad.loss", float("nan"))
        rmon.metric("good.loss", 1.0)
    rmon.finalize()
    doc = build_report(d)
    assert "bad.loss" not in doc["timelines"]
    assert "good.loss" in doc["timelines"]


def test_smoke_report_cleans_up_run_dir(tmp_path):
    import glob as glob_mod

    from repro.core.analysis import smoke_report

    out = str(tmp_path / "smoke.html")
    before = set(glob_mod.glob(os.path.join(tempfile_dir(), "repro-report-smoke-*")))
    assert smoke_report(out_path=out) == out
    after = set(glob_mod.glob(os.path.join(tempfile_dir(), "repro-report-smoke-*")))
    assert after == before, "smoke must remove its throwaway run dir"


def tempfile_dir():
    import tempfile

    return tempfile.gettempdir()
