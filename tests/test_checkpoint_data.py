"""Checkpoint manager (fault tolerance) + data pipeline tests."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, MemmapCorpus, Prefetcher, SyntheticLM, host_shard


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree()
    mgr.save(5, tree, extras={"loss": 1.25})
    out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree))
    assert out is not None
    step, restored, extras = out
    assert step == 5 and extras["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.steps() == [3, 4]  # GC keeps the last 2


def test_checkpoint_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest checkpoint (simulated crash mid-write)
    os.remove(os.path.join(tmp_path, "step_2", "arr_0.npy"))
    out = mgr.restore_latest(tree)
    assert out is not None and out[0] == 1  # fell back to the previous valid


def test_checkpoint_atomicity_tmpdir_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = _tree()
    mgr.save(1, tree)
    # a stale .tmp dir (crash before rename) must not be listed
    os.makedirs(os.path.join(tmp_path, "step_9.tmp"))
    assert mgr.steps() == [1]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((4, 4)), "nested": {"b": jnp.zeros((10,), jnp.int32), "c": jnp.zeros((3,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


def test_checkpoint_reshard_on_load(tmp_path):
    """Elastic scaling: save unsharded, restore with a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"w": jnp.arange(16.0).reshape(16, 1)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    step, restored, _ = mgr.restore_latest(tree, shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# -- data ---------------------------------------------------------------------

def test_synthetic_deterministic_and_stateless():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=3)
    src1, src2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = src1.batch(17), src2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # different steps differ
    assert not np.array_equal(b1["tokens"], src1.batch(18)["tokens"])
    assert b1["tokens"].min() >= 1 and b1["tokens"].max() < 1000


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    MemmapCorpus.write(path, np.arange(10_000, dtype=np.int32) % 777)
    cfg = DataConfig(vocab=777, seq_len=64, global_batch=4, seed=0)
    corpus = MemmapCorpus(path, cfg)
    b = corpus.batch(0)
    assert b["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    np.testing.assert_array_equal(corpus.batch(5)["tokens"], corpus.batch(5)["tokens"])


def test_host_shard():
    batch = {"tokens": np.arange(32).reshape(8, 4)}
    s0 = host_shard(batch, 0, 2)["tokens"]
    s1 = host_shard(batch, 1, 2)["tokens"]
    assert s0.shape == (4, 4)
    np.testing.assert_array_equal(np.concatenate([s0, s1]), batch["tokens"])


def test_prefetcher_orders_steps():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2, seed=0)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src.batch, start_step=3, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
    finally:
        pf.close()
