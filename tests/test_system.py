"""End-to-end system tests: training driver, fault-tolerant restart
determinism, serving driver, monitoring integration."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as rmon
from repro.configs import get_smoke_config
from repro.launch.serve import serve
from repro.launch.train import train

CFG = dataclasses.replace(get_smoke_config("yi-34b"), chunked_loss_chunks=0)


def test_train_loop_reduces_loss(tmp_path):
    result = train(CFG, steps=30, global_batch=4, seq_len=64, lr=1e-3,
                   ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=10)
    assert result["final_loss"] is not None and np.isfinite(result["final_loss"])
    assert result["final_loss"] < result["first_loss"]  # synthetic dist is learnable
    assert result["straggler"]["observed"] == 30


def test_crash_restart_is_bitexact(tmp_path):
    """Fault tolerance: 12 straight steps == 6 steps + 'crash' + resume 6.

    Stateless (seed, step)-keyed data + checkpointed optimizer state makes
    the restarted run reproduce the uninterrupted one bit-for-bit."""
    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")
    r_full = train(CFG, steps=12, global_batch=4, seq_len=64, ckpt_dir=ck_a, ckpt_every=6)
    # same 12-step job, crashing right after the step-6 checkpoint...
    r_crashed = train(CFG, steps=12, global_batch=4, seq_len=64, ckpt_dir=ck_b,
                      ckpt_every=6, abort_at_step=6)
    assert r_crashed["aborted"]
    # ...a fresh invocation auto-resumes from step 6 and finishes the job
    r_resumed = train(CFG, steps=12, global_batch=4, seq_len=64, ckpt_dir=ck_b, ckpt_every=6)
    assert r_resumed["start_step"] == 6
    np.testing.assert_allclose(r_full["final_loss"], r_resumed["final_loss"], rtol=0, atol=0)
    # compare final checkpoints leaf-by-leaf
    from repro.checkpoint import CheckpointManager
    from repro.models import lm_init
    from repro.optim import adamw

    params = lm_init(jax.random.PRNGKey(0), CFG)
    state = {"params": params, "opt": adamw.init(params)}
    _, tree_a, _ = CheckpointManager(ck_a).restore_latest(state)
    _, tree_b, _ = CheckpointManager(ck_b).restore_latest(state)
    for a, b in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_serve_driver(tmp_path):
    cfg = get_smoke_config("recurrentgemma-2b")
    result = serve(cfg, batch=2, prompt_len=16, gen=8)
    assert result["finite"]
    assert result["generated"] == 8


def test_train_under_monitoring(tmp_path):
    """The paper's use case: the training loop runs under measurement and the
    profile contains the user regions + step metrics."""
    run_dir = str(tmp_path / "mon")
    rmon.init(instrumenter="none", substrates=("profiling", "metrics"), run_dir=run_dir)
    try:
        train(CFG, steps=6, global_batch=2, seq_len=32)
    finally:
        out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    assert "train:train_step" in prof["flat"]
    assert prof["flat"]["train:train_step"]["visits"] == 6
    with open(os.path.join(out, "metrics.json")) as fh:
        met = json.load(fh)
    assert met["metrics"]["train.loss"]["count"] == 6
    assert met["metrics"]["train.step_s"]["count"] == 6  # straggler watchdog feed
