"""Two-phase bootstrap tests — `python -m repro.scorep` subprocess runs."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

APP = """\
import sys

def compute(n):
    return sum(range(n))

def main():
    val = compute(1000)
    print("APP_RESULT", val)
    return val

if __name__ == "__main__":
    main()
    sys.exit(0)
"""


def _run_scorep(tmp_path, *args, app_args=(), app_src=APP, check=True):
    app = tmp_path / "app.py"
    app.write_text(app_src)
    run_dir = tmp_path / "run"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable,
        "-m",
        "repro.scorep",
        f"--run-dir={run_dir}",
        *args,
        str(app),
        *app_args,
    ]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=120)
    if check:
        assert proc.returncode == 0, proc.stderr[-2000:]
    return proc, str(run_dir)


def test_bootstrap_restart_and_artifacts(tmp_path):
    proc, run_dir = _run_scorep(tmp_path, "--instrumenter=profile", "--experiment=boot")
    assert "APP_RESULT 499500" in proc.stdout
    files = set(os.listdir(run_dir))
    assert {"defs.json", "meta.json", "profile.json", "profile.txt"} <= files
    with open(os.path.join(run_dir, "profile.json")) as fh:
        prof = json.load(fh)
    visits = {k: v["visits"] for k, v in prof["flat"].items()}
    assert visits.get("__main__:compute") == 1
    assert visits.get("__main__:main") == 1
    with open(os.path.join(run_dir, "meta.json")) as fh:
        meta = json.load(fh)
    assert meta["instrumenter"] == "profile"


def test_bootstrap_forwards_app_args(tmp_path):
    src = "import sys\nprint('ARGS', sys.argv[1:])\n"
    proc, _ = _run_scorep(tmp_path, "--instrumenter=none", app_args=["--x", "1"], app_src=src)
    assert "ARGS ['--x', '1']" in proc.stdout


def test_bootstrap_filter_flag(tmp_path):
    proc, run_dir = _run_scorep(
        tmp_path, "--instrumenter=profile", "--filter=include:__main__*"
    )
    with open(os.path.join(run_dir, "profile.json")) as fh:
        prof = json.load(fh)
    mods = {k.split(":")[0] for k in prof["flat"]}
    assert mods <= {"__main__", "user"}, mods


def test_bootstrap_propagates_exit_code(tmp_path):
    src = "import sys\nsys.exit(3)\n"
    proc, run_dir = _run_scorep(tmp_path, "--instrumenter=profile", app_src=src, check=False)
    assert proc.returncode == 3
    # measurement still finalized on the way out
    assert os.path.exists(os.path.join(run_dir, "profile.json"))


def test_bootstrap_no_restart_mode(tmp_path):
    proc, run_dir = _run_scorep(tmp_path, "--instrumenter=profile", "--no-restart")
    assert "APP_RESULT" in proc.stdout
    assert os.path.exists(os.path.join(run_dir, "profile.json"))


def test_bootstrap_trace_instrumenter_produces_lines(tmp_path):
    proc, run_dir = _run_scorep(tmp_path, "--instrumenter=trace")
    with open(os.path.join(run_dir, "profile.json")) as fh:
        prof = json.load(fh)
    t0 = list(prof["threads"].values())[0]
    assert sum(t0["lines_executed"].values()) > 0
