"""Shared-memory ring (repro.agent.ringbus): encoding, wraparound, drops,
reattach semantics, and corrupt-file errors."""

import os

import numpy as np
import pytest

from repro.agent.ringbus import (
    RECORD_DTYPE,
    RingError,
    RingReader,
    RingWriter,
    decode_records,
    defs_path_for,
    encode_columns,
    encode_metric,
    read_defs,
    write_defs,
)
from repro.core.buffer import COLUMNS, EV_ENTER, EV_EXIT


def _columns(kinds, regions, ts, auxs):
    cols = {name: np.asarray(v, dtype=dt) for (name, dt), v in zip(
        COLUMNS, (kinds, regions, ts, auxs))}
    return cols


def _pair_columns(n, region=3, t0=1000, dt=10):
    kinds, regions, ts, auxs = [], [], [], []
    t = t0
    for _ in range(n):
        kinds += [EV_ENTER, EV_EXIT]
        regions += [region, region]
        ts += [t, t + dt]
        auxs += [0, 0]
        t += 2 * dt
    return _columns(kinds, regions, ts, auxs)


# -- encode / decode ----------------------------------------------------------


def test_encode_decode_round_trip():
    cols = _pair_columns(5, region=7)
    rec = encode_columns(cols, stream=2)
    assert rec.dtype == RECORD_DTYPE
    assert len(rec) == 11  # header + 10 events
    batches, metrics = decode_records(rec)
    assert metrics == []
    assert len(batches) == 1
    stream, out = batches[0]
    assert stream == 2
    for name, _ in COLUMNS:
        np.testing.assert_array_equal(out[name], cols[name])


def test_metric_encode_decode_round_trip():
    rec = encode_metric(4, 123.5, 999)
    (batches, metrics) = decode_records(rec)
    assert batches == []
    assert metrics == [(4, 999, 123.5)]
    # f32 payload: large values round but survive with float32 precision
    _, m = decode_records(encode_metric(0, 1e12, 1))
    assert m[0][2] == pytest.approx(1e12, rel=1e-6)


def test_decode_skips_torn_tail():
    """A batch header whose body was cut off (writer died mid-copy) is
    skipped, not misattributed."""
    cols = _pair_columns(3)
    rec = encode_columns(cols)
    torn = rec[:4]  # header claims 6 events, only 3 present
    batches, metrics = decode_records(torn)
    assert batches == [] and metrics == []


def test_decode_interleaved_batches_and_metrics():
    spans = [
        encode_columns(_pair_columns(2), stream=0),
        encode_metric(1, 2.0, 50),
        encode_columns(_pair_columns(1, region=9), stream=1),
    ]
    batches, metrics = decode_records(np.concatenate(spans))
    assert [s for s, _ in batches] == [0, 1]
    assert metrics == [(1, 50, 2.0)]


def test_property_encode_decode_round_trip():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (requirements-dev)"
    )
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.integers(0, 5),              # kind
                st.integers(-1, 2**31 - 1),     # region (i4, -1 sentinel)
                st.integers(0, 2**63),          # t (u8)
                st.integers(0, 2**32 - 1),      # aux (u4)
            ),
            max_size=64,
        ),
        st.integers(0, 200),
    )
    def check(events, stream):
        cols = _columns(*(zip(*events) if events else ([], [], [], [])))
        rec = encode_columns(cols, stream=stream)
        batches, metrics = decode_records(rec)
        assert metrics == []
        assert len(batches) == 1
        out_stream, out = batches[0]
        assert out_stream == stream
        for name, _ in COLUMNS:
            np.testing.assert_array_equal(out[name], cols[name])

    check()


# -- ring transport -----------------------------------------------------------


def test_ring_wraparound_preserves_order(tmp_path):
    """Many batches through a tiny ring: every record crosses the wrap
    boundary eventually and still round-trips in order."""
    ring = str(tmp_path / "agent.ring")
    w = RingWriter(ring, capacity=64, rank=0)
    r = RingReader(ring)
    seen = []
    for i in range(100):
        cols = _columns([EV_ENTER, EV_EXIT], [i, i], [i, i + 1], [0, 0])
        assert w.publish(encode_columns(cols))
        batches, _ = decode_records(r.poll())
        seen += [int(c["region"][0]) for _, c in batches]
    assert seen == list(range(100))
    assert w.drops == 0
    w.close()
    r.close()


def test_ring_overrun_drops_whole_batches_and_counts(tmp_path):
    ring = str(tmp_path / "agent.ring")
    w = RingWriter(ring, capacity=32, rank=0)
    r = RingReader(ring)  # attached but deliberately not draining
    ok = w.publish(encode_columns(_pair_columns(10)))  # 21 records
    assert ok
    dropped = encode_columns(_pair_columns(10))
    assert not w.publish(dropped)  # 21 > 32 - 21 free: dropped whole
    assert w.drops == len(dropped)
    # The reader sees exactly the published batch, never a partial one.
    batches, _ = decode_records(r.poll())
    assert len(batches) == 1
    assert len(batches[0][1]["kind"]) == 20
    # Space freed by the drain: the next batch fits again.
    assert w.publish(encode_columns(_pair_columns(10)))
    assert w.drops == len(dropped)
    w.close()
    r.close()


def test_reader_reattach_resumes_at_newest(tmp_path):
    ring = str(tmp_path / "agent.ring")
    w = RingWriter(ring, capacity=256, rank=1)
    r1 = RingReader(ring)
    w.publish(encode_columns(_pair_columns(3)))
    assert len(r1.poll()) == 7
    r1.close()  # reader "crashes"
    w.publish(encode_columns(_pair_columns(5)))  # published while unread
    r2 = RingReader(ring)
    # Reattach snaps to the newest sequence: the unread backlog is skipped…
    assert len(r2.poll()) == 0
    # …but everything published from now on flows.
    w.publish(encode_columns(_pair_columns(2)))
    batches, _ = decode_records(r2.poll())
    assert len(batches) == 1 and len(batches[0][1]["kind"]) == 4
    assert r2.rank == 1
    w.close()
    assert r2.writer_closed
    r2.close()


def test_reader_errors_on_missing_or_corrupt_ring(tmp_path):
    with pytest.raises(RingError):
        RingReader(str(tmp_path / "nope.ring"))
    short = tmp_path / "short.ring"
    short.write_bytes(b"\x00" * 100)
    with pytest.raises(RingError):
        RingReader(str(short))
    bad = tmp_path / "bad.ring"
    bad.write_bytes(b"\xff" * 8192)
    with pytest.raises(RingError):
        RingReader(str(bad))
    # Valid header, file truncated below the declared capacity.
    ring = str(tmp_path / "trunc.ring")
    w = RingWriter(ring, capacity=1024)
    w.close()
    with open(ring, "r+b") as fh:
        fh.truncate(4096 + 17 * 10)
    with pytest.raises(RingError):
        RingReader(ring)


# -- definitions sidecar ------------------------------------------------------


def test_defs_sidecar_round_trip(tmp_path):
    ring = str(tmp_path / "agent.ring")
    path = defs_path_for(ring)
    assert os.path.dirname(path) == str(tmp_path)
    doc = {"meta": {"rank": 0}, "regions": [[0, "m:f", "py"]], "metrics": {"x": 0}}
    write_defs(path, doc)
    assert read_defs(path) == doc
    assert not os.path.exists(path + ".tmp")
    assert read_defs(str(tmp_path / "missing.json")) is None
