"""Unit tests for region interning and filter semantics."""

import pytest

from repro.core.filtering import Filter
from repro.core.regions import FILTERED, RegionRegistry


def test_filter_spec_roundtrip():
    f = Filter.from_spec("exclude:numpy.*,scipy;include:mypkg.*")
    assert f.exclude == ["numpy.*", "scipy"]
    assert f.include == ["mypkg.*"]
    f2 = Filter.from_spec(f.to_spec())
    assert f2.include == f.include and f2.exclude == f.exclude


def test_filter_bad_spec():
    with pytest.raises(ValueError):
        Filter.from_spec("badclause")
    with pytest.raises(ValueError):
        Filter.from_spec("allow:x")


def test_filter_semantics():
    f = Filter.from_spec("exclude:numpy.*")
    assert f.decide("mymod", "fn", "x.py")
    assert not f.decide("numpy.linalg", "solve", "x.py")
    # include re-admits from exclude
    f2 = Filter.from_spec("exclude:numpy.*;include:numpy.fft")
    assert f2.decide("numpy.fft", "fft", "x.py")
    assert not f2.decide("numpy.linalg", "solve", "x.py")
    # include-only acts as allow-list
    f3 = Filter.from_spec("include:mypkg.*")
    assert f3.decide("mypkg.sub", "fn", "x.py")
    assert not f3.decide("other", "fn", "x.py")


def test_filter_semantics_all_rule_combinations():
    """Score-P filter-file semantics per rule combination (regression for
    the drift where include rules acted as a global allow-list even with
    exclude rules present)."""
    # 1. no rules: everything recorded
    assert Filter.from_spec("").decide("anything", "fn", "x.py")
    # 2. exclude only: everything not excluded recorded
    f = Filter.from_spec("exclude:hot.*")
    assert not f.decide("hot.loop", "fn", "x.py")
    assert f.decide("cold", "fn", "x.py")
    # 3. include only: allow-list
    f = Filter.from_spec("include:mypkg.*")
    assert f.decide("mypkg.sub", "fn", "x.py")
    assert not f.decide("unrelated", "fn", "x.py")
    # 4. mixed: exclude first, include re-admits, everything else RECORDED
    f = Filter.from_spec("exclude:numpy.*;include:numpy.fft")
    assert not f.decide("numpy.linalg", "solve", "x.py")  # excluded
    assert f.decide("numpy.fft", "fft", "x.py")  # re-admitted
    assert f.decide("unrelated", "fn", "x.py")  # neither rule -> recorded


def test_filter_runtime_excludes():
    # Runtime excludes tighten and win over include re-admission...
    f = Filter.from_spec("exclude:numpy.*;include:numpy.fft")
    assert f.decide("numpy.fft", "fft", "x.py")
    assert f.add_runtime_excludes(["numpy.fft"]) == ["numpy.fft"]
    assert not f.decide("numpy.fft", "fft", "x.py")
    # ...deduplicate...
    assert f.add_runtime_excludes(["numpy.fft"]) == []
    # ...and must not flip an include-only spec out of allow-list mode.
    f2 = Filter.from_spec("include:mypkg.*")
    f2.add_runtime_excludes(["mypkg.hot"])
    assert not f2.decide("mypkg.hot", "fn", "x.py")
    assert not f2.decide("unrelated", "fn", "x.py")  # still an allow-list
    # Runtime excludes serialize under their own verb ("exclude!"), so the
    # round-trip preserves the exact semantics — allow-list included.
    f3 = Filter.from_spec(f2.to_spec())
    assert "mypkg.hot" in f3.runtime_exclude
    assert not f3.decide("mypkg.hot", "fn", "x.py")
    assert f3.decide("mypkg.keep", "fn", "x.py")
    assert not f3.decide("unrelated", "fn", "x.py")  # allow-list survived


def test_registry_refilter_invalidates_cached_verdicts():
    flt = Filter()
    reg = RegionRegistry(decide=flt.decide)
    code = compile("def f(): pass", "/app/hotmod.py", "exec")
    rid = reg.register_code(code, None)
    assert rid >= 0 and reg.by_code[code] == rid
    user = reg.register_user("phase", module="app")
    flt.add_runtime_excludes(["hotmod.*"])
    changed = reg.refilter()
    assert changed == [rid]
    assert reg.by_code[code] == FILTERED  # in-place: closures see it
    assert reg.register_code(code, None) == FILTERED  # re-register stays out
    assert reg.register_user("phase", module="app") == user  # untouched
    # region table stays dense (definitions are never removed)
    snap = reg.snapshot()
    assert [r["id"] for r in snap] == list(range(len(snap)))


def test_filter_never_records_self():
    f = Filter.from_spec("")
    assert not f.decide("repro.core.measurement", "region", "m.py")
    assert not f.decide("?", "cb", "/x/repro/core/buffer.py")


def test_registry_interning_and_snapshot():
    reg = RegionRegistry()
    rid_a = reg.register_user("phase_a")
    rid_b = reg.register_user("phase_b")
    assert rid_a != rid_b
    assert reg.register_user("phase_a") == rid_a  # interned
    snap = reg.snapshot()
    assert [r["id"] for r in snap] == list(range(len(snap)))  # dense, index==id
    assert snap[rid_a]["name"] == "phase_a"
    assert snap[rid_a]["kind"] == "user"


def test_registry_filter_verdict_cached():
    reg = RegionRegistry(decide=lambda module, name, file: not module.startswith("skipme"))
    rid = reg.register_user("x", module="skipme.sub")
    assert rid == FILTERED
    assert reg.register_user("y", module="keep") >= 0


def test_registry_register_code_frameless():
    reg = RegionRegistry()
    code = compile("def f(): pass", "/some/path/mymodule.py", "exec")
    rid = reg.register_code(code, None)
    assert rid >= 0
    assert reg.get(rid).module == "mymodule"
