"""Unit tests for region interning and filter semantics."""

import pytest

from repro.core.filtering import Filter
from repro.core.regions import FILTERED, RegionRegistry


def test_filter_spec_roundtrip():
    f = Filter.from_spec("exclude:numpy.*,scipy;include:mypkg.*")
    assert f.exclude == ["numpy.*", "scipy"]
    assert f.include == ["mypkg.*"]
    f2 = Filter.from_spec(f.to_spec())
    assert f2.include == f.include and f2.exclude == f.exclude


def test_filter_bad_spec():
    with pytest.raises(ValueError):
        Filter.from_spec("badclause")
    with pytest.raises(ValueError):
        Filter.from_spec("allow:x")


def test_filter_semantics():
    f = Filter.from_spec("exclude:numpy.*")
    assert f.decide("mymod", "fn", "x.py")
    assert not f.decide("numpy.linalg", "solve", "x.py")
    # include re-admits from exclude
    f2 = Filter.from_spec("exclude:numpy.*;include:numpy.fft")
    assert f2.decide("numpy.fft", "fft", "x.py")
    assert not f2.decide("numpy.linalg", "solve", "x.py")
    # include-only acts as allow-list
    f3 = Filter.from_spec("include:mypkg.*")
    assert f3.decide("mypkg.sub", "fn", "x.py")
    assert not f3.decide("other", "fn", "x.py")


def test_filter_never_records_self():
    f = Filter.from_spec("")
    assert not f.decide("repro.core.measurement", "region", "m.py")
    assert not f.decide("?", "cb", "/x/repro/core/buffer.py")


def test_registry_interning_and_snapshot():
    reg = RegionRegistry()
    rid_a = reg.register_user("phase_a")
    rid_b = reg.register_user("phase_b")
    assert rid_a != rid_b
    assert reg.register_user("phase_a") == rid_a  # interned
    snap = reg.snapshot()
    assert [r["id"] for r in snap] == list(range(len(snap)))  # dense, index==id
    assert snap[rid_a]["name"] == "phase_a"
    assert snap[rid_a]["kind"] == "user"


def test_registry_filter_verdict_cached():
    reg = RegionRegistry(decide=lambda module, name, file: not module.startswith("skipme"))
    rid = reg.register_user("x", module="skipme.sub")
    assert rid == FILTERED
    assert reg.register_user("y", module="keep") >= 0


def test_registry_register_code_frameless():
    reg = RegionRegistry()
    code = compile("def f(): pass", "/some/path/mymodule.py", "exec")
    rid = reg.register_code(code, None)
    assert rid >= 0
    assert reg.get(rid).module == "mymodule"
