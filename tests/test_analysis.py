"""Profile-diff analysis tool tests."""

import json

import pytest

import repro.core as rmon
from repro.core.analysis import diff_profiles, hotspots, render_diff


def _make_run(tmp_path, name, inner_iters):
    d = str(tmp_path / name)
    rmon.init(instrumenter="profile", run_dir=d, experiment=name)

    def hot_loop():
        total = 0
        for i in range(inner_iters):
            total += i
        return total

    def cold_once():
        return 1

    for _ in range(10):
        hot_loop()
    cold_once()
    rmon.finalize()
    return d


def test_diff_profiles_detects_regression(tmp_path):
    fast = _make_run(tmp_path, "fast", 100)
    slow = _make_run(tmp_path, "slow", 50_000)
    rows = diff_profiles(fast, slow)
    top = rows[0]
    assert "hot_loop" in top["region"]
    assert top["delta_ns"] > 0  # B (slow) is slower
    assert top["ratio"] > 2
    assert top["visits_a"] == top["visits_b"] == 10
    text = render_diff(rows)
    assert "hot_loop" in text and "region" in text


def test_hotspots(tmp_path):
    run = _make_run(tmp_path, "hot", 20_000)
    top = hotspots(run, top=5)
    assert any("hot_loop" in name for name, _ in top)
    # sorted descending by exclusive time
    excl = [v["excl_ns"] for _, v in top]
    assert excl == sorted(excl, reverse=True)


def test_analysis_cli(tmp_path, capsys):
    a = _make_run(tmp_path, "a", 100)
    b = _make_run(tmp_path, "b", 10_000)
    from repro.core.analysis import main

    assert main(["diff", a, b, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "region" in out
    assert main(["top", a]) == 0
