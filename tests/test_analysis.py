"""Profile-diff analysis tool tests."""

import json

import pytest

import repro.core as rmon
from repro.core.analysis import diff_profiles, hotspots, render_diff


def _make_run(tmp_path, name, inner_iters):
    d = str(tmp_path / name)
    rmon.init(instrumenter="profile", run_dir=d, experiment=name)

    def hot_loop():
        total = 0
        for i in range(inner_iters):
            total += i
        return total

    def cold_once():
        return 1

    for _ in range(10):
        hot_loop()
    cold_once()
    rmon.finalize()
    return d


def test_diff_profiles_detects_regression(tmp_path):
    fast = _make_run(tmp_path, "fast", 100)
    slow = _make_run(tmp_path, "slow", 50_000)
    rows = diff_profiles(fast, slow)
    top = rows[0]
    assert "hot_loop" in top["region"]
    assert top["delta_ns"] > 0  # B (slow) is slower
    assert top["ratio"] > 2
    assert top["visits_a"] == top["visits_b"] == 10
    text = render_diff(rows)
    assert "hot_loop" in text and "region" in text


def test_hotspots(tmp_path):
    run = _make_run(tmp_path, "hot", 20_000)
    top = hotspots(run, top=5)
    assert any("hot_loop" in name for name, _ in top)
    # sorted descending by exclusive time
    excl = [v["excl_ns"] for _, v in top]
    assert excl == sorted(excl, reverse=True)


def test_analysis_cli(tmp_path, capsys):
    a = _make_run(tmp_path, "a", 100)
    b = _make_run(tmp_path, "b", 10_000)
    from repro.core.analysis import main

    assert main(["diff", a, b, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "region" in out
    assert main(["top", a]) == 0


def test_missing_artifact_exit_codes_are_uniform(tmp_path, capsys):
    """Every subcommand pointed at a dir without its artifact follows one
    convention: one-line ``error:`` on stderr + exit code 2 (never a
    traceback, never a different code)."""
    from repro.core.analysis import main

    empty = tmp_path / "empty"
    empty.mkdir()
    for argv in (
        ["top", str(empty)],
        ["diff", str(empty), str(empty)],
        ["memory", str(empty)],
        ["memory-diff", str(empty), str(empty)],
        ["governor", str(empty)],
        ["suggest-filter", str(empty)],
        ["merge-summary", str(empty / "nope.json")],
        ["merge-summary", str(empty)],  # dir form: no summary inside
        ["report", str(empty)],
        ["plan", str(empty / "nope")],  # bad path: no such file
        ["plan", str(empty)],  # dir form: no Python sources inside
        ["lint", str(empty / "nope")],
        ["lint", str(empty)],
        ["concurrency", str(empty / "nope")],
        ["concurrency", str(empty)],  # dir form: no Python sources inside
        ["fleet", str(empty)],  # shorthand analyze: no runs under root
        ["fleet", "analyze", str(empty / "nope")],  # bad path: no such root
        ["fleet", "analyze"],  # no roots and no --smoke
        ["fleet", "gate", str(empty / "no-traj")],  # no trajectory dir
        ["fleet", "show", str(empty)],  # dir form: no fleet_summary.json
        ["fleet", "show", str(empty / "nope.json")],
    ):
        assert main(argv) == 2, argv
        err = capsys.readouterr().err
        assert err.startswith("error:"), (argv, err)

    # The agent CLI follows the same convention (missing/corrupt ring).
    from repro.agent.cli import main as agent_main

    corrupt = tmp_path / "corrupt"
    corrupt.mkdir()
    (corrupt / "agent.ring").write_bytes(b"not a ring header")
    for argv in (
        ["attach", str(empty)],  # dir without a ring
        ["attach", str(empty / "nope.ring")],  # no such file
        ["attach", str(corrupt)],  # truncated/bad-magic ring
    ):
        assert agent_main(argv) == 2, argv
        err = capsys.readouterr().err
        assert err.startswith("error:"), (argv, err)


def test_lint_exit_codes(tmp_path, capsys):
    """`analysis lint` follows the linter convention: 1 with violations,
    0 when clean (on top of the uniform exit-2 for bad paths)."""
    from repro.core.analysis import main

    clean = tmp_path / "clean.py"
    clean.write_text("def f():\n    return 1\n")
    assert main(["lint", str(clean)]) == 0
    assert "clean" in capsys.readouterr().out

    bad = tmp_path / "bad.py"
    bad.write_text("import sys\nsys.setprofile(print)\n")
    assert main(["lint", str(bad)]) == 1
    captured = capsys.readouterr()
    assert "SP201" in captured.out
    assert "violation" in captured.err


def test_concurrency_exit_codes(tmp_path, capsys):
    """`analysis concurrency` mirrors the lint convention: 1 with findings,
    0 when clean, and --smoke always 0 (artifact round-trip gate)."""
    from repro.core.analysis import main

    clean = tmp_path / "clean.py"
    clean.write_text("def f():\n    return 1\n")
    assert main(["concurrency", str(clean)]) == 0
    assert "clean" in capsys.readouterr().out

    bad = tmp_path / "bad.py"
    bad.write_text(
        "import threading\n"
        "def leak():\n"
        "    t = threading.Thread(target=print)\n"
        "    t.start()\n"
    )
    assert main(["concurrency", str(bad)]) == 1
    captured = capsys.readouterr()
    assert "SP405" in captured.out
    assert "finding" in captured.err

    out = tmp_path / "concurrency_plan.json"
    assert main(["concurrency", str(bad), "--out", str(out)]) == 1
    plan = json.loads(out.read_text())
    assert plan["rule_counts"].get("SP405") == 1

    assert main(["concurrency", str(bad), "--smoke"]) == 0
    assert "smoke OK" in capsys.readouterr().out


def test_plan_cli_writes_artifact(tmp_path, capsys):
    from repro.core.analysis import main

    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "__init__.py").write_text("")
    (pkg / "mod.py").write_text(
        "def tiny(x):\n    return x + 1\n"
        "def loop(n):\n    s = 0\n"
        "    for i in range(n):\n        s += tiny(i)\n    return s\n"
    )
    out = tmp_path / "static_plan.json"
    assert main(["plan", str(pkg), "--out", str(out)]) == 0
    assert "plan written to" in capsys.readouterr().out
    plan = json.loads(out.read_text())
    assert plan["functions"] == 2
    assert any("tiny" in p for p in plan["filter"]["patterns"])
    # --smoke without --out verifies the round-trip and writes nothing
    assert main(["plan", str(pkg), "--smoke"]) == 0
    assert "plan smoke OK" in capsys.readouterr().out


def test_merge_summary_accepts_directory(tmp_path, capsys):
    """`analysis merge-summary` takes either the JSON path or the merge
    root directory containing merged_trace_summary.json."""
    import json as json_mod

    from repro.core.analysis import main

    summary = {"ranks": [], "dropped_runs": [], "total_events": 0, "world_size": 1}
    path = tmp_path / "merged_trace_summary.json"
    path.write_text(json_mod.dumps(summary))
    assert main(["merge-summary", str(path)]) == 0
    assert main(["merge-summary", str(tmp_path)]) == 0
    assert "world_size" in capsys.readouterr().out


def test_merge_summary_corrupt_json_exits_2(tmp_path, capsys):
    from repro.core.analysis import main

    path = tmp_path / "merged_trace_summary.json"
    path.write_text("{not json")
    assert main(["merge-summary", str(path)]) == 2
    assert "error:" in capsys.readouterr().err


def test_corrupt_artifact_exits_2(tmp_path, capsys):
    """A truncated/corrupt artifact (crashed writer) follows the same
    exit-2 convention as a missing one — no tracebacks."""
    from repro.core.analysis import main

    run = tmp_path / "corrupt"
    run.mkdir()
    (run / "profile.json").write_text("{truncated")
    assert main(["top", str(run)]) == 2
    assert "error:" in capsys.readouterr().err

    # Fleet follows suit: a corrupt saved summary and a corrupt trajectory
    # snapshot both fail loud with the uniform exit 2.
    (run / "fleet_summary.json").write_text("{truncated")
    assert main(["fleet", "show", str(run)]) == 2
    assert capsys.readouterr().err.startswith("error:")
    traj = tmp_path / "traj" / "00000"
    traj.mkdir(parents=True)
    (traj / "bench.json").write_text("{truncated")
    assert main(["fleet", "gate", str(tmp_path / "traj")]) == 2
    assert capsys.readouterr().err.startswith("error:")
