"""PEP 669 DISABLE semantics — zero-cost filtered regions, tool-id hygiene,
refilter re-arming, and the adaptive epoch sampler.

Two tiers:

* **Stub tests** (run on every interpreter): a fake ``sys.monitoring`` is
  monkeypatched in and driven by hand, emulating the slice of PEP 669 the
  instrumenters use — tool ids, per-event callbacks, per-(code, event)
  DISABLE bookkeeping, ``restart_events``.  These pin down the protocol
  (what we return, when we re-arm, what uninstall must release) even on
  interpreters without the real thing.
* **Real tests** (gated on 3.12+): the same claims against the live
  interpreter — filtered locations fire at most once per epoch, runtime
  excludes go dark after a refilter, instrumenter swaps never leak a tool
  id, and a foreign profiler's id is never stolen.
"""

import json
import os
import sys
import time

import pytest

import repro.core as rmon
from repro.core.buffer import EV_ENTER, EV_EXIT, ListEventBuffer
from repro.core.instrumenters import make_instrumenter
from repro.core.instrumenters.adaptive import GROW_STREAK, AdaptiveInstrumenter
from repro.core.instrumenters.monitoring import _TOOL_NAME, acquire_tool_id
from repro.core.measurement import Measurement, MeasurementConfig
from repro.core.regions import FILTERED, RegionRegistry

needs_sys_monitoring = pytest.mark.skipif(
    not hasattr(sys, "monitoring"),
    reason="sys.monitoring (PEP 669) needs Python 3.12+",
)


# ---------------------------------------------------------------------------
# stub sys.monitoring
# ---------------------------------------------------------------------------


class _Events:
    PY_START = 1
    PY_RESUME = 2
    PY_RETURN = 4
    PY_YIELD = 8
    PY_UNWIND = 16


class StubMonitoring:
    """The slice of PEP 669 our instrumenters touch, driven by hand.

    ``fire`` dispatches like the interpreter: locations retired by a DISABLE
    return are skipped until ``restart_events`` clears them, and PY_UNWIND
    rejects DISABLE with ValueError exactly as CPython does.
    """

    DEBUGGER_ID = 0
    COVERAGE_ID = 1
    PROFILER_ID = 2
    OPTIMIZER_ID = 5

    def __init__(self):
        self.DISABLE = object()
        self.events = _Events()
        self._tools = {}
        self._callbacks = {}  # (tool_id, event) -> fn
        self._event_mask = {}  # tool_id -> int
        self._disabled = set()  # (code, event)
        self.restart_count = 0

    def use_tool_id(self, tool_id, name):
        if self._tools.get(tool_id) is not None:
            raise ValueError(f"tool id {tool_id} already in use")
        self._tools[tool_id] = name

    def free_tool_id(self, tool_id):
        self._tools.pop(tool_id, None)
        self._event_mask.pop(tool_id, None)

    def get_tool(self, tool_id):
        return self._tools.get(tool_id)

    def register_callback(self, tool_id, event, fn):
        if fn is None:
            self._callbacks.pop((tool_id, event), None)
        else:
            self._callbacks[(tool_id, event)] = fn

    def set_events(self, tool_id, mask):
        self._event_mask[tool_id] = mask

    def restart_events(self):
        self.restart_count += 1
        self._disabled.clear()

    # -- test driver --------------------------------------------------------

    def fire(self, event, code, *args):
        """Dispatch one event; returns True if any callback actually ran."""
        if (code, event) in self._disabled:
            return False
        fired = False
        for (tool_id, ev), fn in list(self._callbacks.items()):
            if ev != event or not self._event_mask.get(tool_id, 0) & event:
                continue
            out = fn(code, 0, *args)
            fired = True
            if out is self.DISABLE:
                if event == _Events.PY_UNWIND:
                    raise ValueError("cannot disable PY_UNWIND")
                self._disabled.add((code, event))
        return fired


class _Host:
    """Minimal measurement stand-in: a region registry + one buffer."""

    def __init__(self, decide=None):
        self.regions = RegionRegistry(decide=decide)
        self._buf = ListEventBuffer(thread_id=0, flush_threshold=1 << 30)

    def thread_buffer(self):
        return self._buf


@pytest.fixture
def stub(monkeypatch):
    s = StubMonitoring()
    monkeypatch.setattr(sys, "monitoring", s, raising=False)
    return s


def _code():
    def probe_fn():
        return 1

    return probe_fn.__code__


# ---------------------------------------------------------------------------
# tool-id acquisition (stub)
# ---------------------------------------------------------------------------


def test_acquire_tool_id_prefers_profiler_id(stub):
    tid = acquire_tool_id(stub, _TOOL_NAME)
    assert tid == stub.PROFILER_ID
    assert stub.get_tool(tid) == _TOOL_NAME


def test_acquire_tool_id_never_steals_a_foreign_tool(stub):
    stub.use_tool_id(stub.PROFILER_ID, "someone-else")
    tid = acquire_tool_id(stub, _TOOL_NAME)
    assert tid != stub.PROFILER_ID
    assert stub.get_tool(stub.PROFILER_ID) == "someone-else"
    assert stub.get_tool(tid) == _TOOL_NAME


def test_acquire_tool_id_raises_when_all_taken(stub):
    for i in range(6):
        stub.use_tool_id(i, f"hog-{i}")
    with pytest.raises(RuntimeError, match="no free sys.monitoring tool id"):
        acquire_tool_id(stub, _TOOL_NAME)
    # nothing was freed along the way
    assert all(stub.get_tool(i) == f"hog-{i}" for i in range(6))


# ---------------------------------------------------------------------------
# monitoring DISABLE protocol (stub)
# ---------------------------------------------------------------------------


def test_filtered_location_fires_once_per_epoch(stub):
    host = _Host(decide=lambda module, name, file: False)  # everything filtered
    inst = make_instrumenter("monitoring")
    inst.install(host)
    code = _code()
    try:
        assert stub.fire(_Events.PY_START, code)
        assert inst.filtered_calls() == 1
        # retired: no dispatch at all until the next epoch
        for _ in range(5):
            assert not stub.fire(_Events.PY_START, code)
        assert inst.filtered_calls() == 1
        assert host._buf.events == []
        stub.restart_events()  # new epoch: exactly one fresh hit
        assert stub.fire(_Events.PY_START, code)
        assert inst.filtered_calls() == 2
    finally:
        inst.uninstall()


def test_recorded_locations_stay_armed(stub):
    host = _Host()
    inst = make_instrumenter("monitoring")
    inst.install(host)
    code = _code()
    try:
        for _ in range(3):
            assert stub.fire(_Events.PY_START, code)
            assert stub.fire(_Events.PY_RETURN, code, None)
        kinds = [ev for ev, _, _, _ in host._buf.events]
        assert kinds == [EV_ENTER, EV_EXIT] * 3
    finally:
        inst.uninstall()


def test_refilter_rearms_then_newly_filtered_goes_dark(stub):
    allow = [True]
    host = _Host(decide=lambda module, name, file: allow[0])
    inst = make_instrumenter("monitoring")
    inst.install(host)
    code = _code()
    try:
        assert stub.fire(_Events.PY_START, code)
        assert host.regions.by_code[code] >= 0
        assert len(host._buf.events) == 1

        restarts_before = stub.restart_count
        allow[0] = False
        changed = host.regions.refilter()
        assert changed  # the verdict actually flipped
        assert host.regions.by_code[code] == FILTERED
        # the refilter hook re-armed every retired location
        assert stub.restart_count == restarts_before + 1

        # one fresh hit under the new verdict, then dark
        assert stub.fire(_Events.PY_START, code)
        assert inst.filtered_calls() == 1
        assert not stub.fire(_Events.PY_START, code)
        assert len(host._buf.events) == 1  # nothing new recorded
    finally:
        inst.uninstall()


def test_refilter_without_changes_does_not_rearm(stub):
    host = _Host()
    inst = make_instrumenter("monitoring")
    inst.install(host)
    try:
        stub.fire(_Events.PY_START, _code())
        before = stub.restart_count
        assert host.regions.refilter() == []
        assert stub.restart_count == before
    finally:
        inst.uninstall()


def test_uninstall_releases_tool_and_refilter_hook(stub):
    allow = [True]
    host = _Host(decide=lambda module, name, file: allow[0])
    inst = make_instrumenter("monitoring")
    inst.install(host)
    code = _code()
    stub.fire(_Events.PY_START, code)
    inst.uninstall()

    assert stub._tools == {}  # tool id freed
    assert stub._callbacks == {}  # every callback deregistered
    assert inst._tool_id is None
    # the refilter hook is gone: tightening the filter no longer re-arms
    before = stub.restart_count
    allow[0] = False
    assert host.regions.refilter()
    assert stub.restart_count == before


# ---------------------------------------------------------------------------
# adaptive epoch sampler (stub / direct callbacks)
# ---------------------------------------------------------------------------


def test_adaptive_samples_once_then_backs_off(stub):
    host = _Host()
    inst = AdaptiveInstrumenter()
    on_start, on_return, _ = inst._make_callbacks(host)
    code = _code()

    # every start retires its location; an epoch boundary is simply "the
    # interpreter dispatches again", i.e. the next direct call here
    enters = []
    for _ in range(12):
        assert on_start(code, 0) is stub.DISABLE
        enters.append(len(host._buf.events))

    # streak of GROW_STREAK sampled epochs doubles the per-code period, so
    # later epochs are skipped entirely (no event appended)
    assert enters[:GROW_STREAK] == list(range(1, GROW_STREAK + 1))
    assert enters[-1] < 12
    assert inst.sampled_calls() == enters[-1]


def test_adaptive_balances_sampled_enters(stub):
    host = _Host()
    inst = AdaptiveInstrumenter()
    on_start, on_return, on_unwind = inst._make_callbacks(host)
    code = _code()

    assert on_start(code, 0) is stub.DISABLE
    # matching return records the exit and retires the return location
    assert on_return(code, 0, None) is stub.DISABLE
    kinds = [ev for ev, _, _, _ in host._buf.events]
    assert kinds == [EV_ENTER, EV_EXIT]
    # nothing pending: a bare return goes dark without recording
    assert on_return(code, 0, None) is stub.DISABLE
    assert len(host._buf.events) == 2
    # unwind balances like a return but must not return DISABLE (PY_UNWIND
    # is not locally disableable)
    assert on_start(code, 0) is stub.DISABLE
    assert on_unwind(code, 0, None) is None
    kinds = [ev for ev, _, _, _ in host._buf.events]
    assert kinds == [EV_ENTER, EV_EXIT, EV_ENTER, EV_EXIT]


def test_adaptive_filtered_location_counts_once(stub):
    host = _Host(decide=lambda module, name, file: False)
    inst = AdaptiveInstrumenter()
    on_start, _, _ = inst._make_callbacks(host)
    code = _code()
    assert on_start(code, 0) is stub.DISABLE
    assert inst.filtered_calls() == 1
    assert host._buf.events == []


def test_adaptive_lifecycle_controller_and_cleanup(stub):
    host = _Host()
    inst = AdaptiveInstrumenter(interval=0.002)
    inst.install(host)
    try:
        assert stub.get_tool(inst._tool_id) == _TOOL_NAME
        assert inst._controller is not None and inst._controller.is_alive()
        # the controller drives epochs: restart_events keeps firing
        deadline = time.time() + 5
        baseline = stub.restart_count  # install itself restarts once
        while time.time() < deadline and stub.restart_count < baseline + 3:
            time.sleep(0.005)
        assert stub.restart_count >= baseline + 3, "controller never re-armed"
    finally:
        inst.uninstall()
    assert inst._controller is None
    assert inst._tool_id is None
    assert stub._tools == {}
    assert stub._callbacks == {}
    assert host.regions._refilter_hooks == []


def test_adaptive_rejects_bad_parameters():
    with pytest.raises(ValueError):
        AdaptiveInstrumenter(target_rate=0)
    with pytest.raises(ValueError):
        AdaptiveInstrumenter(interval=10.0)


def test_refilter_hook_add_remove_is_idempotent():
    reg = RegionRegistry()
    calls = []
    hook = calls.append  # bound method: equality-stable
    reg.add_refilter_hook(hook)
    reg.add_refilter_hook(hook)  # dedup
    assert reg._refilter_hooks == [hook]
    reg.remove_refilter_hook(hook)
    reg.remove_refilter_hook(hook)  # no-op, no raise
    assert reg._refilter_hooks == []


# ---------------------------------------------------------------------------
# real interpreter (3.12+)
# ---------------------------------------------------------------------------


@needs_sys_monitoring
def test_real_filtered_callback_fires_once_per_epoch(tmp_path):
    d = str(tmp_path / "real-epoch")
    m = rmon.init(
        instrumenter="monitoring",
        run_dir=d,
        substrates=("profiling",),
        filter_spec="exclude:test_monitoring_disable.*",
    )
    try:

        def blocked():
            return 1

        for _ in range(500):
            blocked()
        first = m.instrumenter.filtered_calls()
        assert first >= 1
        for _ in range(500):
            blocked()
        second = m.instrumenter.filtered_calls()
        # every filtered location was retired on its first hit: 500 more
        # calls add at most a handful of new locations, not ~500 counts
        assert second - first <= 5
        sys.monitoring.restart_events()  # a new epoch re-arms each location once
        for _ in range(500):
            blocked()
        third = m.instrumenter.filtered_calls()
        assert 1 <= third - second <= 20
    finally:
        rmon.finalize()


@needs_sys_monitoring
def test_real_runtime_exclude_goes_dark_after_refilter(tmp_path):
    d = str(tmp_path / "real-refilter")
    m = rmon.init(instrumenter="monitoring", run_dir=d, substrates=("profiling",))
    try:

        def hot():
            return 1

        for _ in range(200):
            hot()
        assert m.regions.by_code[hot.__code__] >= 0

        m.filter.add_runtime_excludes(["test_monitoring_disable.*hot"])
        changed = m.regions.refilter()
        assert changed
        assert m.regions.by_code[hot.__code__] == FILTERED

        before = m.instrumenter.filtered_calls()
        for _ in range(1000):
            hot()
        after = m.instrumenter.filtered_calls()
        # re-armed by the refilter hook: hot fires again at least once under
        # the new verdict...
        assert after > before
        # ...but DISABLE retires it — a per-call cost would add >= 1000
        assert after - before < 500
    finally:
        rmon.finalize()


@needs_sys_monitoring
def test_real_swap_instrumenter_leaves_no_tool_behind(tmp_path):
    mon = sys.monitoring

    def repro_ids():
        return [i for i in range(6) if mon.get_tool(i) == _TOOL_NAME]

    cfg = MeasurementConfig(
        instrumenter="profile",
        substrates=("profiling",),
        run_dir=str(tmp_path / "real-swap"),
    )
    m = Measurement(cfg)
    m.start()
    try:
        m.swap_instrumenter("monitoring")
        assert len(repro_ids()) == 1
        m.swap_instrumenter("profile")
        assert repro_ids() == []
        m.swap_instrumenter("adaptive")
        assert len(repro_ids()) == 1
        m.swap_instrumenter("monitoring")
        assert len(repro_ids()) == 1  # old id freed before the new claim
    finally:
        m.finalize()
    assert repro_ids() == []


@needs_sys_monitoring
def test_real_tool_id_fallback_never_steals(tmp_path):
    mon = sys.monitoring
    held = None
    if mon.get_tool(mon.PROFILER_ID) is None:
        mon.use_tool_id(mon.PROFILER_ID, "someone-else")
        held = mon.PROFILER_ID
    foreign = mon.get_tool(mon.PROFILER_ID)
    try:
        m = rmon.init(
            instrumenter="monitoring",
            run_dir=str(tmp_path / "real-fallback"),
            substrates=("profiling",),
        )
        try:
            assert m.instrumenter._tool_id != mon.PROFILER_ID
            assert mon.get_tool(m.instrumenter._tool_id) == _TOOL_NAME
            assert mon.get_tool(mon.PROFILER_ID) == foreign
        finally:
            rmon.finalize()
        assert mon.get_tool(mon.PROFILER_ID) == foreign
    finally:
        if held is not None:
            mon.free_tool_id(held)


@needs_sys_monitoring
def test_real_acquire_tool_id_exhausted_raises():
    mon = sys.monitoring
    taken = []
    try:
        for i in range(6):
            if mon.get_tool(i) is None:
                mon.use_tool_id(i, f"hog-{i}")
                taken.append(i)
        with pytest.raises(RuntimeError, match="no free sys.monitoring tool id"):
            acquire_tool_id(mon, _TOOL_NAME)
    finally:
        for i in taken:
            mon.free_tool_id(i)


@needs_sys_monitoring
def test_real_adaptive_records_bounded_subset(tmp_path):
    d = str(tmp_path / "real-adaptive")
    rmon.init(instrumenter="adaptive", run_dir=d, substrates=("profiling",))

    def tick(x):
        return x + 1

    calls = 0
    x = 0
    try:
        deadline = time.time() + 1.2
        while time.time() < deadline:
            for _ in range(10_000):
                x = tick(x)
            calls += 10_000
    finally:
        out = rmon.finalize()
    with open(os.path.join(out, "profile.json")) as fh:
        prof = json.load(fh)
    flat = prof["flat"]
    total = sum(v["visits"] for v in flat.values())
    assert total > 0  # the sampler did observe the workload
    assert any("tick" in k for k in flat)  # including the hot function
    # ...but DISABLE kept it a sparse subset, not one visit per call
    assert total < calls / 4
    assert prof["meta"]["instrumenter"] == "adaptive"
