"""Live-monitoring agent tests: config round-trip, in-process end-to-end
HTTP, the external attach CLI, multi-rank fan-in with rank dedup, governor
cost accounting, the publisher degradation ladder, and finalize isolation."""

import json
import time
import urllib.error
import urllib.request
import warnings

import numpy as np
import pytest

from repro.agent.aggregator import Aggregator
from repro.agent.publisher import MAX_STRIDE
from repro.agent.ringbus import RingWriter, defs_path_for, encode_columns, write_defs
from repro.core.buffer import COLUMNS, EV_ENTER, EV_EXIT
from repro.core.measurement import Measurement, MeasurementConfig
from repro.core.schema import REPORT_SCHEMA_VERSION, SCHEMA_KEY


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.getcode(), resp.read().decode("utf-8")


def _agent_measurement(tmp_path, name="agent-run", **overrides):
    cfg = MeasurementConfig(
        instrumenter="none",
        substrates=("profiling",),
        run_dir=str(tmp_path / name),
        agent=True,
        **overrides,
    )
    m = Measurement(cfg)
    m.start()
    return m


def _work(m, n=150, metric=True):
    for i in range(n):
        with m.region("work"):
            time.sleep(0.0002)
        if metric:
            m.metric("toks", float(i))
    m.thread_buffer().flush()


# -- configuration ------------------------------------------------------------


def test_agent_config_env_round_trip():
    cfg = MeasurementConfig(agent=True, agent_port=8707)
    env = cfg.to_env()
    assert env["REPRO_MONITOR_AGENT"] == "1"
    assert env["REPRO_MONITOR_AGENT_PORT"] == "8707"
    back = MeasurementConfig.from_env(env)
    assert back.agent is True and back.agent_port == 8707
    off = MeasurementConfig.from_env(MeasurementConfig().to_env())
    assert off.agent is False and off.agent_port == 0


# -- end-to-end: in-process sidecar ------------------------------------------


def test_agent_live_endpoints_end_to_end(tmp_path):
    m = _agent_measurement(tmp_path)
    assert m.agent is not None and m.agent.server is not None
    url = m.agent.server.url
    try:
        _work(m)
        deadline = time.monotonic() + 10.0
        rows = []
        while time.monotonic() < deadline and not rows:
            time.sleep(0.25)
            _, body = _get(url + "/stats.json")
            stats = json.loads(body)
            rows = [r for r in stats["regions"] if r["visits"] > 0]
        assert stats[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
        assert rows and rows[0]["visits"] == 150
        assert rows[0]["excl_ns"] > 0 and rows[0]["p95_ns"] >= rows[0]["p50_ns"]

        code, body = _get(url + "/healthz")
        health = json.loads(body)
        assert code == 200 and health["status"] == "ok"
        assert health["drops"] == 0 and health["rings"]

        _, page = _get(url + "/report")
        from repro.core.report import extract_payload

        payload = extract_payload(page)
        assert payload[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
        assert payload["meta"]["live"] is True
        for needle in ("https://", "cdn.", "@import", 'src="//'):
            assert needle not in page

        code, _ = _get(url + "/nope")
    except urllib.error.HTTPError as exc:
        assert exc.code == 404
    finally:
        m.finalize()
    # Finalize tears the endpoint down.
    with pytest.raises((urllib.error.URLError, ConnectionError, OSError)):
        urllib.request.urlopen(url + "/healthz", timeout=2.0)


def test_agent_run_dir_artifacts_and_describe(tmp_path):
    m = _agent_measurement(tmp_path)
    _work(m, n=30, metric=False)
    desc = m.agent.describe()
    assert desc["drops"] == 0 and desc["write_seq"] > 0
    assert desc["url"].startswith("http://127.0.0.1:")
    run_dir = m.finalize()
    assert (tmp_path / "agent-run" / "agent.ring").exists()
    defs = json.load(open(defs_path_for(str(tmp_path / "agent-run" / "agent.ring"))))
    names = [row[1] for row in defs["regions"]]
    assert "user:work" in names
    assert defs["meta"]["rank"] == 0
    assert json.load(open(run_dir + "/meta.json"))


# -- external attach (rank > 0: no in-process server competes) ---------------


def test_agent_attach_cli_once(tmp_path, capsys):
    from repro.agent.cli import main as agent_main

    cfg = MeasurementConfig(
        instrumenter="none",
        substrates=("profiling",),
        run_dir=str(tmp_path / "r1"),
        agent=True,
        rank=1,
    )
    m = Measurement(cfg)
    m.start()
    assert m.agent.server is None  # only rank 0 hosts the sidecar
    # --once attaches at the newest sequence (spectating starts *now*), so
    # the pre-attach history below is skipped by design; the assertions
    # cover the payload contract and the live-writer health verdict.
    _work(m, n=40, metric=False)
    assert agent_main(["attach", str(tmp_path / "r1"), "--once"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
    assert doc["window"]["status"] == "ok"
    m.finalize()


# -- multi-rank fan-in --------------------------------------------------------


def _fake_ring(run_dir, rank, epoch_time_ns):
    run_dir.mkdir(parents=True)
    ring = str(run_dir / "agent.ring")
    w = RingWriter(ring, capacity=4096, rank=rank, epoch_time_ns=epoch_time_ns,
                   epoch_perf_ns=1)
    write_defs(defs_path_for(ring), {
        "meta": {"rank": rank, "experiment": "exp", "epoch_time_ns": epoch_time_ns,
                 "epoch_perf_ns": 1},
        "regions": [[0, "serve:step", "user"]],
        "metrics": {},
    })
    return ring, w


def _publish_pairs(w, n, dur_ns=1000):
    kinds = np.array([EV_ENTER, EV_EXIT] * n, dtype=COLUMNS[0][1])
    regions = np.zeros(2 * n, dtype=COLUMNS[1][1])
    t = np.arange(2 * n, dtype=COLUMNS[2][1]) * dur_ns
    aux = np.zeros(2 * n, dtype=COLUMNS[3][1])
    assert w.publish(encode_columns(
        {"kind": kinds, "region": regions, "t": t, "aux": aux}))


def test_multi_rank_fan_in_and_rank_dedup(tmp_path):
    ring0, w0 = _fake_ring(tmp_path / "exp-a-r0", rank=0, epoch_time_ns=100)
    ring1, w1 = _fake_ring(tmp_path / "exp-b-r1", rank=1, epoch_time_ns=100)
    # A stale duplicate of rank 1 (older epoch): must be dropped, newest wins.
    ring1s, w1s = _fake_ring(tmp_path / "exp-stale-r1", rank=1, epoch_time_ns=50)
    agg = Aggregator(paths=(ring0, ring1, ring1s))
    try:
        assert len(agg._tails) == 2
        _publish_pairs(w0, 10)
        _publish_pairs(w1, 30)
        agg.drain_once()
        doc = agg.snapshot()
        merge = doc["merge"]
        assert merge is not None and merge["world_size"] == 2
        assert [r["rank"] for r in merge["ranks"]] == [0, 1]
        assert merge["total_events"] == 80
        assert [d["rank"] for d in merge["dropped_runs"]] == [1]
        # Per-rank heatmap: rank 1 did 3x the work of rank 0.
        prof = merge["profile"]
        assert prof["regions"] == ["serve:step"]
        (row,) = prof["excl_ns"]
        assert row[1] == pytest.approx(3 * row[0])
        assert prof["imbalance"]["serve:step"] == pytest.approx(1.5)
        assert doc["meta"]["world_size"] == 2
        # The unified table sums both ranks.
        (region_row,) = [r for r in doc["regions"] if r["visits"]]
        assert region_row["region"] == "serve:step"
        assert region_row["visits"] == 40
    finally:
        agg.close()
        w0.close()
        w1.close()
        w1s.close()


def test_aggregator_root_rescan_picks_up_late_ranks(tmp_path):
    ring0, w0 = _fake_ring(tmp_path / "exp-r0", rank=0, epoch_time_ns=100)
    agg = Aggregator(paths=(ring0,), root=str(tmp_path), experiment="exp",
                     rescan_s=0.0)
    try:
        _publish_pairs(w0, 5)
        agg.drain_once()
        assert len(agg._tails) == 1
        ring1, w1 = _fake_ring(tmp_path / "exp-late-r1", rank=1, epoch_time_ns=200)
        _publish_pairs(w1, 5)  # published before the rescan attaches…
        agg.drain_once()       # …so resume-at-newest skips it
        assert len(agg._tails) == 2
        _publish_pairs(w1, 7)
        agg.drain_once()
        health = agg.healthz()
        assert {r["rank"] for r in health["rings"]} == {0, 1}
        w1.close()
    finally:
        agg.close()
        w0.close()


# -- governor integration -----------------------------------------------------


def test_governor_accounts_publish_cost(tmp_path):
    m = _agent_measurement(tmp_path, budget=0.5)
    try:
        assert m.governor is not None
        pub = m.agent.publisher
        with pub._cost_lock:
            pub._cost_pending += 12_345_678
        before = m.governor._window_cost
        empty = {name: np.empty(0, dtype=dt) for name, dt in COLUMNS}
        m.governor.on_flush(0, empty)
        assert m.governor._window_cost - before >= 12_345_678
        # The pull is a swap: a second flush must not double-count.
        after = m.governor._window_cost
        m.governor.on_flush(0, empty)
        assert m.governor._window_cost - after < 12_345_678
    finally:
        m.finalize()


def test_publisher_degrades_and_relaxes_stride(tmp_path):
    m = _agent_measurement(tmp_path)
    try:
        pub = m.agent.publisher
        cols = {name: np.empty(0, dtype=dt) for name, dt in COLUMNS}
        # Overdrive: pretend publishing consumed ~all wall time.
        for _ in range(10):
            pub._window_t0 = time.perf_counter_ns() - int(2e9)
            pub._window_publish_ns = int(2e9)
            pub.on_flush(0, cols)
        assert pub.stride == MAX_STRIDE
        assert pub.thinned_batches > 0
        # Pressure gone: the ladder steps back down to 1.
        for _ in range(10):
            pub._window_t0 = time.perf_counter_ns() - int(2e9)
            pub._window_publish_ns = 0
            pub.on_flush(0, cols)
        assert pub.stride == 1
    finally:
        m.finalize()


# -- finalize isolation (one failing hook must not skip the others) ----------


class _ExplodingSubstrate:
    name = "exploding"

    def open(self, run_dir, meta):
        pass

    def on_flush(self, thread_id, columns):
        pass

    def on_metric(self, name, value, t_ns):
        pass

    def close(self, region_table):
        raise RuntimeError("substrate close boom")

    def export_chrome(self):
        raise RuntimeError("chrome export boom")


def test_finalize_isolates_failing_hooks(tmp_path):
    m = _agent_measurement(tmp_path, name="iso")
    m._substrates.insert(0, _ExplodingSubstrate())
    orig_close = m.agent.close
    calls = {"agent": 0}

    def agent_boom():
        calls["agent"] += 1
        raise RuntimeError("agent shutdown boom")

    m.agent.close = agent_boom
    _work(m, n=10, metric=False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        run_dir = m.finalize()
    msgs = [str(w.message) for w in caught if w.category is RuntimeWarning]
    assert any("substrate close (exploding)" in s for s in msgs)
    assert any("chrome trace export (exploding)" in s for s in msgs)
    assert any("agent shutdown" in s for s in msgs)
    assert calls["agent"] == 1
    # Every hook after the failing ones still ran: the profiling substrate
    # wrote its artifact and meta.json closed out the run dir.
    assert (tmp_path / "iso" / "profile.json").exists()
    meta = json.load(open(run_dir + "/meta.json"))
    assert meta[SCHEMA_KEY] == REPORT_SCHEMA_VERSION
    assert m.finalized
    orig_close()  # real teardown so the server thread doesn't leak


def test_finalize_survives_failing_buffer_flush(tmp_path):
    cfg = MeasurementConfig(
        instrumenter="none", substrates=("profiling",), run_dir=str(tmp_path / "b")
    )
    m = Measurement(cfg)
    m.start()
    with m.region("ok"):
        pass
    buf = m.thread_buffer()
    orig_flush = buf.flush
    buf.flush = lambda: (_ for _ in ()).throw(RuntimeError("flush boom"))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        run_dir = m.finalize()
    assert any("buffer flush" in str(w.message) for w in caught)
    assert json.load(open(run_dir + "/meta.json"))
    buf.flush = orig_flush
