"""Streaming vectorized Chrome-trace export engine tests.

Round-trip assertions for the new exporter: strict JSON on
trace.json/merged_trace.json, B/E balance per (pid, tid), metadata +
counter events present, byte-equivalent span content vs the naive
reference exporter, chunked encoding via REPRO_MONITOR_EXPORT_CHUNK,
and duplicate-rank handling in the merge path.
"""

import importlib.util
import json
import os

import numpy as np
import pytest

import repro.core as rmon
from repro.core.buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT, EV_LINE
from repro.core.export import ENV_CHUNK, ChromeTraceWriter, export_run
from repro.core.merge import find_runs, merge_runs

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_bench_module():
    """Import benchmarks/trace_export.py (the naive reference exporter)."""
    spec = importlib.util.spec_from_file_location(
        "bench_trace_export", os.path.join(REPO_ROOT, "benchmarks", "trace_export.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def strict_load(path):
    """json.load that rejects bare NaN/Infinity (strict JSON only)."""
    def reject(token):
        raise AssertionError(f"non-strict JSON constant {token!r} in {path}")

    with open(path) as fh:
        return json.load(fh, parse_constant=reject)


def _write_run(root, name, rank, epoch_time_ns, epoch_perf_ns, events,
               world_size=2, region_name=None, metrics_series=None):
    """Materialize a minimal trace run dir (defs.json + one stream)."""
    run_dir = os.path.join(str(root), name)
    os.makedirs(run_dir)
    cols = np.asarray(events, dtype=np.uint64)
    np.savez_compressed(
        os.path.join(run_dir, "stream_t0.npz"),
        kind=cols[:, 0].astype(np.uint8),
        region=cols[:, 1].astype(np.int32),
        t=cols[:, 2],
        aux=cols[:, 3].astype(np.uint32),
    )
    defs = {
        "meta": {
            "rank": rank,
            "topology": {"rank": rank, "world_size": world_size,
                         "local_rank": rank, "mesh_shape": []},
            "epoch_time_ns": epoch_time_ns,
            "epoch_perf_ns": epoch_perf_ns,
        },
        "streams": {"0": {"file": "stream_t0.npz", "events": len(events)}},
        "regions": [{"name": region_name or f"rank{rank}_work", "module": "test"}],
    }
    with open(os.path.join(run_dir, "defs.json"), "w") as fh:
        json.dump(defs, fh)
    if metrics_series is not None:
        with open(os.path.join(run_dir, "metrics.json"), "w") as fh:
            json.dump({"series": metrics_series}, fh)
    return run_dir


def _spans(events):
    return [e for e in events if e["ph"] in ("B", "E")]


def _assert_balanced(events):
    bal = {}
    for e in _spans(events):
        key = (e["pid"], e["tid"], e["name"])
        bal[key] = bal.get(key, 0) + (1 if e["ph"] == "B" else -1)
    assert all(v == 0 for v in bal.values()), bal


# ----------------------------------------------------------------------------
# Per-run export
# ----------------------------------------------------------------------------

def test_export_run_matches_naive_reference(tmp_path):
    bench = _load_bench_module()
    run_dir = str(tmp_path / "synth")
    bench.make_synthetic_run(run_dir, n_events=4_000, n_regions=9, n_streams=2)
    engine_path = export_run(run_dir)["out"]
    naive_path = bench._export_naive(run_dir)
    n = bench.check_equivalence(engine_path, naive_path)
    assert n == 4_000
    doc = strict_load(engine_path)
    _assert_balanced(doc["traceEvents"])


def test_export_real_run_roundtrip(tmp_path):
    """End-to-end: measured run -> strict trace.json with metadata,
    counters (from metrics.json series) and balanced spans."""
    d = str(tmp_path / "run")
    rmon.init(instrumenter="profile", run_dir=d, experiment="exp", rank=3)

    def work():
        return sum(range(50))

    with rmon.region("phase"):
        work()
    rmon.metric("loss", 2.5)
    rmon.metric("loss", 3.5)
    out = rmon.finalize()

    doc = strict_load(os.path.join(out, "trace.json"))
    events = doc["traceEvents"]
    _assert_balanced(events)
    assert "phase" in {e["name"] for e in _spans(events)}
    meta = [e for e in events if e["ph"] == "M"]
    proc_names = [e for e in meta if e["name"] == "process_name"]
    assert proc_names and proc_names[0]["args"]["name"] == "r3of4"
    assert any(e["name"] == "thread_name" for e in meta)
    counters = [e for e in events if e["ph"] == "C"]
    assert {c["name"] for c in counters} == {"loss"}
    assert sorted(c["args"]["loss"] for c in counters) == [2.5, 3.5]
    # counters share the spans' (raw perf) timebase in the per-run export
    span_ts = [e["ts"] for e in _spans(events)]
    assert min(span_ts) <= counters[0]["ts"] <= max(span_ts) + 1e9


def test_export_chunking_env_knob(tmp_path, monkeypatch):
    bench = _load_bench_module()
    run_dir = str(tmp_path / "synth")
    bench.make_synthetic_run(run_dir, n_events=2_000, n_regions=5, n_streams=1)
    big = export_run(run_dir, out_path=os.path.join(run_dir, "one.json"))
    monkeypatch.setenv(ENV_CHUNK, "64")
    small = export_run(run_dir, out_path=os.path.join(run_dir, "many.json"))
    assert big["chunks"] == 1
    assert small["chunks"] > 10
    assert small["max_chunk_events"] <= 64
    assert small["span_events"] == big["span_events"] == 2_000
    with open(os.path.join(run_dir, "one.json"), "rb") as fh_a, \
            open(os.path.join(run_dir, "many.json"), "rb") as fh_b:
        assert fh_a.read() == fh_b.read()


def test_export_skips_non_span_events_and_line_aux(tmp_path):
    run = _write_run(
        tmp_path, "lines-r0", 0, 0, 0,
        events=[
            (EV_ENTER, 0, 1_000, 0),
            (EV_LINE, 0, 1_500, 42),
            (EV_EXIT, 0, 2_000, 0),
        ],
    )
    doc = strict_load(export_run(run)["out"])
    spans = _spans(doc["traceEvents"])
    assert [e["ph"] for e in spans] == ["B", "E"]
    assert [e["ts"] for e in spans] == [1.0, 2.0]


def test_writer_empty_trace_is_valid(tmp_path):
    path = str(tmp_path / "empty.json")
    stats = ChromeTraceWriter(path).close()
    doc = strict_load(path)
    assert doc["traceEvents"] == []
    assert stats["events"] == 0


def test_export_large_wall_offsets_exact_decimal(tmp_path):
    """Merged traces carry ~1.7e18 ns wall timestamps; the engine emits
    exact decimal microseconds (integer math, no float rounding)."""
    epoch = 1_700_000_000_000_000_000
    run = _write_run(
        tmp_path, "wall-r0", 0, epoch, 1_000,
        events=[(EV_ENTER, 0, 1_000, 0), (EV_EXIT, 0, 1_234_567, 0)],
        world_size=1,
    )
    out = str(tmp_path / "merged.json")
    merge_runs([run], out)
    raw = open(out).read()
    assert f"{epoch // 1000}.000" in raw
    assert f"{(epoch + 1_233_567) // 1000}.567" in raw


def test_merge_negative_wall_fallback(tmp_path):
    """Pathological epoch (wall clock behind the perf epoch) exercises the
    per-event fallback; timestamps must keep exact value and sign."""
    run = _write_run(
        tmp_path, "neg-r0", 0, epoch_time_ns=0, epoch_perf_ns=10_000,
        events=[(EV_ENTER, 0, 1_500, 0), (EV_EXIT, 0, 20_000, 0)],
        world_size=1,
    )
    out = str(tmp_path / "merged.json")
    summary = merge_runs([run], out)
    spans = _spans(strict_load(out)["traceEvents"])
    assert [e["ts"] for e in spans] == [-8.5, 10.0]
    assert summary["total_events"] == 2


# ----------------------------------------------------------------------------
# Merge path
# ----------------------------------------------------------------------------

def test_merge_metadata_counters_and_alignment(tmp_path):
    ms = 1_000_000
    run0 = _write_run(
        tmp_path, "exp-a-r0", 0, 1_000 * ms, 500,
        events=[(EV_ENTER, 0, 500, 0), (EV_EXIT, 0, 500 + 4 * ms, 0)],
        metrics_series={"loss": [[500, 7.0], [600, None]]},
    )
    run1 = _write_run(
        tmp_path, "exp-a-r1", 1, 1_002 * ms, 900,
        events=[(EV_C_ENTER, 0, 900, 0), (EV_C_EXIT, 0, 900 + 6 * ms, 0)],
    )
    out = str(tmp_path / "merged.json")
    summary = merge_runs([run0, run1], out)
    doc = strict_load(out)
    events = doc["traceEvents"]
    spans = _spans(events)
    ts = [e["ts"] for e in spans]
    assert ts == sorted(ts)
    np.testing.assert_allclose(
        ts, [1_000_000.0, 1_002_000.0, 1_004_000.0, 1_008_000.0]
    )
    assert [(e["pid"], e["ph"]) for e in spans] == [
        (0, "B"), (1, "B"), (0, "E"), (1, "E"),
    ]
    proc_names = {
        e["pid"]: e["args"]["name"]
        for e in events if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert proc_names == {0: "r0of2", 1: "r1of2"}
    sort_idx = {
        e["pid"]: e["args"]["sort_index"]
        for e in events if e["ph"] == "M" and e["name"] == "process_sort_index"
    }
    assert sort_idx == {0: 0, 1: 1}
    counters = [e for e in events if e["ph"] == "C"]
    assert len(counters) == 1  # the None sample is dropped
    assert counters[0]["args"]["loss"] == 7.0
    # counter ts is wall-aligned like the spans
    assert counters[0]["ts"] == pytest.approx(1_000_000.0)
    assert summary["total_events"] == 4
    assert summary["export"]["span_events"] == 4
    assert summary["export"]["counter_events"] == 1
    assert summary["export"]["bytes"] > 0


def test_merge_duplicate_ranks_keeps_newest(tmp_path):
    stale = _write_run(
        tmp_path, "exp-20240101-r0", 0, epoch_time_ns=1_000_000_000,
        epoch_perf_ns=0, events=[(EV_ENTER, 0, 10, 0), (EV_EXIT, 0, 20, 0)],
        region_name="stale_work",
    )
    fresh = _write_run(
        tmp_path, "exp-20240102-r0", 0, epoch_time_ns=2_000_000_000,
        epoch_perf_ns=0, events=[(EV_ENTER, 0, 10, 0), (EV_EXIT, 0, 20, 0)],
        region_name="fresh_work",
    )
    out = str(tmp_path / "merged.json")
    with pytest.warns(RuntimeWarning, match="duplicate rank"):
        summary = merge_runs([stale, fresh], out)
    assert [r["run_dir"] for r in summary["ranks"]] == [fresh]
    assert [d["run_dir"] for d in summary["dropped_runs"]] == [stale]
    assert summary["total_events"] == 2
    names = {e["name"] for e in _spans(strict_load(out)["traceEvents"])}
    assert names == {"fresh_work"}


def test_merge_drops_stale_higher_ranks_from_previous_larger_launch(tmp_path):
    """Relaunching an experiment with a smaller world must not merge the
    dead launch's higher ranks: duplicates prove the overlap, and the
    surviving duplicates' recorded world_size bounds the live ranks."""
    old = [
        _write_run(tmp_path, f"exp-1-r{r}", r, epoch_time_ns=1_000,
                   epoch_perf_ns=0, events=[(EV_ENTER, 0, 10, 0), (EV_EXIT, 0, 20, 0)],
                   world_size=4, region_name=f"old_r{r}")
        for r in range(4)
    ]
    new = [
        _write_run(tmp_path, f"exp-2-r{r}", r, epoch_time_ns=2_000,
                   epoch_perf_ns=0, events=[(EV_ENTER, 0, 10, 0), (EV_EXIT, 0, 20, 0)],
                   world_size=2, region_name=f"new_r{r}")
        for r in range(2)
    ]
    out = str(tmp_path / "merged.json")
    with pytest.warns(RuntimeWarning, match="duplicate rank"):
        summary = merge_runs(old + new, out)
    assert [r["run_dir"] for r in summary["ranks"]] == new
    assert sorted(d["run_dir"] for d in summary["dropped_runs"]) == sorted(old)
    assert summary["world_size"] == 2
    names = {e["name"] for e in _spans(strict_load(out)["traceEvents"])}
    assert names == {"new_r0", "new_r1"}


def test_find_runs_experiment_boundary(tmp_path):
    a = _write_run(tmp_path, "run-1-r0", 0, 0, 0, [(EV_ENTER, 0, 10, 0)])
    _write_run(tmp_path, "run2-1-r0", 0, 0, 0, [(EV_ENTER, 0, 10, 0)])
    exact = _write_run(tmp_path, "run", 1, 0, 0, [(EV_ENTER, 0, 10, 0)])
    assert find_runs(str(tmp_path), "run") == [exact, a]
    assert find_runs(str(tmp_path), "run2") == [str(tmp_path / "run2-1-r0")]


def test_merge_summary_render_and_cli(tmp_path, capsys):
    _write_run(tmp_path, "exp-a-r0", 0, 1_000, 0,
               events=[(EV_ENTER, 0, 10, 0), (EV_EXIT, 0, 20, 0)])
    from repro.core.merge import main as merge_main

    assert merge_main([str(tmp_path), "--experiment", "exp"]) == 0
    out = capsys.readouterr().out
    assert "span events" in out and "events/s" in out
    summary_path = str(tmp_path / "merged_trace_summary.json")
    assert os.path.exists(summary_path)
    strict_load(summary_path)
    strict_load(str(tmp_path / "merged_trace.json"))

    from repro.core.analysis import main as analysis_main

    assert analysis_main(["merge-summary", summary_path]) == 0
    assert "merged trace" in capsys.readouterr().out


# ----------------------------------------------------------------------------
# Non-finite metric artifacts (bugfix)
# ----------------------------------------------------------------------------

def test_non_finite_metrics_artifacts_strictly_parseable(tmp_path):
    d = str(tmp_path / "nan-run")
    rmon.init(instrumenter="profile", run_dir=d, experiment="nan")

    def work():
        return 1

    with rmon.region("phase"):
        work()
    rmon.metric("x", float("nan"))
    rmon.metric("x", float("inf"))
    rmon.metric("x", 4.0)
    rmon.metric("all_bad", float("-inf"))
    out = rmon.finalize()

    metrics = strict_load(os.path.join(out, "metrics.json"))
    agg = metrics["metrics"]["x"]
    assert agg["count"] == 3 and agg["nonfinite"] == 2
    assert agg["min"] == agg["max"] == agg["mean"] == 4.0
    all_bad = metrics["metrics"]["all_bad"]
    assert all_bad["min"] is None and all_bad["max"] is None
    assert all_bad["mean"] is None  # no finite samples -> no fabricated 0.0
    assert metrics["series"]["x"] == [
        [metrics["series"]["x"][0][0], None],
        [metrics["series"]["x"][1][0], None],
        [metrics["series"]["x"][2][0], 4.0],
    ]
    strict_load(os.path.join(out, "profile.json"))
    # the trace counters drop non-finite samples instead of corrupting JSON
    doc = strict_load(os.path.join(out, "trace.json"))
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert [c["args"]["x"] for c in counters if c["name"] == "x"] == [4.0]


def test_diff_profiles_new_region_ratio_serializable(tmp_path):
    from repro.core.analysis import diff_profiles, render_diff

    def make(name, regions):
        d = str(tmp_path / name)
        rmon.init(instrumenter="none", run_dir=d, substrates=("profiling",))
        for r in regions:
            with rmon.region(r):
                pass
        return rmon.finalize()

    a = make("a", ["shared"])
    b = make("b", ["shared", "only_in_b"])
    rows = diff_profiles(a, b)
    by_region = {r["region"]: r for r in rows}
    assert by_region["user:only_in_b"]["ratio"] is None
    json.dumps(rows, allow_nan=False)  # must not raise
    assert "new" in render_diff(rows)
