"""Distributed-feature tests on placeholder devices (subprocess-isolated:
the main test process must keep seeing exactly 1 CPU device)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


# -- gradient compression (runs single-device: math-only tests) ---------------

def test_int8_quantize_roundtrip():
    from repro.dist.compression import int8_dequantize, int8_quantize

    g = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) * 0.01
    q, scale = int8_quantize(g)
    back = int8_dequantize(q, scale)
    # max quantization error is scale/2 per element (round-to-nearest)
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.51


def test_topk_error_feedback_conserves_mass():
    from repro.dist.compression import TopKEF

    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (128,))}
    err = TopKEF.init(grads)
    sparse, new_err = TopKEF.compress(grads, err, k_fraction=0.1)
    # sent + residual == original
    np.testing.assert_allclose(
        np.asarray(sparse["w"] + new_err["w"]), np.asarray(grads["w"]), rtol=1e-6
    )
    nnz = int(jnp.sum(sparse["w"] != 0))
    assert nnz == max(1, int(128 * 0.1))
    # second round: residual re-enters
    sparse2, err2 = TopKEF.compress(jax.tree.map(jnp.zeros_like, grads), new_err, 0.1)
    np.testing.assert_allclose(
        np.asarray(sparse2["w"] + err2["w"]), np.asarray(new_err["w"]), rtol=1e-6
    )


def test_int8_psum_multidevice():
    out = _run_with_devices(
        """
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import int8_psum
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
        @partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
        def reduce(g):
            return int8_psum(g, "data")[None]
        g = jnp.arange(8.0)[:, None] * jnp.ones((8, 16)) * 0.01
        got = reduce(g.reshape(8, 16))
        expect = jnp.mean(g.reshape(8,16), axis=0)
        err = float(jnp.max(jnp.abs(got - expect[None])))
        assert err < 0.01 * 0.5, err  # within quantization error
        print("INT8_PSUM_OK", err)
        """
    )
    assert "INT8_PSUM_OK" in out


# -- pipeline parallelism ------------------------------------------------------

def test_gpipe_pipeline_matches_sequential():
    out = _run_with_devices(
        """
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.dist.pipeline import pipeline_forward
        S = 4  # stages
        mesh = jax.make_mesh((S,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.PRNGKey(0)
        # per-stage affine layer
        ws = jax.random.normal(key, (S, 16, 16)) * 0.3
        bs = jax.random.normal(jax.random.fold_in(key, 1), (S, 16)) * 0.1
        def stage_fn(params, x):
            w, b = params
            return jnp.tanh(x @ w[0] + b[0])
        M, mb, d = 8, 4, 16
        x = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, d))
        @partial(jax.shard_map, mesh=mesh,
                 in_specs=((P("stage"), P("stage")), P(None)),
                 out_specs=P(None))
        def run(params, microbatches):
            return pipeline_forward(stage_fn, params, microbatches, S, "stage")
        got = run((ws, bs), x)
        # sequential reference
        y = x
        for s in range(S):
            y = jnp.tanh(y @ ws[s] + bs[s])
        import numpy as np
        np.testing.assert_allclose(np.asarray(got), np.asarray(y), rtol=1e-5, atol=1e-5)
        print("PIPELINE_OK")
        """,
        n_devices=4,
    )
    assert "PIPELINE_OK" in out


# -- sharding rules ------------------------------------------------------------

def test_sharding_rules_divisibility_and_coverage():
    out = _run_with_devices(
        """
        import jax
        from repro.configs import get_config, ARCHS
        from repro.dist import sharding as shd
        from repro.models import lm_init
        mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        for arch in ARCHS:
            cfg = get_config(arch)
            shapes = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
            shardings = shd.params_shardings(mesh, shapes)
            import jax.tree_util as jtu
            n_sharded = 0
            for (path, leaf), (_, s) in zip(jtu.tree_leaves_with_path(shapes),
                                            jtu.tree_leaves_with_path(shardings)):
                spec = s.spec
                # every sharded dim must divide evenly
                for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 10):
                    if axes is None: continue
                    ax = (axes,) if isinstance(axes, str) else axes
                    size = 1
                    for a in ax: size *= mesh.shape[a]
                    assert dim % size == 0, (arch, jtu.keystr(path), leaf.shape, spec)
                    n_sharded += 1
            assert n_sharded > 0, arch
        print("SHARDING_RULES_OK")
        """,
        n_devices=8,
    )
    assert "SHARDING_RULES_OK" in out


def test_small_mesh_e2e_train_step_matches_single_device():
    """Numerical equivalence: 8-device FSDP x TP train step == 1-device."""
    out = _run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke_config
        from repro.dist import sharding as shd
        from repro.dist.train import make_train_step, with_act_sharding
        from repro.models import lm_init
        from repro.optim import adamw
        cfg = get_smoke_config("yi-34b")
        mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        key = jax.random.PRNGKey(0)
        params = lm_init(key, cfg)
        opt = adamw.init(params)
        batch = {
            "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.fold_in(key, 1), (4, 32), 0, cfg.vocab),
        }
        opt_cfg = adamw.AdamWConfig()
        # single-device
        p1, o1, s1 = jax.jit(make_train_step(cfg, opt_cfg))(params, opt, batch)
        # meshed
        cfg2 = with_act_sharding(cfg, mesh)
        ps = shd.params_shardings(mesh, params)
        os_ = shd.opt_state_shardings(mesh, opt)
        bs = shd.batch_shardings(mesh, batch)
        with mesh:
            pp = jax.device_put(params, ps)
            oo = jax.device_put(opt, os_)
            bb = jax.device_put(batch, bs)
            p2, o2, s2 = jax.jit(make_train_step(cfg2, opt_cfg))(pp, oo, bb)
        np.testing.assert_allclose(float(s1["loss"]), float(s2["loss"]), rtol=2e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                       rtol=5e-3, atol=5e-3)
        print("MESH_EQUIV_OK", float(s1["loss"]), float(s2["loss"]))
        """,
        n_devices=8,
        timeout=900,
    )
    assert "MESH_EQUIV_OK" in out


# -- straggler watchdog ---------------------------------------------------------

def test_straggler_watchdog_flags_and_mitigates():
    from repro.dist.straggler import StragglerConfig, StragglerWatchdog

    events = []
    wd = StragglerWatchdog(
        StragglerConfig(window=16, threshold=1.5, evict_after=3, min_samples=4),
        on_straggler=events.append,
    )
    for i in range(10):
        assert not wd.observe(i, 0.1)
    flagged = [wd.observe(10 + i, 0.5) for i in range(3)]
    assert all(flagged)
    assert wd.mitigations == 1 and len(events) == 1
    assert events[0]["ratio"] > 1.5
    summary = wd.summary()
    assert summary["flags"] == 3
