"""Static-analysis subsystem tests (repro.core.staticpass).

Covers the scanner's module-naming parity with the live registry, the
classifier's verdicts, the plan artifact contract (round-trip, exit-2
errors), the linter's rule set against the tests/fixtures/lint_bad fixture
(each rule exactly once) and against this repo itself (zero violations),
and the plan -> measurement -> governor -> report integration.
"""

import json
import os

import pytest

import repro.core as rmon
from repro.core.filtering import Filter
from repro.core.measurement import Measurement, MeasurementConfig
from repro.core.schema import MissingArtifact
from repro.core.staticpass import (
    RULES,
    apply_plan,
    build_plan,
    lint_paths,
    load_plan,
    module_name_for,
    plan_exclude_patterns,
    plan_vs_observed,
    save_plan,
    scan_paths,
    verify_plan,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")
LINT_BAD = os.path.join(REPO, "tests", "fixtures", "lint_bad")


# ---------------------------------------------------------------------------
# scanner: module naming
# ---------------------------------------------------------------------------


def test_module_name_matches_package_layout():
    """Dotted module names climb packages — including the repro namespace
    package (src/repro has no __init__.py) — and stop at project roots."""
    cases = {
        os.path.join(SRC_REPRO, "data", "synthetic.py"): "repro.data.synthetic",
        os.path.join(SRC_REPRO, "core", "filtering.py"): "repro.core.filtering",
        os.path.join(SRC_REPRO, "data", "__init__.py"): "repro.data",
    }
    for path, expected in cases.items():
        assert module_name_for(path) == expected, path


def test_module_name_bare_script(tmp_path):
    """A packageless script keeps its stem — no namespace hop is invented
    for a file that never sat inside a real package."""
    script = tmp_path / "kernel.py"
    script.write_text("x = 1\n")
    assert module_name_for(str(script)) == "kernel"


def test_module_naming_parity_with_live_registry(tmp_path):
    """The satellite cross-check: for repro.data, the planner's dotted
    module names must be exactly what a live RegionRegistry records when
    the same functions actually run under the profile instrumenter."""
    plan = build_plan([os.path.join(SRC_REPRO, "data")])
    planned = {(r["module"], r["name"]) for r in plan["records"]}

    # Import before start(): class-body code objects execute at import time
    # and would register as regions, but the planner deliberately records
    # functions only.
    from repro.data.synthetic import DataConfig, SyntheticLM, _mix
    import numpy as np

    m = Measurement(MeasurementConfig(
        instrumenter="profile", substrates=("profiling",),
        run_dir=str(tmp_path / "parity-run"),
    ))
    m.start()
    try:
        lm = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=2))
        lm.batch(0)
        _mix(np.arange(4, dtype=np.uint64), 3)
    finally:
        m.finalize()

    data_dir = os.path.join(SRC_REPRO, "data")
    observed = {
        (row["module"], row["name"])
        for row in m.regions.snapshot()
        if row.get("file", "").startswith(data_dir) and "<" not in row["name"]
    }
    assert observed, "live run registered no repro.data regions"
    missing = observed - planned
    assert not missing, f"live registry names the plan missed: {missing}"


# ---------------------------------------------------------------------------
# classifier
# ---------------------------------------------------------------------------


def _classify(tmp_path, source):
    path = tmp_path / "mod.py"
    path.write_text(source)
    from repro.core.staticpass.classify import classify_modules

    out = classify_modules(scan_paths([str(path)]))
    return {c.info.qualname: c for c in out}


def test_classifier_trivial_hot_exclude(tmp_path):
    by_name = _classify(tmp_path, (
        "def tiny(x):\n    return x + 1\n"
        "def loop(n):\n    s = 0\n"
        "    for i in range(n):\n        s += tiny(i)\n    return s\n"
    ))
    tiny = by_name["tiny"]
    assert "trivial" in tiny.classes and "hot" in tiny.classes
    assert tiny.verdict == "exclude"
    assert tiny.est_rate > by_name["loop"].est_rate
    assert by_name["loop"].verdict == "keep"


def test_classifier_generator_async_cost_class(tmp_path):
    by_name = _classify(tmp_path, (
        "def gen():\n    yield 1\n"
        "async def coro():\n    return 1\n"
    ))
    assert by_name["gen"].cost_class == "yield"
    assert "generator" in by_name["gen"].classes
    assert by_name["coro"].cost_class == "yield"
    assert "async" in by_name["coro"].classes


def test_classifier_recursive_and_cwrapper(tmp_path):
    by_name = _classify(tmp_path, (
        "import math\n"
        "def fact(n):\n    return 1 if n < 2 else n * fact(n - 1)\n"
        "def wrap(x):\n    return math.sqrt(x)\n"
    ))
    assert "recursive" in by_name["fact"].classes
    assert "hot" in by_name["fact"].classes
    assert "cwrapper" in by_name["wrap"].classes
    assert by_name["wrap"].verdict == "sample"


# ---------------------------------------------------------------------------
# plan artifact contract
# ---------------------------------------------------------------------------


def test_plan_round_trip_and_both_module_forms(tmp_path):
    # The project marker pins the import root: without it the namespace
    # heuristic may climb one level past the package (pytest tmp dirs are
    # anonymous; real checkouts have pyproject/setup/.git at the root).
    (tmp_path / "pyproject.toml").write_text("")
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "__init__.py").write_text("")
    (pkg / "mod.py").write_text(
        "def tiny(x):\n    return x + 1\n"
        "def drive(n):\n    return [tiny(i) for i in range(n)]\n"
    )
    plan = build_plan([str(pkg)])
    verify_plan(plan)
    assert plan["report_schema_version"] >= 1
    patterns = plan_exclude_patterns(plan)
    # both the dotted (framed) and the stem (frameless) module form
    assert "pkg.mod.tiny" in patterns and "mod.tiny" in patterns
    spec = plan["filter"]["spec"]
    assert Filter.from_spec(spec).to_spec() == spec
    flt = Filter.from_spec(spec)
    assert not flt.decide("pkg.mod", "tiny", str(pkg / "mod.py"))
    assert not flt.decide("mod", "tiny", str(pkg / "mod.py"))
    assert flt.decide("pkg.mod", "drive", str(pkg / "mod.py"))


def test_plan_save_load_and_exit2_errors(tmp_path):
    (tmp_path / "m.py").write_text("def f():\n    return 1\n")
    plan = build_plan([str(tmp_path / "m.py")])
    path = save_plan(plan, str(tmp_path / "static_plan.json"))
    loaded = load_plan(path)
    assert loaded["functions"] == plan["functions"]
    # directory form resolves to static_plan.json inside
    assert load_plan(str(tmp_path))["functions"] == plan["functions"]

    with pytest.raises(MissingArtifact):
        load_plan(str(tmp_path / "nope.json"))
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{truncated")
    with pytest.raises(MissingArtifact):
        load_plan(str(corrupt))
    not_a_plan = tmp_path / "other.json"
    not_a_plan.write_text(json.dumps({"foo": 1}))
    with pytest.raises(MissingArtifact):
        load_plan(str(not_a_plan))


def test_scan_bad_path_raises_missing_artifact(tmp_path):
    with pytest.raises(MissingArtifact):
        scan_paths([str(tmp_path / "nope")])
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(MissingArtifact):
        scan_paths([str(empty)])


def test_plan_records_syntax_errors_without_dying(tmp_path):
    (tmp_path / "ok.py").write_text("def f():\n    return 1\n")
    (tmp_path / "broken.py").write_text("def oops(:\n")
    plan = build_plan([str(tmp_path)])
    assert any("broken.py" in e["file"] for e in plan["errors"])
    assert plan["functions"] >= 1  # the parsable file still contributes


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------


def test_lint_fixture_each_rule_fires_exactly_once():
    violations = lint_paths([LINT_BAD])
    by_rule = {}
    for v in violations:
        by_rule.setdefault(v.rule_id, []).append(v)
    for rule_id in RULES:
        assert len(by_rule.get(rule_id, [])) == 1, (
            f"{rule_id} fired {len(by_rule.get(rule_id, []))}x: "
            f"{[v.format() for v in by_rule.get(rule_id, [])]}"
        )
    assert len(violations) == len(RULES)
    # diagnostics carry file:line and the stable id + name
    v = by_rule["SP101"][0]
    assert v.format().startswith(f"{v.file}:{v.line}: SP101 region-not-entered")


def test_lint_self_clean_over_repo():
    """The CI gate, as a test: our own sources, examples, and benchmarks
    hold zero measurement-API violations (instrumenter modules carry
    explicit allow-file pragmas — installing hooks is their job)."""
    violations = lint_paths([
        SRC_REPRO,
        os.path.join(REPO, "examples"),
        os.path.join(REPO, "benchmarks"),
    ])
    assert violations == [], [v.format() for v in violations]


def test_lint_suppression_pragmas(tmp_path):
    line = tmp_path / "line.py"
    line.write_text(
        "import sys\n"
        "sys.setprofile(print)  # repro-lint: allow=SP201\n"
        "sys.settrace(print)\n"
    )
    vs = lint_paths([str(line)])
    assert [v.line for v in vs] == [3]  # only the unsuppressed one

    file_scoped = tmp_path / "file.py"
    file_scoped.write_text(
        "# repro-lint: allow-file=foreign-hook-install\n"
        "import sys\n"
        "sys.setprofile(print)\n"
        "sys.settrace(print)\n"
    )
    assert lint_paths([str(file_scoped)]) == []


# ---------------------------------------------------------------------------
# integration: plan -> measurement -> governor -> report
# ---------------------------------------------------------------------------

KERNEL_SRC = (
    "def add(val):\n"
    "    return val + 1\n"
    "def main(n):\n"
    "    total = 0\n"
    "    for i in range(n):\n"
    "        total = add(total)\n"
    "    return total\n"
)


def _kernel_plan(tmp_path):
    kpath = tmp_path / "case2_kernel.py"
    kpath.write_text(KERNEL_SRC)
    plan = build_plan([str(kpath)])
    return str(kpath), save_plan(plan, str(tmp_path / "static_plan.json"))


def test_static_plan_env_round_trip(tmp_path):
    _, plan_path = _kernel_plan(tmp_path)
    cfg = MeasurementConfig(static_plan=plan_path)
    env = dict(os.environ)
    env.update(cfg.to_env())
    assert MeasurementConfig.from_env(env).static_plan == plan_path
    # unset stays unset (no empty-string key leaks into the child env)
    assert "REPRO_MONITOR_STATIC_PLAN" not in MeasurementConfig().to_env()


def test_measurement_applies_plan_and_copies_artifact(tmp_path):
    kpath, plan_path = _kernel_plan(tmp_path)
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"), static_plan=plan_path,
        substrates=("profiling",),
    ))
    assert "case2_kernel.add" in m.filter.runtime_exclude
    m.start()
    try:
        g = {"__name__": "case2_kernel", "__file__": kpath}
        exec(compile(KERNEL_SRC, kpath, "exec"), g)
        g["main"](5000)
    finally:
        m.finalize()
    # provenance copy lands in the run dir and loads as a plan
    copied = load_plan(m.run_dir)
    assert copied["filter"]["patterns"] == plan_exclude_patterns(copied)
    flat = json.load(open(os.path.join(m.run_dir, "profile.json")))["flat"]
    assert not any(k.endswith(":add") for k in flat), list(flat)
    assert any("main" in k for k in flat)


def test_bad_plan_path_fails_at_construction(tmp_path):
    with pytest.raises(MissingArtifact):
        Measurement(MeasurementConfig(
            run_dir=str(tmp_path / "run"),
            static_plan=str(tmp_path / "nope.json"),
        ))


def test_plan_merges_under_exclude_precedence(tmp_path):
    """Plan excludes ride the runtime-exclude (exclude!) channel: they
    tighten an include-only allow-list instead of flipping it, and survive
    a to_spec/from_spec round trip alongside user rules."""
    _, plan_path = _kernel_plan(tmp_path)
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"),
        filter_spec="include:case2_kernel.*",
        static_plan=plan_path,
    ))
    flt = m.filter
    assert flt.decide("case2_kernel", "main", "case2_kernel.py")
    assert not flt.decide("case2_kernel", "add", "case2_kernel.py")
    assert not flt.decide("elsewhere", "anything", "elsewhere.py")  # allow-list held
    round_tripped = Filter.from_spec(flt.to_spec())
    assert not round_tripped.decide("case2_kernel", "add", "case2_kernel.py")
    assert round_tripped.decide("case2_kernel", "main", "case2_kernel.py")


def test_governor_seeded_and_documented(tmp_path):
    kpath, plan_path = _kernel_plan(tmp_path)
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"), static_plan=plan_path,
        substrates=(), budget=0.05,
    ))
    assert m.governor is not None
    assert "case2_kernel:add" in m.governor._plan_offenders
    m.start()
    try:
        g = {"__name__": "case2_kernel", "__file__": kpath}
        exec(compile(KERNEL_SRC, kpath, "exec"), g)
        g["main"](2000)
    finally:
        m.finalize()
    doc = json.load(open(os.path.join(m.run_dir, "governor.json")))
    assert doc["static_plan"]["predicted_offenders"] >= 1
    assert doc["static_plan"]["patterns"] >= 1


def test_apply_plan_to_live_measurement(tmp_path):
    """apply_plan works mid-run too: runtime excludes tighten and cached
    region verdicts are refiltered (launch --static-plan path)."""
    kpath, plan_path = _kernel_plan(tmp_path)
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"), substrates=("profiling",),
    ))
    m.start()
    try:
        g = {"__name__": "case2_kernel", "__file__": kpath}
        exec(compile(KERNEL_SRC, kpath, "exec"), g)
        g["main"](100)  # registers case2_kernel:add with a keep verdict
        added = apply_plan(m, load_plan(plan_path))
        assert "case2_kernel.add" in added
        g["main"](5000)  # post-plan traffic must not record add
    finally:
        m.finalize()
    flat = json.load(open(os.path.join(m.run_dir, "profile.json")))["flat"]
    add_rows = {k: v for k, v in flat.items() if k.endswith(":add")}
    # at most the 100 pre-plan visits survive; the 5000 post-plan do not
    assert all(v["visits"] <= 100 for v in add_rows.values()), add_rows


def test_plan_vs_observed_buckets():
    plan = {
        "predicted_offenders": [
            {"region": "m:pre", "frameless_region": "m:pre", "verdict": "exclude"},
            {"region": "m:conf", "frameless_region": "m:conf", "verdict": "sample"},
            {"region": "m:unconf", "frameless_region": "m:unconf", "verdict": "sample"},
        ],
    }
    gov = {
        "regions": [
            {"region": "m:conf", "excluded": True},
            {"region": "m:unconf", "excluded": False},
            {"region": "m:surprise", "excluded": True},
        ],
        "actions": [],
    }
    vs = plan_vs_observed(plan, gov)
    assert vs["pre_excluded"] == ["m:pre"]
    assert vs["confirmed"] == ["m:conf"]
    assert vs["unconfirmed"] == ["m:unconf"]
    assert vs["unpredicted"] == ["m:surprise"]
    assert vs["governed"] is True
    ungoverned = plan_vs_observed(plan, None)
    assert ungoverned["governed"] is False and ungoverned["confirmed"] == []


def test_report_renders_plan_section(tmp_path):
    kpath, plan_path = _kernel_plan(tmp_path)
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"), static_plan=plan_path,
        substrates=("profiling",), budget=0.05,
    ))
    m.start()
    try:
        g = {"__name__": "case2_kernel", "__file__": kpath}
        exec(compile(KERNEL_SRC, kpath, "exec"), g)
        g["main"](2000)
    finally:
        m.finalize()
    from repro.core.report import build_report, render_report

    doc = build_report(m.run_dir)
    assert doc["plan"] is not None
    assert doc["plan"]["vs_observed"]["governed"] is True
    assert "case2_kernel:add" in doc["plan"]["vs_observed"]["pre_excluded"]
    assert "Static plan vs observed" in render_report(doc)


def test_scorep_cli_carries_static_plan(tmp_path):
    """repro.scorep --static-plan lands in the composed child environment."""
    from repro.core.bootstrap import build_parser, compose_environment

    _, plan_path = _kernel_plan(tmp_path)
    ns = build_parser().parse_args(
        ["--static-plan", plan_path, "target.py"]
    )
    env = compose_environment(ns, {})
    assert env["REPRO_MONITOR_STATIC_PLAN"] == plan_path


# ---------------------------------------------------------------------------
# concurrency analyzer (SP4xx)
# ---------------------------------------------------------------------------


BAD_CONCURRENCY = os.path.join(LINT_BAD, "bad_concurrency.py")


def test_concurrency_fixture_each_rule_fires_exactly_once():
    """bad_concurrency.py demonstrates every SP4xx rule exactly once, with a
    call-path witness on each finding (the broader lint fixture test covers
    the fold into `analysis lint`; this one checks the analyzer directly)."""
    from repro.core.staticpass import CONCURRENCY_RULES, analyze_paths

    model, findings = analyze_paths([BAD_CONCURRENCY])
    counts = {}
    for f in findings:
        counts[f["rule"]] = counts.get(f["rule"], 0) + 1
        assert f["witness"], f
        assert os.path.exists(f["file"]) and f["line"] > 0, f
    assert counts == {rule: 1 for rule in CONCURRENCY_RULES}, counts
    # the fixture's threads were discovered as concurrent entrypoints
    kinds = {ep.kind for ep in model.entrypoints.values()}
    assert "thread" in kinds and "main" in kinds


def test_concurrency_artifact_round_trip(tmp_path):
    from repro.core.staticpass import (
        build_concurrency_plan,
        load_concurrency_plan,
        render_concurrency_plan,
        save_concurrency_plan,
    )

    doc = build_concurrency_plan([BAD_CONCURRENCY])
    assert doc["report_schema_version"] >= 1
    assert doc["generator"] == "repro.core.staticpass.concurrency"
    assert sum(doc["rule_counts"].values()) == len(doc["findings"]) == 5
    # directory form resolves to concurrency_plan.json inside
    save_concurrency_plan(doc, str(tmp_path))
    loaded = load_concurrency_plan(str(tmp_path))
    assert loaded["findings"] == doc["findings"]
    text = render_concurrency_plan(loaded)
    assert "SP401" in text and "lock-order-inversion" in text

    with pytest.raises(MissingArtifact):
        load_concurrency_plan(str(tmp_path / "nope"))
    (tmp_path / "corrupt.json").write_text("{truncated")
    with pytest.raises(MissingArtifact):
        load_concurrency_plan(str(tmp_path / "corrupt.json"))
    # a different artifact (e.g. a static plan) is rejected, not mis-read
    (tmp_path / "other.json").write_text(json.dumps({"generator": "x"}))
    with pytest.raises(MissingArtifact):
        load_concurrency_plan(str(tmp_path / "other.json"))


def test_concurrency_wait_points_carry_both_module_forms(tmp_path):
    """Wait-point rows name the region in both module forms (dotted +
    file-stem) so governor matching works under every instrumenter family."""
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "__init__.py").write_text("")
    (pkg / "mod.py").write_text(
        "import threading\n"
        "_lock = threading.Lock()\n"
        "def guarded():\n"
        "    with _lock:\n"
        "        return 1\n"
    )
    from repro.core.staticpass import analyze_paths
    from repro.core.staticpass.concurrency import assemble_plan

    model, findings = analyze_paths([str(pkg)])
    doc = assemble_plan([str(pkg)], model, findings)
    rows = [w for w in doc["wait_points"] if w["kind"] == "lock-acquire"]
    assert rows, doc["wait_points"]
    assert any(w["region"].endswith("pkg.mod:guarded") for w in rows)
    assert any(w["frameless_region"] == "mod:guarded" for w in rows)


def test_concurrency_suppression_pragma(tmp_path):
    """SP4xx findings honour the shared lint pragmas on the anchor line."""
    src = (
        "import threading\n"
        "def leak():\n"
        "    t = threading.Thread(target=print)\n"
        "    t.start(){pragma}\n"
    )
    from repro.core.staticpass import analyze_paths

    noisy = tmp_path / "noisy.py"
    noisy.write_text(src.format(pragma=""))
    _, findings = analyze_paths([str(noisy)])
    assert [f["rule"] for f in findings] == ["SP405"]

    quiet = tmp_path / "quiet.py"
    quiet.write_text(src.format(pragma="  # repro-lint: allow=SP405"))
    _, findings = analyze_paths([str(quiet)])
    assert findings == []


def test_governor_never_excludes_wait_point_regions():
    """A region the plan marks as a wait point is never offered for
    exclusion — its enter/exit pairs are the wait-state signal."""
    import sys
    from types import SimpleNamespace

    import numpy as np

    from repro.core.governor import Governor
    from repro.core.regions import RegionRegistry

    def waity():  # pragma: no cover - never called, only registered
        pass

    reg = RegionRegistry()
    rid = reg.register_code(waity.__code__, sys._getframe())
    fake = SimpleNamespace(
        regions=reg,
        instrumenter=SimpleNamespace(
            name="profile", period=0, cost_multiplier=lambda: 1.0
        ),
    )
    g = Governor(fake, budget=0.5)
    n = len(reg)
    g._visits = np.ones(n, dtype=np.int64)
    g._est_cost = np.ones(n, dtype=np.float64)
    g._leaf_min = np.zeros(n, dtype=np.float64)  # short leaf: prime offender
    assert rid in g._offenders(set())
    region = reg.get(rid)
    g._plan_wait_points = {f"{region.module}:{region.name}"}
    assert rid not in g._offenders(set())


def test_seed_static_plan_collects_wait_points(tmp_path):
    kpath, plan_path = _kernel_plan(tmp_path)
    plan = load_plan(plan_path)
    plan["concurrency"] = {
        "entrypoints": 1,
        "locks": 1,
        "findings": {},
        "wait_points": [
            {
                "region": "case2_kernel:main",
                "frameless_region": "case2_kernel:main",
                "kind": "lock-acquire",
                "file": kpath,
                "line": 1,
            }
        ],
    }
    m = Measurement(MeasurementConfig(
        run_dir=str(tmp_path / "run"), substrates=(), budget=0.05,
    ))
    try:
        m.governor.seed_static_plan(plan)
        assert "case2_kernel:main" in m.governor._plan_wait_points
        assert m.governor._plan_meta["wait_points"] == 1
    finally:
        m.finalize()


def test_scan_cache_hit_and_invalidation(tmp_path):
    """scan_paths serves repeated scans of unchanged trees from cache
    (plan + lint + concurrency share one parse) and invalidates on edit."""
    from repro.core.staticpass.scanner import clear_scan_cache

    clear_scan_cache()
    mod = tmp_path / "m.py"
    mod.write_text("def f():\n    return 1\n")
    first = scan_paths([str(tmp_path)])
    second = scan_paths([str(tmp_path)])
    assert [id(m) for m in first] == [id(m) for m in second]  # cache hit
    assert first is not second  # but callers get their own list

    mod.write_text("def f():\n    return 2\n\ndef g():\n    return 3\n")
    os.utime(mod, ns=(1, 1))  # force a distinct mtime even on coarse clocks
    third = scan_paths([str(tmp_path)])
    assert id(third[0]) != id(first[0])
    assert {fn.qualname for fn in third[0].functions} == {"f", "g"}
    clear_scan_cache()


def test_concurrency_never_raises_on_odd_modules(tmp_path):
    """Manual-fuzz battery: the analyzer must survive valid-but-weird
    modules (it sees arbitrary user code) and tolerate parse errors by
    recording them, never raising.  test_property_core.py runs the
    hypothesis-backed generalisation of this when hypothesis is present."""
    from repro.core.staticpass import analyze_paths

    cases = [
        # empty / comment-only / docstring-only
        "",
        "# nothing here\n",
        '"""doc"""\n',
        # locks in odd positions
        "import threading\nl = [threading.Lock() for _ in range(3)]\n",
        "import threading\ndef f(x=threading.Lock()):\n    with x:\n        pass\n",
        "import threading\nclass C:\n    lock = threading.Lock()\n"
        "    def m(self):\n        with C.lock:\n            pass\n",
        # spawn targets that cannot be resolved
        "import threading\ndef f(fn):\n"
        "    t = threading.Thread(target=fn)\n    t.start()\n    t.join()\n",
        "import threading\nthreading.Thread(target=lambda: 1).run()\n",
        # async corner cases
        "import asyncio\nasync def f():\n    await asyncio.sleep(0)\n",
        "async def g():\n    async with open_thing() as x:\n        yield x\n",
        "async def h():\n    return [i async for i in gen()]\n",
        # control flow soup
        "def f():\n    global x\n    x = (y := 1)\n    del x\n",
        "def f(a, /, b, *, c, **kw):\n    match a:\n"
        "        case [1, *rest]:\n            return rest\n"
        "        case {'k': v}:\n            return v\n"
        "        case _:\n            return b\n",
        "import os\ntry:\n    os.fork()\nfinally:\n    pass\n",
        "import threading\nwhile True:\n"
        "    t = threading.Thread(target=print)\n    t.start()\n"
        "    t.join()\n    break\n",
        # decorators, nesting, class-in-function
        "import functools\n@functools.lru_cache\ndef f():\n"
        "    def g():\n        class C:\n            pass\n        return C\n"
        "    return g\n",
    ]
    for i, src in enumerate(cases):
        p = tmp_path / f"case_{i}.py"
        p.write_text(src)
        model, findings = analyze_paths([str(p)])  # must not raise
        assert model.errors == [], (src, model.errors)

    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    model, findings = analyze_paths([str(broken)])
    assert findings == []
    assert model.errors and "broken.py" in model.errors[0]["file"]
