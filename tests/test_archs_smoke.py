"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned archs: instantiate the REDUCED config of the
same family, run one forward/train step on CPU, assert output shapes and
no NaNs.  Also checks decode-vs-prefill logit consistency (exact for
deterministic mixers; no-drop capacity for MoE).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import cache_init, decode_step, lm_init, lm_loss, prefill
from repro.models.lm import padded_vocab

B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend is not None:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend.n_tokens, cfg.frontend.dim), jnp.bfloat16
        )
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exactness(arch):
    """The FULL config matches the assignment spec (exercised via dry-run only)."""
    cfg = get_config(arch)
    spec = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "whisper-large-v3": (64, 1280, 20, 20, 5120, 51866),
    }[arch]
    n_layers, d_model, n_heads, n_kv, d_ff, vocab = spec
    assert cfg.n_layers == n_layers
    assert cfg.d_model == d_model
    assert cfg.n_heads == n_heads
    assert cfg.n_kv_heads == n_kv
    assert cfg.vocab == vocab
    if cfg.moe is not None:
        assert cfg.moe.d_ff_expert == d_ff
        assert (cfg.moe.n_experts, cfg.moe.top_k) == {
            "deepseek-moe-16b": (64, 6),
            "deepseek-v2-236b": (160, 6),
        }[arch]
        assert cfg.moe.n_shared == 2
    elif arch == "mamba2-370m":
        assert cfg.ssm is not None and cfg.ssm.d_state == 128
    else:
        assert cfg.d_ff == d_ff


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    batch = _batch(cfg, key)

    def loss_fn(p):
        loss, metrics = lm_loss(cfg, p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # expected initial loss ~ ln(padded_vocab) for random init
    assert abs(float(loss) - np.log(padded_vocab(cfg.vocab))) < 1.5
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32))) for g in flat)
    # at least one nonzero gradient leaf
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # no-drop capacity so prefill (tokens compete for expert slots) and
        # single-token decode route identically
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    key = jax.random.PRNGKey(1)
    params = lm_init(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend is not None:
        kw["patches"] = jax.random.normal(key, (B, cfg.frontend.n_tokens, cfg.frontend.dim), jnp.bfloat16)
    if cfg.encoder is not None:
        kw["frames"] = jax.random.normal(key, (B, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16)
    max_len = 32

    logits_prefill, cache = prefill(cfg, params, toks, max_len, **kw)
    assert logits_prefill.shape == (B, 1, padded_vocab(cfg.vocab))
    assert np.all(np.isfinite(np.asarray(logits_prefill)))
    assert int(cache["index"]) == S + (cfg.frontend.n_tokens if cfg.frontend else 0)

    if cfg.frontend is not None:
        # VLM: image prefix enters via prefill; check one decode step works
        logits_d, cache = decode_step(cfg, params, cache, toks[:, -1:])
        assert np.all(np.isfinite(np.asarray(logits_d)))
        return

    c = cache_init(cfg, params, B, max_len, frames=kw.get("frames"))
    logits_d = None
    for t in range(S):
        logits_d, c = decode_step(cfg, params, c, toks[:, t : t + 1])
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32),
        np.asarray(logits_prefill, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


@pytest.mark.parametrize("arch", ["gemma3-12b", "recurrentgemma-2b"])
def test_window_cache_bounded(arch):
    """Local-attention caches must be ring buffers of window size — this is
    what makes long_500k feasible for the sub-quadratic archs."""
    cfg = get_smoke_config(arch)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    max_len = 64
    c = cache_init(cfg, params, B, max_len)

    def find_local_caches(tree):
        out = []
        if isinstance(tree, dict):
            if "k" in tree and "v" in tree:
                out.append(tree)
            else:
                for v in tree.values():
                    out.extend(find_local_caches(v))
        elif isinstance(tree, list):
            for v in tree:
                out.extend(find_local_caches(v))
        return out

    kvs = find_local_caches(c)
    assert kvs
    sizes = sorted({kv["k"].shape[-3] for kv in kvs})
    assert cfg.window in sizes  # at least the local layers are window-bounded
    for size in sizes:
        assert size <= max_len


def test_long_decode_past_window():
    """Decode far past the window: ring buffer + RG-LRU state stay finite and
    depend on position (sanity for long_500k semantics)."""
    cfg = get_smoke_config("recurrentgemma-2b")
    params = lm_init(jax.random.PRNGKey(0), cfg)
    c = cache_init(cfg, params, 1, cfg.window)  # max_len == window
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    logits = None
    for t in range(cfg.window * 3):
        logits, c = step(params, c, jnp.full((1, 1), t % cfg.vocab, jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(c["index"]) == cfg.window * 3
