"""Multi-process tracing + merge — the paper's `mpirun -n 2 python -m scorep
--mpp=mpi` workflow, with JAX-style per-rank processes.

Spawns N worker processes, each running an instrumented script under
``python -m repro.scorep`` with a distinct rank; then merges the per-rank
trace streams into one clock-aligned Chrome trace (the OTF2-unification
step).

    PYTHONPATH=src python examples/trace_multiprocess.py --ranks 2
"""

import argparse
import os
import subprocess
import sys
import tempfile
import textwrap

WORKER = """
import sys, time

def compute_shard(rank, n):
    # pretend-work with rank-dependent skew (a straggler!)
    total = 0
    for i in range(n * (1 + rank)):
        total += i * i
    return total

def exchange(rank):
    time.sleep(0.01)  # stand-in for a collective

def main():
    rank = int(sys.argv[1])
    for step in range(3):
        compute_shard(rank, 50_000)
        exchange(rank)
    print(f"rank {rank} done")

main()
"""


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--ranks", type=int, default=2)
    p.add_argument("--out", default=None)
    ns = p.parse_args()

    root = ns.out or tempfile.mkdtemp(prefix="repro-mp-")
    src_path = os.path.join(root, "worker.py")
    with open(src_path, "w") as fh:
        fh.write(textwrap.dedent(WORKER))

    procs = []
    for rank in range(ns.ranks):
        env = dict(os.environ)
        env["REPRO_MONITOR_RANK"] = str(rank)
        env.setdefault("PYTHONPATH", os.path.join(os.path.dirname(__file__), "..", "src"))
        cmd = [
            sys.executable,
            "-m",
            "repro.scorep",
            "--instrumenter=profile",
            f"--out={root}",
            "--experiment=mp",
            "--no-chrome",
            src_path,
            str(rank),
        ]
        procs.append(subprocess.Popen(cmd, env=env))
    for proc in procs:
        assert proc.wait() == 0

    from repro.core.analysis import render_merge_summary
    from repro.core.merge import find_runs, merge_runs

    runs = find_runs(root, "mp")
    summary = merge_runs(runs, os.path.join(root, "merged_trace.json"))
    print(render_merge_summary(summary))
    print("open it in chrome://tracing — rank 1 runs ~2x longer per step "
          "(the skew is visible in the timeline, paper Fig. 3 style)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
