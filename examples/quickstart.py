"""Quickstart — the paper's Fig. 2 / Listing 2 example, end to end.

Run directly (self-instrumenting):
    PYTHONPATH=src python examples/quickstart.py

Or exactly like the paper's Listing 1 (no source changes needed):
    PYTHONPATH=src python -m repro.scorep --instrumenter=profile \
        examples/quickstart.py
"""

import json
import os
import sys

import repro.core as rmon


def baz():
    print("Hello World")


def foo():
    baz()


if __name__ == "__main__":
    # Self-instrument only when not already launched under repro.scorep.
    owns = rmon.active() is None
    if owns:
        rmon.init(instrumenter="profile", out_dir="repro-traces", experiment="quickstart",
                  substrates=("profiling", "tracing", "metrics", "memory"))

    foo()

    if owns:
        run_dir = rmon.finalize()
        print(f"\nartifacts in {run_dir}:")
        for name in sorted(os.listdir(run_dir)):
            print("  ", name)
        with open(os.path.join(run_dir, "profile.txt")) as fh:
            print("\n" + fh.read())

        from repro.core.analysis import load_memory_doc, render_memory
        from repro.core.report import write_report

        print("== memory hotspots ==")
        print(render_memory(load_memory_doc(run_dir), top=10))
        report = write_report(run_dir)
        print(f"\nunified report: {report} (self-contained; open in any browser)")
        print("open trace.json in chrome://tracing or https://ui.perfetto.dev"
              " (RSS/heap/GC appear as counter tracks)")
