"""End-to-end training driver example: train a small LM with the full stack
(monitoring, checkpointing + auto-resume, straggler watchdog, stateless data).

Presets (CPU-feasible by default; scale up on real hardware):
    PYTHONPATH=src python examples/train_lm.py                 # ~6M params, 60 steps
    PYTHONPATH=src python examples/train_lm.py --preset 25m    # ~25M params, 120 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # ~100M params, 300 steps

Under the monitoring CLI (paper Listing 1):
    PYTHONPATH=src python -m repro.scorep --instrumenter=monitoring \
        examples/train_lm.py -- --preset tiny
"""

import argparse
import dataclasses
import sys

import repro.core as rmon
from repro.configs import ModelConfig
from repro.launch.train import train

PRESETS = {
    # name: (d_model, n_groups, d_ff, heads, kv, vocab, steps, batch, seq)
    "tiny": (256, 4, 1024, 4, 2, 8192, 60, 4, 128),
    "25m": (512, 8, 2048, 8, 4, 16384, 120, 4, 128),
    "100m": (768, 12, 3072, 12, 4, 32768, 300, 8, 256),
}


def build_config(preset: str) -> ModelConfig:
    d, n, ff, h, kv, v, *_ = PRESETS[preset]
    return ModelConfig(
        name=f"example-lm-{preset}",
        family="dense",
        d_model=d,
        n_heads=h,
        n_kv_heads=kv,
        head_dim=d // h,
        d_ff=ff,
        vocab=v,
        pattern=(("attn", "mlp"),),
        n_groups=n,
        remat="none",
        attn_chunk_q=0,
        chunked_loss_chunks=0,
    )


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--ckpt-dir", default="/tmp/repro-example-ckpt")
    ns = p.parse_args()

    cfg = build_config(ns.preset)
    _, _, _, _, _, _, steps, batch, seq = PRESETS[ns.preset]
    steps = ns.steps or steps

    owns = rmon.active() is None
    if owns:
        rmon.init(instrumenter="none", substrates=("metrics", "profiling", "memory"),
                  out_dir="repro-traces", experiment=f"train-{ns.preset}")

    result = train(
        cfg,
        steps=steps,
        global_batch=batch,
        seq_len=seq,
        ckpt_dir=ns.ckpt_dir,
        ckpt_every=max(steps // 5, 1),
    )
    print(result)
    if owns:
        run_dir = rmon.finalize()
        print("monitoring artifacts:", run_dir)
        from repro.core.analysis import MissingArtifact, load_memory_doc, render_memory

        try:
            print("== memory hotspots ==")
            print(render_memory(load_memory_doc(run_dir), top=10))
        except MissingArtifact as exc:
            print(f"(no memory report: {exc})")
    # training must actually learn something on the synthetic distribution
    ok = result["final_loss"] is not None and result["final_loss"] < result["first_loss"]
    print("loss improved:", ok)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
