"""Serving example: batched prefill + greedy decode with monitoring.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --batch 8
"""

import argparse
import sys

import repro.core as rmon
from repro.configs import ARCHS, get_smoke_config
from repro.launch.serve import serve


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="recurrentgemma-2b", choices=list(ARCHS))
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=24)
    ns = p.parse_args()

    cfg = get_smoke_config(ns.arch)
    owns = rmon.active() is None
    if owns:
        rmon.init(instrumenter="none", substrates=("metrics", "tracing"),
                  out_dir="repro-traces", experiment=f"serve-{ns.arch}")
    result = serve(cfg, batch=ns.batch, prompt_len=ns.prompt_len, gen=ns.gen)
    print(result)
    if owns:
        print("monitoring artifacts:", rmon.finalize())
    return 0 if result["finite"] else 1


if __name__ == "__main__":
    sys.exit(main())
