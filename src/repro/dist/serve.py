"""Sharded serving: batched prefill + single-token decode.

This is the level above ``models.attention``'s documented contract: batched
decode produces one token for every sequence per call with a shared cache
length; *continuous batching* — admitting and retiring sequences in fixed
cache slots so the decode step never recompiles — lives here as
:class:`SlotAllocator`.

``jit_prefill_step`` / ``jit_serve_step`` are the AOT entries used by the
dry-run and roofline harnesses (abstract inputs, explicit shardings).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro import _compat  # noqa: F401

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.dist.train import with_act_sharding
from repro.models import cache_init, decode_step, lm_init, prefill


# ----------------------------------------------------------------------------
# Step builders (pure functions; jit at the call site or via jit_* below)
# ----------------------------------------------------------------------------

def make_prefill_step(cfg, max_len: int) -> Callable:
    """(params, batch) -> (last_logits, cache); batch keys mirror training
    minus labels (tokens + optional patches/frames)."""

    def prefill_step(params, batch):
        return prefill(
            cfg,
            params,
            batch["tokens"],
            max_len,
            patches=batch.get("patches"),
            frames=batch.get("frames"),
        )

    return prefill_step


def make_decode_step(cfg) -> Callable:
    """(params, cache, token) -> (logits, cache): one token per sequence."""

    def serve_step(params, cache, token):
        return decode_step(cfg, params, cache, token)

    return serve_step


# ----------------------------------------------------------------------------
# Abstract inputs
# ----------------------------------------------------------------------------

def prefill_batch_shapes(cfg, global_batch: int, seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    text_len = seq_len - (cfg.frontend.n_tokens if cfg.frontend else 0)
    shapes = {"tokens": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32)}
    if cfg.frontend is not None:
        shapes["patches"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend.n_tokens, cfg.frontend.dim), jnp.bfloat16
        )
    if cfg.encoder is not None:
        shapes["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16
        )
    return shapes


def abstract_cache(cfg, batch: int, max_len: int):
    """Decode-cache ShapeDtypeStructs (includes cross-attention K/V for
    enc-dec archs, so decode needs no encoder input)."""

    def build():
        params = lm_init(jax.random.PRNGKey(0), cfg)
        frames = None
        if cfg.encoder is not None:
            frames = jnp.zeros((batch, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16)
        return cache_init(cfg, params, batch, max_len, frames=frames)

    return jax.eval_shape(build)


def _abstract_params(cfg):
    return jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))


def jit_prefill_step(cfg, mesh, global_batch: int, seq_len: int, max_len: Optional[int] = None):
    """Returns (jitted, (params_s, batch_s)) for AOT lowering on ``mesh``."""
    cfg = with_act_sharding(cfg, mesh)
    max_len = max_len or seq_len
    batch_shapes = prefill_batch_shapes(cfg, global_batch, seq_len)
    params_shapes = _abstract_params(cfg)
    params_s = shd.with_shardings(params_shapes, shd.params_shardings(mesh, params_shapes))
    batch_s = shd.with_shardings(batch_shapes, shd.batch_shardings(mesh, batch_shapes))
    jitted = jax.jit(make_prefill_step(cfg, max_len))
    return jitted, (params_s, batch_s)


def jit_serve_step(cfg, mesh, global_batch: int, seq_len: int):
    """Returns (jitted, (params_s, cache_s, tok_s)): one decode step against
    a cache of ``seq_len`` already-cached tokens."""
    cfg = with_act_sharding(cfg, mesh)
    params_shapes = _abstract_params(cfg)
    cache_shapes = abstract_cache(cfg, global_batch, seq_len)
    tok_shapes = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    params_s = shd.with_shardings(params_shapes, shd.params_shardings(mesh, params_shapes))
    cache_s = shd.with_shardings(cache_shapes, shd.cache_shardings(mesh, cache_shapes))
    tok_s = jax.ShapeDtypeStruct(
        tok_shapes.shape, tok_shapes.dtype,
        sharding=jax.sharding.NamedSharding(mesh, shd.batch_spec(mesh, tok_shapes.shape)),
    )
    jitted = jax.jit(make_decode_step(cfg))
    return jitted, (params_s, cache_s, tok_s)


# ----------------------------------------------------------------------------
# Continuous batching (host-side slot bookkeeping; shapes stay static)
# ----------------------------------------------------------------------------

@dataclass
class SlotAllocator:
    """Fixed-size decode slots for continuous batching.

    The jitted decode step has a static batch dimension; sequences are
    admitted into free slots and retired on EOS/length, so arrivals never
    trigger recompilation.  Purely host-side: the device-side cache is the
    caller's pytree, slot occupancy only gates which rows are live.
    """

    n_slots: int
    active: List[Optional[Any]] = field(default_factory=list)
    admitted: int = 0
    retired: int = 0

    def __post_init__(self):
        if not self.active:
            self.active = [None] * self.n_slots

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.active) if s is None]

    @property
    def live_mask(self) -> List[bool]:
        return [s is not None for s in self.active]

    def admit(self, request: Any) -> int:
        """Place a request in a free slot; raises when saturated."""
        free = self.free_slots
        if not free:
            raise RuntimeError("no free decode slots")
        slot = free[0]
        self.active[slot] = request
        self.admitted += 1
        return slot

    def retire(self, slot: int) -> Any:
        request = self.active[slot]
        if request is None:
            raise KeyError(f"slot {slot} is not live")
        self.active[slot] = None
        self.retired += 1
        return request

    def utilization(self) -> float:
        return sum(self.live_mask) / max(self.n_slots, 1)
