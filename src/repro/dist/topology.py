"""Topology re-export for the dist layer.

:class:`ProcessTopology` lives in ``repro.core.topology`` (the monitoring
core must stay jax-free); the dist layer is its main consumer, so it is
re-exported here alongside the env helpers.
"""

from repro.core.topology import (  # noqa: F401
    ProcessTopology,
    format_mesh_shape,
    parse_mesh_shape,
)

__all__ = ["ProcessTopology", "parse_mesh_shape", "format_mesh_shape"]
