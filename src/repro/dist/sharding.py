"""Mesh partitioning rules — one shape-deterministic spec per pytree leaf.

Axis conventions (see ``repro.launch.mesh``):

  pod     pure data parallelism across pods (slowest links: only the
          per-step gradient all-reduce crosses them)
  data    batch dim of inputs; FSDP shard dim of params/optimizer state
  model   tensor parallelism (Megatron-style) + sequence parallelism for
          activations (``act_axes``)
  stage   GPipe pipeline stages (``repro.dist.pipeline``)

Rules are pure functions of (mesh, leaf shape) so params, optimizer moments
and checkpoint-restore targets always agree, and every assignment is
divisibility-checked — a spec produced here never makes GSPMD pad.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

from repro import _compat  # noqa: F401

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_BATCH_AXES = ("pod", "data")
_MODEL_AXIS = "model"
_FSDP_AXIS = "data"


def _axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 0


def _trim(entries) -> P:
    entries = list(entries)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_spec(mesh, shape: Tuple[int, ...]) -> P:
    """Partition spec for a parameter-like leaf.

    The largest dim divisible by the model-axis size is tensor-parallel;
    the largest remaining dim divisible by the data-axis size is
    FSDP-sharded.  Dims of 1 and scalars stay replicated; the pod axis
    never shards parameters (pure DP across pods).
    """
    if not shape:
        return P()
    entries: list = [None] * len(shape)
    by_size = sorted(range(len(shape)), key=lambda i: -shape[i])
    model = _axis_size(mesh, _MODEL_AXIS)
    if model > 1:
        for i in by_size:
            if shape[i] > 1 and shape[i] % model == 0:
                entries[i] = _MODEL_AXIS
                break
    fsdp = _axis_size(mesh, _FSDP_AXIS)
    if fsdp > 1:
        for i in by_size:
            if entries[i] is None and shape[i] > 1 and shape[i] % fsdp == 0:
                entries[i] = _FSDP_AXIS
                break
    return _trim(entries)


def batch_spec(mesh, shape: Tuple[int, ...]) -> P:
    """Partition spec for a host-batch leaf: leading dim over (pod, data).

    Falls back to data-only, then to replication, whenever the batch size
    does not divide — small smoke batches on big meshes must still run.
    """
    if not shape:
        return P()
    axes = tuple(a for a in _BATCH_AXES if _axis_size(mesh, a) > 0)
    rest = [None] * (len(shape) - 1)
    if axes:
        size = 1
        for a in axes:
            size *= _axis_size(mesh, a)
        if shape[0] % size == 0:
            return _trim([axes[0] if len(axes) == 1 else axes] + rest)
        if _FSDP_AXIS in axes and shape[0] % _axis_size(mesh, _FSDP_AXIS) == 0:
            return _trim([_FSDP_AXIS] + rest)
    return P()


def cache_spec(mesh, shape: Tuple[int, ...]) -> P:
    """Decode-cache leaves: batch dim over data, everything else replicated
    (KV heads rarely divide the model axis; sequence stays whole for the
    ring-buffer window update)."""
    return batch_spec(mesh, shape)


def act_axes(mesh) -> Optional[Tuple[Any, Any]]:
    """(batch_axes, seq_axes) for residual-stream constraints (Megatron-SP).

    Returned value lands in ``ModelConfig.act_pspec`` and is consumed by
    ``models.attention`` at block boundaries; None when the mesh has no
    relevant axes (single device / CPU smoke)."""
    batch = tuple(a for a in _BATCH_AXES if _axis_size(mesh, a) > 0)
    b_ax: Any = batch[0] if len(batch) == 1 else (batch or None)
    s_ax = _MODEL_AXIS if _axis_size(mesh, _MODEL_AXIS) > 1 else None
    if b_ax is None and s_ax is None:
        return None
    return (b_ax, s_ax)


# ----------------------------------------------------------------------------
# Tree-level helpers (leaves need only .shape — arrays or ShapeDtypeStructs)
# ----------------------------------------------------------------------------

def _leaf_sharding(mesh, leaf, rule) -> NamedSharding:
    shape = tuple(getattr(leaf, "shape", ()))
    return NamedSharding(mesh, rule(mesh, shape))


def params_shardings(mesh, params):
    """NamedSharding tree for model parameters (TP + FSDP)."""
    return jax.tree.map(lambda l: _leaf_sharding(mesh, l, param_spec), params)


def opt_state_shardings(mesh, opt_state):
    """Optimizer state mirrors the parameter rule (moments share shapes);
    step counters and other scalars come out replicated."""
    return jax.tree.map(lambda l: _leaf_sharding(mesh, l, param_spec), opt_state)


def batch_shardings(mesh, batch):
    """NamedSharding tree for a host batch (leading dim = global batch)."""
    return jax.tree.map(lambda l: _leaf_sharding(mesh, l, batch_spec), batch)


def cache_shardings(mesh, cache):
    """NamedSharding tree for a decode cache."""
    return jax.tree.map(lambda l: _leaf_sharding(mesh, l, cache_spec), cache)


def with_shardings(shapes, shardings):
    """Attach shardings to a ShapeDtypeStruct tree (AOT ``.lower`` inputs)."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), shapes, shardings
    )


def describe(mesh, tree) -> str:
    """One-line sharding census (debug aid): sharded/total leaf counts."""
    leaves = jax.tree.leaves(params_shardings(mesh, tree))
    sharded = sum(1 for s in leaves if tuple(s.spec))
    return f"{sharded}/{len(leaves)} leaves sharded on {dict(mesh.shape)}"
