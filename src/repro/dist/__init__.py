"""repro.dist — the distributed-monitoring layer.

Everything multi-process / multi-device routes through here so the core
monitoring layer can annotate events with a :class:`ProcessTopology`
instead of bare rank plumbing:

  sharding     mesh-axis partitioning rules (params / optimizer / batch / cache)
  train        sharded train step + AOT jit helpers for the dry-run harness
  serve        sharded prefill / decode + continuous batching slots
  compression  int8 all-reduce and top-k error-feedback gradient compression
  pipeline     GPipe stage-parallel forward over a 'stage' mesh axis
  straggler    per-step watchdog feeding the metrics substrate

Submodules import lazily (``from repro.dist import train``) so that
importing the package does not initialize jax device state — required by
the dry-run contract, which must set XLA_FLAGS first.
"""

from repro import _compat  # noqa: F401  (installs jax API shims)
from repro.core.topology import ProcessTopology  # noqa: F401

__all__ = ["ProcessTopology"]
