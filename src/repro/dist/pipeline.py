"""GPipe pipeline parallelism over a ``stage`` mesh axis.

``pipeline_forward`` runs inside ``jax.shard_map`` with per-stage
parameters: microbatches stream through the stage ring via ``ppermute``,
one scan tick per schedule slot.  With M microbatches and S stages the
schedule is the classic GPipe trapezoid — M + S - 1 ticks, bubble fraction
(S-1)/(M+S-1).

The final outputs are collected with a masked psum so every stage returns
the same (replicated) result — callers can declare ``out_specs=P(None)``.
"""

from __future__ import annotations

from typing import Callable

from repro import _compat  # noqa: F401

import jax
import jax.numpy as jnp


def pipeline_forward(
    stage_fn: Callable,
    stage_params,
    microbatches: jax.Array,  # (M, microbatch, d) — replicated across stages
    n_stages: int,
    axis_name: str,
) -> jax.Array:
    """Stage-parallel forward; returns (M, microbatch, d), replicated.

    ``stage_fn(stage_params, x)`` applies THIS device's stage (params carry
    a leading length-1 stage dim from the shard_map split); its output shape
    must equal its input shape (it feeds the next stage's input).
    """
    m = microbatches.shape[0]
    stage = jax.lax.axis_index(axis_name)
    n_ticks = m + n_stages - 1
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(recv, t):
        # Stage 0 pulls from the microbatch queue; later stages consume what
        # the previous stage sent last tick.  Past the queue end stage 0
        # re-runs the last microbatch; those outputs can't reach the final
        # stage within the schedule, so they are never observed.
        queued = jax.lax.dynamic_index_in_dim(
            microbatches, jnp.minimum(t, m - 1), axis=0, keepdims=False
        )
        x = jnp.where(stage == 0, queued, recv)
        y = stage_fn(stage_params, x)
        return jax.lax.ppermute(y, axis_name, ring), y

    _, ys = jax.lax.scan(tick, jnp.zeros_like(microbatches[0]), jnp.arange(n_ticks))

    # Final stage finishes microbatch i at tick i + (S-1); mask + psum
    # replicates the result across the stage axis.
    tail = jax.lax.slice_in_dim(ys, n_stages - 1, n_stages - 1 + m, axis=0)
    out = jnp.where(stage == n_stages - 1, tail, jnp.zeros_like(tail))
    return jax.lax.psum(out, axis_name)


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule (monitoring aid)."""
    return (n_stages - 1) / max(n_microbatches + n_stages - 1, 1)
