"""Straggler watchdog — per-step duration monitoring with mitigation hooks.

Every observed step feeds the metrics substrate (``train.step_s``); steps
slower than ``threshold`` x the windowed median are flagged
(``straggler.ratio``), and ``evict_after`` consecutive flags trigger one
mitigation event — the hook the elastic-mesh restart path (and tests) hang
off.  Flagged samples never enter the baseline window, so a stuck host
cannot normalize itself.

Events are annotated with this process's :class:`ProcessTopology`, not a
bare rank: the merged multi-rank view needs (rank, world) to attribute a
slow step to a host.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

import repro.core as rmon
from repro.core.topology import ProcessTopology


@dataclass(frozen=True)
class StragglerConfig:
    window: int = 64  # baseline samples kept
    threshold: float = 2.0  # flag when dt > threshold * median(window)
    evict_after: int = 5  # consecutive flags before a mitigation fires
    min_samples: int = 8  # no flagging until the window has this many
    metric: str = "train.step_s"  # per-step metric name fed to the substrate


class StragglerWatchdog:
    """Observe per-step wall times; flag and (synthetically) mitigate.

    ``observe(step, dt)`` returns True when the step was flagged.  The
    ``on_straggler`` callback receives one dict per mitigation (not per
    flag): {step, ratio, duration_s, baseline_s, rank, world_size}.
    """

    def __init__(
        self,
        config: Optional[StragglerConfig] = None,
        *,
        on_straggler: Optional[Callable[[Dict], None]] = None,
        topology: Optional[ProcessTopology] = None,
    ):
        self.config = config or StragglerConfig()
        self.on_straggler = on_straggler
        self.topology = topology or rmon.current_topology()
        self._window = deque(maxlen=self.config.window)
        self.observed = 0
        self.flags = 0
        self.mitigations = 0
        self._streak = 0

    def observe(self, step: int, duration_s: float) -> bool:
        cfg = self.config
        self.observed += 1
        rmon.metric(cfg.metric, duration_s)
        baseline = (
            float(np.median(self._window)) if len(self._window) >= cfg.min_samples else None
        )
        flagged = baseline is not None and baseline > 0 and duration_s > cfg.threshold * baseline
        if not flagged:
            self._streak = 0
            self._window.append(duration_s)
            return False

        ratio = duration_s / baseline
        self.flags += 1
        self._streak += 1
        rmon.metric("straggler.ratio", ratio)
        if self._streak == cfg.evict_after:
            self.mitigations += 1
            rmon.metric("straggler.mitigations", float(self.mitigations))
            event = {
                "step": step,
                "ratio": ratio,
                "duration_s": duration_s,
                "baseline_s": baseline,
                "rank": self.topology.rank,
                "world_size": self.topology.world_size,
                "mitigation": "evict",
            }
            if self.on_straggler is not None:
                self.on_straggler(event)
        return True

    def summary(self) -> Dict[str, float]:
        window = list(self._window)
        return {
            "observed": self.observed,
            "flags": self.flags,
            "mitigations": self.mitigations,
            "rank": self.topology.rank,
            "baseline_p50_s": float(np.median(window)) if window else 0.0,
            "baseline_mean_s": float(np.mean(window)) if window else 0.0,
        }
