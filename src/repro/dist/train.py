"""Sharded training step.

``make_train_step`` builds the pure (params, opt_state, batch) -> (params,
opt_state, stats) function; data/FSDP/TP placement is carried entirely by
input shardings + the activation constraints installed by
``with_act_sharding``, so the same step runs unchanged on one device or a
pod mesh (the numerical-equivalence test in tests/test_dist_features.py
holds it to that).

``jit_train_step`` is the AOT entry used by the dry-run / roofline
harnesses: it returns a jitted step plus sharding-annotated
ShapeDtypeStructs for ``.lower()`` — no parameter allocation.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro import _compat  # noqa: F401

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import lm_init, lm_loss
from repro.optim import adamw


def with_act_sharding(cfg, mesh):
    """Config with residual-stream activation constraints for ``mesh``.

    No-op (returns ``cfg`` unchanged) when the mesh has no batch/model axes,
    so CPU smoke paths keep act_pspec=None."""
    axes = shd.act_axes(mesh)
    return cfg.scaled(act_pspec=axes) if axes is not None else cfg


def _cast_params_for_compute(params, dtype):
    """Mixed precision: >=2D fp32 weights compute in bf16; fp32 masters stay
    in the optimizer (halves FSDP all-gather wire bytes)."""
    target = jnp.dtype(dtype)

    def cast(p):
        if p.ndim >= 2 and p.dtype == jnp.float32:
            return p.astype(target)
        return p

    return jax.tree.map(cast, params)


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig) -> Callable:
    """One optimizer step: loss + grad + AdamW update.

    stats: loss, ce, aux (MoE balance), grad_norm, lr.
    """

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            if cfg.params_compute_dtype == "bfloat16":
                p = _cast_params_for_compute(p, jnp.bfloat16)
            return lm_loss(cfg, p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_stats = adamw.update(opt_cfg, grads, opt_state, params)
        stats = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"], **opt_stats}
        return new_params, new_opt, stats

    return train_step


# ----------------------------------------------------------------------------
# Abstract inputs (dry-run: ShapeDtypeStructs only, no allocation)
# ----------------------------------------------------------------------------

def batch_shapes(cfg, global_batch: int, seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train batch matching the data pipeline's layout: ``seq_len``
    is the *total* sequence budget; VLM patch tokens come out of it."""
    text_len = seq_len - (cfg.frontend.n_tokens if cfg.frontend else 0)
    shapes = {
        "tokens": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, text_len), jnp.int32),
    }
    if cfg.frontend is not None:
        shapes["patches"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend.n_tokens, cfg.frontend.dim), jnp.bfloat16
        )
    if cfg.encoder is not None:
        shapes["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16
        )
    return shapes


def abstract_state(cfg) -> Tuple[Any, Any]:
    """(params, opt_state) as ShapeDtypeStruct trees."""
    params = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(adamw.init, params)
    return params, opt


def jit_train_step(cfg, mesh, opt_cfg: Optional[adamw.AdamWConfig] = None):
    """AOT compile helper: returns ``compile_for(batch_abstract) -> (jitted,
    (params_s, opt_s, batch_s))`` where the ``*_s`` trees are
    sharding-annotated ShapeDtypeStructs ready for ``jitted.lower``."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    cfg = with_act_sharding(cfg, mesh)
    step = make_train_step(cfg, opt_cfg)

    def compile_for(batch_abstract):
        params_shapes, opt_shapes = abstract_state(cfg)
        params_s = shd.with_shardings(params_shapes, shd.params_shardings(mesh, params_shapes))
        opt_s = shd.with_shardings(opt_shapes, shd.opt_state_shardings(mesh, opt_shapes))
        batch_s = shd.with_shardings(batch_abstract, shd.batch_shardings(mesh, batch_abstract))
        jitted = jax.jit(step, donate_argnums=(0, 1))
        return jitted, (params_s, opt_s, batch_s)

    return compile_for
