"""Gradient compression for the data-parallel all-reduce.

Two classic schemes, both pure jax (shard_map-compatible):

  * int8 quantized all-reduce (``int8_psum``): a shared per-tensor scale
    (pmax across the axis) keeps the integer sum exact; the only error is
    the local round-to-nearest, bounded by scale/2 per element.
  * top-k with error feedback (:class:`TopKEF`): only the k largest-
    magnitude entries are sent each step, the residual re-enters the next
    step's gradient (Stich et al., 2018) — mass is conserved exactly:
    ``sent + residual == grad + carried_error``.
"""

from __future__ import annotations

from typing import Any, Tuple

from repro import _compat  # noqa: F401

import jax
import jax.numpy as jnp


def int8_quantize(g: jax.Array, axis_name: str | None = None) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale) with
    ``g ~= q * scale``.  Inside a shard_map, pass ``axis_name`` to share the
    scale across the axis (required for an exact integer psum)."""
    amax = jnp.max(jnp.abs(g))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = (amax / 127.0).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / safe), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Mean of ``g`` across ``axis_name`` over an int8 wire format.

    Quantize with the axis-shared scale, sum the int32-widened payload
    (exact), rescale, divide by the axis size.  Wire bytes: 1/4 of fp32.
    """
    q, scale = int8_quantize(g, axis_name=axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32)


# ----------------------------------------------------------------------------
# Top-k sparsification with error feedback
# ----------------------------------------------------------------------------

def _topk_leaf(acc: jax.Array, k_fraction: float) -> jax.Array:
    flat = acc.reshape(-1)
    k = max(1, int(flat.size * k_fraction))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    sparse = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return sparse.reshape(acc.shape)


class TopKEF:
    """Top-k gradient sparsification with per-leaf error feedback.

    Usage::

        err = TopKEF.init(grads)               # zero residuals, once
        sent, err = TopKEF.compress(grads, err, k_fraction=0.01)
        # all-reduce `sent` (sparse), apply; `err` carries to next step
    """

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(jnp.zeros_like, grads)

    @staticmethod
    def compress(grads: Any, error: Any, k_fraction: float = 0.01) -> Tuple[Any, Any]:
        """Returns (sparse, new_error) with sparse + new_error == grads + error
        exactly (elementwise: each entry lands in exactly one of the two)."""
        acc = jax.tree.map(jnp.add, grads, error)
        sparse = jax.tree.map(lambda a: _topk_leaf(a, k_fraction), acc)
        new_error = jax.tree.map(jnp.subtract, acc, sparse)
        return sparse, new_error
