"""JAX API-drift shims, applied once at import.

The dist layer and its tests target the current jax surface
(``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``).  Older jaxlib builds (the CPU wheels this container
ships) predate those names; this module backfills them from their
``jax.experimental`` ancestors so the same source runs on both.  Importing
any ``repro.dist`` or ``repro.launch.mesh`` module installs the shims —
including in the subprocess harness used by the multi-device tests, which
imports ``repro.dist.*`` before touching a mesh.

Everything here is a no-op on a jax that already has the real API.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _ensure_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType (Auto/Explicit/Manual)."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _ensure_make_mesh() -> None:
    orig = getattr(jax, "make_mesh", None)
    if orig is None:
        # Pre-0.4.35 jax: synthesize make_mesh from Mesh + device reshape.
        import numpy as np

        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types
            devices = list(jax.devices() if devices is None else devices)
            n = int(np.prod(axis_shapes)) if axis_shapes else 1
            grid = np.asarray(devices[:n]).reshape(axis_shapes)
            return jax.sharding.Mesh(grid, axis_names)

        jax.make_mesh = make_mesh
        return
    try:
        params = inspect.signature(orig).parameters
    except (TypeError, ValueError):  # C-accelerated signature: assume current
        return
    if "axis_types" in params:
        return

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-AxisType jax: every mesh axis is Auto
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _ensure_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f=None, *, mesh, in_specs, out_specs, **kwargs):
        # check_rep/check_vma predates the modern replication checker and
        # rejects some valid collectives (masked psum of ppermute chains);
        # outputs declared replicated here really are (psum-produced).
        kwargs.setdefault("check_rep", False)
        if f is None:
            return functools.partial(
                shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
            )
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map


def ensure_jax_compat() -> None:
    _ensure_axis_type()
    _ensure_make_mesh()
    _ensure_shard_map()


ensure_jax_compat()
