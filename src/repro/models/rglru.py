"""Griffin recurrent block — RG-LRU + short conv (arXiv:2402.19427).

The recurrence is h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) with
a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)); it is associative in
(a, b) pairs, so training/prefill run as ``jax.lax.associative_scan``
(log-depth — the TPU-idiomatic replacement for the paper's custom GPU scan
kernel; the Pallas kernel in ``repro.kernels.rg_lru`` implements the blocked
linear-time variant for the TPU target).  Decode keeps O(1) state.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed constant on the log-rate


def rglru_init(key, d_model: int, d_rnn: int, conv_width: int = 4) -> Params:
    ks = jax.random.split(key, 5)
    # Lambda init so that a ~ uniform near 0.9..0.999 (Griffin appendix)
    u = jax.random.uniform(ks[0], (d_rnn,), minval=0.9, maxval=0.999)
    log_lambda = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "w_x": dense_init(ks[1], d_model, d_rnn),
        "w_gate_in": dense_init(ks[2], d_rnn, d_rnn, scale=0.02),
        "w_gate_rec": dense_init(ks[3], d_rnn, d_rnn, scale=0.02),
        "log_lambda": log_lambda.astype(jnp.float32),
        "conv_w": jax.random.normal(ks[4], (conv_width, d_rnn), jnp.float32)
        * (1.0 / math.sqrt(conv_width)),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 7), d_rnn, d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along time. x (B,S,N), w (W,N).

    With ``state`` (B, W-1, N) acting as left context (decode), returns the
    updated state as well."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, N)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    out = out + b.astype(x.dtype)
    new_state = xp[:, -(width - 1) :] if width > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return out, new_state


def _gates(params: Params, u: jax.Array):
    """RG-LRU gates in fp32. u (B,S,N) -> (a, b_scale, gated_input)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_gate_rec"])
    i = jax.nn.sigmoid(uf @ params["w_gate_in"])
    log_a = -_C * jax.nn.softplus(params["log_lambda"]) * r  # (B,S,N)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) multiplier on the gated input (Griffin eq. 4)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, b * (i * uf)


def rglru_scan_ref(a: jax.Array, bx: jax.Array, h0: Optional[jax.Array] = None) -> jax.Array:
    """Associative scan for h_t = a_t h_{t-1} + bx_t over axis 1 (fp32)."""
    if h0 is not None:
        # fold initial state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_apply(params: Params, x: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Full-sequence application (training / prefill). x (B,S,D)."""
    dtype = x.dtype
    u = jnp.einsum("bsd,dn->bsn", x, params["w_x"].astype(dtype))
    u, _ = _causal_conv(u, params["conv_w"], params["conv_b"])
    a, bx = _gates(params, u)
    if use_kernel:
        from repro.kernels import ops as _kops

        h = _kops.rg_lru_scan(a, bx)
    else:
        h = rglru_scan_ref(a, bx)
    return jnp.einsum("bsn,nd->bsd", h.astype(dtype), params["w_out"].astype(dtype))


# -- decode -------------------------------------------------------------------

def rglru_state_init(batch: int, d_rnn: int, conv_width: int = 4) -> Params:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), jnp.bfloat16),
    }


def rglru_prefill_state(params: Params, x: jax.Array) -> Params:
    """Run the sequence and keep the final recurrent + conv state."""
    dtype = x.dtype
    u = jnp.einsum("bsd,dn->bsn", x, params["w_x"].astype(dtype))
    u_conv, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"])
    a, bx = _gates(params, u_conv)
    h = rglru_scan_ref(a, bx)
    return {"h": h[:, -1].astype(jnp.float32), "conv": conv_state.astype(jnp.bfloat16)}


def rglru_decode(params: Params, x: jax.Array, state: Params) -> Tuple[jax.Array, Params]:
    """One-token step. x (B,1,D)."""
    dtype = x.dtype
    u = jnp.einsum("bsd,dn->bsn", x, params["w_x"].astype(dtype))
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"], state["conv"])
    a, bx = _gates(params, u)
    h = a[:, 0] * state["h"] + bx[:, 0]  # (B, N) fp32
    out = jnp.einsum("bn,nd->bd", h.astype(dtype), params["w_out"].astype(dtype))[:, None]
    return out, {"h": h, "conv": conv_state.astype(jnp.bfloat16)}
