"""Mixture-of-Experts FFN — DeepSeekMoE-style shared + fine-grained routed
experts (arXiv:2401.06066 / 2405.04434), GShard capacity-based dispatch.

TPU adaptation: routing materializes dispatch/combine one-hots of shape
(groups, S, E, C) and the expert GEMMs run as einsums with the expert axis
first — the canonical pjit-friendly formulation (the expert axis shards on
the `model` mesh axis = expert parallelism; XLA inserts the all-to-alls).
Capacity C = S * top_k / E * capacity_factor, overflow tokens are dropped
(recorded in DESIGN.md).  Token groups bound the dispatch tensor size:
S*E*C grows ~ S^2 * top_k * cf, so callers group long sequences.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_apply, mlp_init

Params = Dict[str, Any]


def moe_init(
    key,
    d_model: int,
    d_ff_expert: int,
    n_experts: int,
    n_shared: int = 0,
    d_ff_shared: Optional[int] = None,
) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    # routed experts: stacked along a leading expert axis (shards on `model`)
    e_keys = jax.random.split(ke, 3)
    params: Params = {
        "router": dense_init(kr, d_model, n_experts, scale=0.02),
        "experts": {
            "w_gate": _stack_init(e_keys[0], n_experts, d_model, d_ff_expert),
            "w_up": _stack_init(e_keys[1], n_experts, d_model, d_ff_expert),
            "w_down": _stack_init(e_keys[2], n_experts, d_ff_expert, d_model),
        },
    }
    if n_shared > 0:
        params["shared"] = mlp_init(ks, d_model, d_ff_shared or (d_ff_expert * n_shared))
    return params


def _stack_init(key, n: int, d_in: int, d_out: int) -> jax.Array:
    keys = jax.random.split(key, n)
    return jnp.stack([dense_init(k, d_in, d_out) for k in keys])


def _capacity(tokens_per_group: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / n_experts)
    return max(c, top_k)


def moe_apply(
    params: Params,
    x: jax.Array,  # (B, S, D)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 4096,
    router_noise: float = 0.0,
    rng: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar)."""
    dtype = x.dtype
    b, s, d = x.shape
    tokens = b * s
    gs = min(group_size, tokens)
    assert tokens % gs == 0, (tokens, gs)
    g = tokens // gs
    xg = x.reshape(g, gs, d)

    # -- routing (fp32) -----------------------------------------------------
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    if router_noise > 0.0 and rng is not None:
        logits = logits + router_noise * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)  # (g, gs, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (g, gs, k)
    # DeepSeek normalizes the top-k gate values to sum to 1
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # -- load-balancing auxiliary loss (Switch/GShard form) ------------------
    me = jnp.mean(probs, axis=1)  # (g, E) mean router prob
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32), axis=2), axis=1
    ) / top_k  # (g, E) fraction of tokens per expert
    aux_loss = jnp.mean(jnp.sum(me * ce, axis=-1)) * n_experts

    # -- capacity assignment --------------------------------------------------
    c = _capacity(gs, n_experts, top_k, capacity_factor)
    sel_onehot = jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32)  # (g, gs, k, E)
    # position of each (token, k) within its expert queue, in token order with
    # priority to lower k (primary routes beat secondary on overflow)
    flat = sel_onehot.transpose(0, 2, 1, 3).reshape(g, top_k * gs, n_experts)
    pos_flat = jnp.cumsum(flat, axis=1) - flat  # (g, k*gs, E)
    pos = pos_flat.reshape(g, top_k, gs, n_experts).transpose(0, 2, 1, 3)  # (g, gs, k, E)
    pos = jnp.sum(pos * sel_onehot, axis=-1).astype(jnp.int32)  # (g, gs, k)
    keep = pos < c
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # -- dispatch / combine one-hots -----------------------------------------
    pos_onehot = jax.nn.one_hot(pos, c, dtype=jnp.float32)  # (g, gs, k, C)
    # (g, gs, E, C) = sum_k sel(k) x pos(k)
    dispatch = jnp.einsum("gske,gskc->gsec", sel_onehot, pos_onehot * keep[..., None].astype(jnp.float32))
    combine = jnp.einsum("gske,gskc->gsec", sel_onehot * gate_vals[..., None], pos_onehot)

    # -- expert computation (expert axis leads; shards on `model`) -----------
    ex_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dtype), xg)  # (E, g, C, D)
    w = params["experts"]
    gate = jnp.einsum("egcd,edf->egcf", ex_in, w["w_gate"].astype(dtype))
    up = jnp.einsum("egcd,edf->egcf", ex_in, w["w_up"].astype(dtype))
    h = jax.nn.silu(gate) * up
    ex_out = jnp.einsum("egcf,efd->egcd", h, w["w_down"].astype(dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(dtype), ex_out)

    # -- shared experts (always-on dense path, DeepSeekMoE) -------------------
    if "shared" in params:
        out = out + mlp_apply(params["shared"], xg)
    return out.reshape(b, s, d), aux_loss.astype(jnp.float32)
