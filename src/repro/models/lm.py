"""Language-model tops: decoder-only LM, VLM (stub frontend), enc-dec.

Public surface (all pure functions of (cfg, params, ...)):

    lm_init(key, cfg)                         -> params
    lm_apply(cfg, params, tokens, **modal)    -> (hidden, aux_loss)
    lm_loss(cfg, params, batch)               -> (loss, metrics)
    prefill(cfg, params, tokens, max_len, **) -> (last_logits, cache)
    decode_step(cfg, params, cache, token)    -> (logits, cache)

Caches mirror the stack structure ({head: [...], groups: {pj: stacked},
tail: [...]}, plus `index`); decode scans groups with (params, cache) as xs
and the refreshed cache as scan output.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import rglru as rglru_mod
from . import ssd as ssd_mod
from .layers import (
    chunked_cross_entropy,
    dense_init,
    embed_init,
    embed_lookup,
    lm_logits,
    softmax_cross_entropy,
)
from .transformer import block_apply, norm_apply, norm_init, stack_init

Params = Dict[str, Any]


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    """Vocab padded for clean TP sharding (GPT-NeoX-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    v = padded_vocab(cfg.vocab)
    cross = cfg.encoder is not None
    params: Params = {
        "embed": embed_init(keys[0], v, cfg.d_model),
        "stack": stack_init(keys[1], cfg, cross=cross),
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], cfg.d_model, v, scale=0.02)
    if cfg.pos_embed == "learned":
        params["pos_embed"] = (
            jax.random.normal(keys[3], (cfg.max_pos, cfg.d_model), jnp.float32) * 0.01
        )
    if cfg.encoder is not None:
        enc_cfg = cfg.scaled(
            pattern=(("attn_bidir", "mlp"),),
            n_groups=cfg.encoder.n_layers,
            head_pattern=(),
            tail_pattern=(),
            encoder=None,
        )
        params["encoder"] = {
            "stack": stack_init(keys[4], enc_cfg, cross=False),
            "final_norm": norm_init(cfg),
            "pos": jax.random.normal(keys[5], (cfg.encoder.source_len, cfg.d_model), jnp.float32)
            * 0.01,
        }
    return params


# ----------------------------------------------------------------------------
# Forward (training / prefill compute)
# ----------------------------------------------------------------------------

def _embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = embed_lookup(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _encoder_out(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, source_len, D)."""
    from .transformer import stack_apply  # local import to avoid cycle at module load

    enc_cfg = cfg.scaled(
        pattern=(("attn_bidir", "mlp"),),
        n_groups=cfg.encoder.n_layers,
        head_pattern=(),
        tail_pattern=(),
        encoder=None,
    )
    p = params["encoder"]
    x = frames.astype(jnp.bfloat16) + p["pos"][None, : frames.shape[1]].astype(jnp.bfloat16)
    x, _ = stack_apply(enc_cfg, p["stack"], x)
    return norm_apply(cfg, p["final_norm"], x)


def lm_apply(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    *,
    patches: Optional[jax.Array] = None,  # VLM stub embeddings (B, P, D)
    frames: Optional[jax.Array] = None,  # audio stub embeddings (B, T, D)
) -> Tuple[jax.Array, jax.Array]:
    from .transformer import stack_apply

    x = _embed_tokens(cfg, params, tokens)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"][None, : x.shape[1]].astype(x.dtype)
    enc = _encoder_out(cfg, params, frames) if frames is not None else None
    x, aux = stack_apply(cfg, params["stack"], x, enc_kv_list=enc)
    x = norm_apply(cfg, params["final_norm"], x)
    return x, aux


def _head_matrix(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy; batch has `tokens` and `labels` (B, S)."""
    hidden, aux = lm_apply(
        cfg,
        params,
        batch["tokens"],
        patches=batch.get("patches"),
        frames=batch.get("frames"),
    )
    labels = batch["labels"]
    if batch.get("patches") is not None:
        hidden = hidden[:, -labels.shape[1] :]  # loss over text positions only
    head = _head_matrix(cfg, params)
    if cfg.chunked_loss_chunks > 1:
        ce = chunked_cross_entropy(hidden, head, labels, cfg.chunked_loss_chunks, cfg.logit_softcap)
    else:
        logits = lm_logits(hidden, head, cfg.logit_softcap)
        ce = jnp.mean(softmax_cross_entropy(logits, labels))
    aux_w = cfg.moe.aux_weight if cfg.moe is not None else 0.0
    loss = ce + aux_w * aux
    return loss, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------------
# Decode caches
# ----------------------------------------------------------------------------

def _block_cache_init(cfg: ModelConfig, spec, batch: int, max_len: int) -> Params:
    mixer, _ = spec
    hd = cfg.resolved_head_dim
    kv_dtype = jnp.dtype(cfg.kv_cache_dtype)
    if mixer == "attn":
        return attn.gqa_cache_init(batch, max_len, cfg.n_kv_heads, hd, kv_dtype)
    if mixer == "attn_local":
        w = min(cfg.window or max_len, max_len)
        return attn.gqa_cache_init(batch, w, cfg.n_kv_heads, hd, kv_dtype)
    if mixer == "mla":
        m = cfg.mla
        return attn.mla_cache_init(batch, max_len, m.kv_lora_rank, m.qk_rope_head_dim, kv_dtype)
    if mixer == "rglru":
        return rglru_mod.rglru_state_init(batch, cfg.rnn.d_rnn, cfg.rnn.conv_width)
    if mixer == "ssd":
        s = cfg.ssm
        return ssd_mod.ssd_state_init(batch, s.d_inner, s.head_dim, s.d_state, s.n_groups, s.conv_width)
    raise ValueError(mixer)


def _block_cross_cache(cfg: ModelConfig, p: Params, enc: Optional[jax.Array]) -> Params:
    if enc is None or "cross" not in p:
        return {}
    k, v = attn.cross_kv(p["cross"], enc, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"xk": k, "xv": v}


def _block_prefill(cfg, spec, p, x, max_len, enc):
    """Full-sequence block application + cache construction."""
    mixer, _ = spec
    hd = cfg.resolved_head_dim
    enc_kv = None
    if enc is not None and "cross" in p:
        enc_kv = attn.cross_kv(p["cross"], enc, cfg.n_kv_heads, hd)
    h = norm_apply(cfg, p["norm1"], x)
    kv_dtype = jnp.dtype(cfg.kv_cache_dtype)
    if mixer in ("attn", "attn_local"):
        window = cfg.window if mixer == "attn_local" else None
        cache = attn.gqa_prefill_cache(
            p["mixer"], h, max_len, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=hd, rope_theta=_theta(cfg, mixer), window=window,
            cache_dtype=kv_dtype)
    elif mixer == "mla":
        m = cfg.mla
        cache = attn.mla_prefill_cache(
            p["mixer"], h, max_len, n_heads=cfg.n_heads,
            qk_nope_head_dim=m.qk_nope_head_dim, qk_rope_head_dim=m.qk_rope_head_dim,
            v_head_dim=m.v_head_dim, rope_theta=cfg.rope_theta, cache_dtype=kv_dtype)
    elif mixer == "rglru":
        cache = rglru_mod.rglru_prefill_state(p["mixer"], h)
    elif mixer == "ssd":
        s = cfg.ssm
        cache = ssd_mod.ssd_prefill_state(
            p["mixer"], h, d_inner=s.d_inner, head_dim=s.head_dim, d_state=s.d_state,
            n_groups=s.n_groups, chunk=s.chunk)
    else:
        raise ValueError(mixer)
    cache.update(_block_cross_cache(cfg, p, enc))
    x, aux = block_apply(cfg, spec, p, x, enc_kv=enc_kv)
    return x, aux, cache


def _theta(cfg: ModelConfig, mixer: str) -> float:
    if mixer == "attn_local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _block_decode(cfg, spec, p, cache, x, index):
    mixer, _ = spec
    hd = cfg.resolved_head_dim
    h = norm_apply(cfg, p["norm1"], x)
    cross = {k: cache[k] for k in ("xk", "xv") if k in cache}
    core = {k: v for k, v in cache.items() if k not in ("xk", "xv")}
    if mixer in ("attn", "attn_local"):
        window = cfg.window if mixer == "attn_local" else None
        out, core = attn.gqa_decode(
            p["mixer"], h, core, index, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=hd, rope_theta=_theta(cfg, mixer), window=window)
    elif mixer == "mla":
        m = cfg.mla
        out, core = attn.mla_decode(
            p["mixer"], h, core, index, n_heads=cfg.n_heads,
            qk_nope_head_dim=m.qk_nope_head_dim, qk_rope_head_dim=m.qk_rope_head_dim,
            v_head_dim=m.v_head_dim, rope_theta=cfg.rope_theta)
    elif mixer == "rglru":
        out, core = rglru_mod.rglru_decode(p["mixer"], h, core)
    elif mixer == "ssd":
        s = cfg.ssm
        out, core = ssd_mod.ssd_decode(
            p["mixer"], h, core, d_inner=s.d_inner, head_dim=s.head_dim,
            d_state=s.d_state, n_groups=s.n_groups)
    else:
        raise ValueError(mixer)
    x = x + out
    if cross:
        hx = norm_apply(cfg, p["norm_x"], x)
        x = x + attn.cross_attention_apply(
            p["cross"], hx, (cross["xk"], cross["xv"]),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=hd)
    from .transformer import _ffn_apply

    x, _ = _ffn_apply(cfg, spec, p, x)
    new_cache = dict(core)
    new_cache.update(cross)
    return x, new_cache


# ----------------------------------------------------------------------------
# Prefill / decode drivers
# ----------------------------------------------------------------------------

def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    max_len: int,
    *,
    patches: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Params]:
    """Run the prompt, return (logits at last position fp32, cache)."""
    x = _embed_tokens(cfg, params, tokens)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"][None, : x.shape[1]].astype(x.dtype)
    enc = _encoder_out(cfg, params, frames) if frames is not None else None

    stack = params["stack"]
    cache: Params = {"head": [], "groups": {}, "tail": []}
    for i, spec in enumerate(cfg.head_pattern):
        x, _, c = _block_prefill(cfg, spec, stack["head"][i], x, max_len, enc)
        cache["head"].append(c)

    if cfg.n_groups > 0:
        def body(x, group_params):
            caches = {}
            for j, spec in enumerate(cfg.pattern):
                x, _, c = _block_prefill(cfg, spec, group_params[f"p{j}"], x, max_len, enc)
                caches[f"p{j}"] = c
            return x, caches

        if cfg.scan_layers:
            x, cache["groups"] = jax.lax.scan(body, x, stack["groups"])
        else:
            per_group = []
            for g in range(cfg.n_groups):
                x, c = body(x, jax.tree.map(lambda t: t[g], stack["groups"]))
                per_group.append(c)
            cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)

    for i, spec in enumerate(cfg.tail_pattern):
        x, _, c = _block_prefill(cfg, spec, stack["tail"][i], x, max_len, enc)
        cache["tail"].append(c)

    x = norm_apply(cfg, params["final_norm"], x)
    logits = lm_logits(x[:, -1:], _head_matrix(cfg, params), cfg.logit_softcap)
    cache["index"] = jnp.asarray(tokens.shape[1] + (patches.shape[1] if patches is not None else 0), jnp.int32)
    return logits, cache


def cache_init(cfg: ModelConfig, params: Params, batch: int, max_len: int,
               frames: Optional[jax.Array] = None) -> Params:
    """Empty cache (decode-from-scratch; serve_step dry-runs use this)."""
    enc = _encoder_out(cfg, params, frames) if frames is not None else None
    stack = params["stack"]
    cache: Params = {"head": [], "groups": {}, "tail": []}
    for i, spec in enumerate(cfg.head_pattern):
        c = _block_cache_init(cfg, spec, batch, max_len)
        c.update(_block_cross_cache(cfg, stack["head"][i], enc))
        cache["head"].append(c)
    for j, spec in enumerate(cfg.pattern):
        per = []
        for g in range(cfg.n_groups):
            c = _block_cache_init(cfg, spec, batch, max_len)
            if enc is not None:
                pg = jax.tree.map(lambda a: a[g], stack["groups"][f"p{j}"])
                c.update(_block_cross_cache(cfg, pg, enc))
            per.append(c)
        cache["groups"][f"p{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    for i, spec in enumerate(cfg.tail_pattern):
        c = _block_cache_init(cfg, spec, batch, max_len)
        c.update(_block_cross_cache(cfg, stack["tail"][i], enc))
        cache["tail"].append(c)
    cache["index"] = jnp.zeros((), jnp.int32)
    return cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    token: jax.Array,  # (B, 1) int32
) -> Tuple[jax.Array, Params]:
    """One token for every sequence in the batch; returns fp32 logits (B,1,V)."""
    index = cache["index"]
    x = _embed_tokens(cfg, params, token)
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], index, 1, 0)[None].astype(x.dtype)

    stack = params["stack"]
    new_cache: Params = {"head": [], "groups": {}, "tail": []}
    for i, spec in enumerate(cfg.head_pattern):
        x, c = _block_decode(cfg, spec, stack["head"][i], cache["head"][i], x, index)
        new_cache["head"].append(c)

    if cfg.n_groups > 0:
        def body(x, xs):
            group_params, group_cache = xs
            caches = {}
            for j, spec in enumerate(cfg.pattern):
                x, c = _block_decode(cfg, spec, group_params[f"p{j}"], group_cache[f"p{j}"], x, index)
                caches[f"p{j}"] = c
            return x, caches

        if cfg.scan_layers:
            x, new_cache["groups"] = jax.lax.scan(body, x, (stack["groups"], cache["groups"]))
        else:
            per_group = []
            for g in range(cfg.n_groups):
                x, c = body(
                    x,
                    jax.tree.map(lambda t: t[g], (stack["groups"], cache["groups"])),
                )
                per_group.append(c)
            new_cache["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)

    for i, spec in enumerate(cfg.tail_pattern):
        x, c = _block_decode(cfg, spec, stack["tail"][i], cache["tail"][i], x, index)
        new_cache["tail"].append(c)

    x = norm_apply(cfg, params["final_norm"], x)
    logits = lm_logits(x, _head_matrix(cfg, params), cfg.logit_softcap)
    new_cache["index"] = index + 1
    return logits, new_cache
