"""Model substrate: layers, mixers (GQA/MLA/RG-LRU/SSD), MoE, assembly."""

from . import attention, layers, lm, moe, rglru, ssd, transformer  # noqa: F401
from .lm import cache_init, decode_step, lm_apply, lm_init, lm_loss, prefill  # noqa: F401
