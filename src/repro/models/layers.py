"""Shared neural-net layers (pure-functional JAX; params are pytrees).

Precision policy (TPU-idiomatic): parameters are stored fp32, matmul
activations run bf16, normalization / softmax / router statistics run fp32.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# ----------------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(jnp.float32)


def embed_init(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * 0.02


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterization: zero-init == identity
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------

def rope_tables(positions: jax.Array, dim: int, theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding; positions (...,) -> (..., dim//2)."""
    freqs = jnp.exp(
        -jnp.log(theta) * (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim//2)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ----------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ----------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
    }


def mlp_apply(params: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    dtype = x.dtype
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    if activation == "silu":
        act = jax.nn.silu(gate)
    elif activation == "gelu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        raise ValueError(f"unknown activation {activation}")
    return jnp.einsum("...f,fd->...d", act * up, params["w_down"].astype(dtype))


# ----------------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------------

def embed_lookup(embedding: jax.Array, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0).astype(dtype)


def lm_logits(x: jax.Array, head: jax.Array, softcap: Optional[float] = None) -> jax.Array:
    """x: (..., D) @ head (D, V) -> fp32 logits with optional soft-capping."""
    logits = jnp.einsum("...d,dv->...v", x, head.astype(x.dtype)).astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token loss; logits (..., V) fp32, labels (...) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def chunked_cross_entropy(
    x: jax.Array,
    head: jax.Array,
    labels: jax.Array,
    n_chunks: int = 8,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Cross entropy without materializing full (B, S, V) logits.

    ``lax.scan`` over sequence chunks with a ``jax.checkpoint``-ed body:
    forward and backward both hold one chunk's logits at a time, so peak
    logit memory drops ~n_chunks x.  The baseline path (n_chunks <= 1)
    materializes (B, S, V) logits directly.  (Roofline lowering uses the
    baseline path so XLA's cost model sees every flop — scan bodies are
    costed once; see benchmarks/roofline.py.)
    """
    b, s, d = x.shape
    assert s % n_chunks == 0, (s, n_chunks)
    sc = s // n_chunks
    xc = x.reshape(b, n_chunks, sc, d).swapaxes(0, 1)  # (C, B, s', D)
    lc = labels.reshape(b, n_chunks, sc).swapaxes(0, 1)

    @jax.checkpoint
    def body(total, xs):
        xi, li = xs
        logits = lm_logits(xi, head, softcap)
        return total + jnp.sum(softmax_cross_entropy(logits, li)), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)
