"""Block assembly: pattern-based stacks with group-scan, LM / enc-dec tops.

Design notes
------------
* Layers are grouped by ``cfg.pattern`` and scanned with ``jax.lax.scan``
  over stacked parameters — HLO size stays O(pattern) not O(depth), which
  keeps 512-device lowering fast for 60-layer models.
* Heterogeneous stacks (gemma3's 5 local : 1 global, recurrentgemma's
  R,R,A) are expressed inside the pattern, so the scan body stays static.
* ``remat`` wraps the scanned group body in ``jax.checkpoint``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssd as ssd_mod
from .layers import (
    chunked_cross_entropy,
    dense_init,
    embed_init,
    embed_lookup,
    layer_norm,
    lm_logits,
    mlp_apply,
    mlp_init,
    rms_norm,
    softmax_cross_entropy,
)

Params = Dict[str, Any]

# ----------------------------------------------------------------------------
# Norm helpers (rms for llama/gemma-likes, layer for whisper)
# ----------------------------------------------------------------------------

def norm_init(cfg: ModelConfig) -> Params:
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def norm_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layer":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ----------------------------------------------------------------------------
# Plain (non-gated) MLP for whisper
# ----------------------------------------------------------------------------

def plain_mlp_init(key, d_model: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, d_model, d_ff), "b1": jnp.zeros((d_ff,), jnp.float32),
            "w2": dense_init(k2, d_ff, d_model), "b2": jnp.zeros((d_model,), jnp.float32)}


def plain_mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w1"].astype(dtype)) + p["b1"].astype(dtype))
    return jnp.einsum("...f,fd->...d", h, p["w2"].astype(dtype)) + p["b2"].astype(dtype)


# ----------------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------------

def _mixer_init(key, cfg: ModelConfig, mixer: str) -> Params:
    hd = cfg.resolved_head_dim
    if mixer in ("attn", "attn_local", "attn_bidir"):
        return attn.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                             qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    if mixer == "mla":
        m = cfg.mla
        return attn.mla_init(key, cfg.d_model, cfg.n_heads, m.q_lora_rank, m.kv_lora_rank,
                             m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim)
    if mixer == "rglru":
        return rglru_mod.rglru_init(key, cfg.d_model, cfg.rnn.d_rnn, cfg.rnn.conv_width)
    if mixer == "ssd":
        s = cfg.ssm
        return ssd_mod.ssd_init(key, cfg.d_model, s.d_inner, s.head_dim, s.d_state,
                                s.n_groups, s.conv_width)
    raise ValueError(f"unknown mixer {mixer}")


def _ffn_init(key, cfg: ModelConfig, ffn: str) -> Optional[Params]:
    if ffn == "none":
        return None
    if ffn == "mlp":
        if cfg.gated_mlp:
            return mlp_init(key, cfg.d_model, cfg.d_ff)
        return plain_mlp_init(key, cfg.d_model, cfg.d_ff)
    if ffn == "moe":
        m = cfg.moe
        return moe_mod.moe_init(key, cfg.d_model, m.d_ff_expert, m.n_experts,
                                m.n_shared, m.d_ff_shared)
    raise ValueError(f"unknown ffn {ffn}")


def block_init(key, cfg: ModelConfig, spec: Tuple[str, str], cross: bool = False) -> Params:
    mixer, ffn = spec
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": norm_init(cfg), "mixer": _mixer_init(k1, cfg, mixer)}
    if cross:
        p["norm_x"] = norm_init(cfg)
        p["cross"] = attn.gqa_init(k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias)
    f = _ffn_init(k2, cfg, ffn)
    if f is not None:
        p["norm2"] = norm_init(cfg)
        p["ffn"] = f
    return p


def _layer_theta(cfg: ModelConfig, mixer: str) -> float:
    if mixer == "attn_local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _ffn_apply(cfg: ModelConfig, spec: Tuple[str, str], p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss)."""
    _, ffn = spec
    zero = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, zero
    h = norm_apply(cfg, p["norm2"], x)
    if ffn == "mlp":
        if cfg.gated_mlp:
            out = mlp_apply(p["ffn"], h, cfg.activation)
        else:
            out = plain_mlp_apply(p["ffn"], h)
        return x + out, zero
    m = cfg.moe
    out, aux = moe_mod.moe_apply(p["ffn"], h, n_experts=m.n_experts, top_k=m.top_k,
                                 capacity_factor=m.capacity_factor, group_size=m.group_size)
    return x + out, aux


def block_apply(
    cfg: ModelConfig,
    spec: Tuple[str, str],
    p: Params,
    x: jax.Array,
    *,
    enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block (train / prefill compute). Returns (x, aux)."""
    mixer, _ = spec
    hd = cfg.resolved_head_dim
    h = norm_apply(cfg, p["norm1"], x)
    if mixer in ("attn", "attn_local", "attn_bidir"):
        window = cfg.window if mixer == "attn_local" else None
        out = attn.gqa_apply(
            p["mixer"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=hd,
            rope_theta=_layer_theta(cfg, mixer), causal=(mixer != "attn_bidir"),
            window=window, positions=positions, chunk_q=cfg.attn_chunk_q,
            use_flash_kernel=cfg.use_flash_kernel, act_pspec=cfg.act_pspec)
    elif mixer == "mla":
        m = cfg.mla
        out = attn.mla_apply(p["mixer"], h, n_heads=cfg.n_heads,
                             qk_nope_head_dim=m.qk_nope_head_dim,
                             qk_rope_head_dim=m.qk_rope_head_dim,
                             v_head_dim=m.v_head_dim, rope_theta=cfg.rope_theta,
                             positions=positions, chunk_q=cfg.attn_chunk_q,
                             act_pspec=cfg.act_pspec)
    elif mixer == "rglru":
        out = rglru_mod.rglru_apply(p["mixer"], h, use_kernel=cfg.use_scan_kernels)
    elif mixer == "ssd":
        s = cfg.ssm
        out = ssd_mod.ssd_apply(p["mixer"], h, d_inner=s.d_inner, head_dim=s.head_dim,
                                d_state=s.d_state, n_groups=s.n_groups, chunk=s.chunk,
                                use_kernel=cfg.use_scan_kernels)
    else:
        raise ValueError(mixer)
    x = x + out
    if "cross" in p and enc_kv is not None:
        hx = norm_apply(cfg, p["norm_x"], x)
        x = x + attn.cross_attention_apply(p["cross"], hx, enc_kv, n_heads=cfg.n_heads,
                                           n_kv_heads=cfg.n_kv_heads, head_dim=hd)
    return _ffn_apply(cfg, spec, p, x)


# ----------------------------------------------------------------------------
# Stacks (head + scanned groups + tail)
# ----------------------------------------------------------------------------

def _stack_trees(trees: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    keys = jax.random.split(key, 3)
    head = [block_init(jax.random.fold_in(keys[0], i), cfg, spec, cross)
            for i, spec in enumerate(cfg.head_pattern)]
    groups: Dict[str, Params] = {}
    for j, spec in enumerate(cfg.pattern):
        per_group = [block_init(jax.random.fold_in(keys[1], g * 131 + j), cfg, spec, cross)
                     for g in range(cfg.n_groups)]
        groups[f"p{j}"] = _stack_trees(per_group)
    tail = [block_init(jax.random.fold_in(keys[2], i), cfg, spec, cross)
            for i, spec in enumerate(cfg.tail_pattern)]
    return {"head": head, "groups": groups, "tail": tail}


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def constrain_acts(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Megatron-SP residual-stream constraint: (batch, seq, d) sharded
    (batch_axes, seq_axes, None).  The scan carry saved for backward is the
    sharded tensor, cutting per-device activation memory by the model-axis
    width; XLA inserts the all-gather / reduce-scatter pair around each
    block's TP matmuls (standard sequence parallelism)."""
    if cfg.act_pspec is None or x.ndim != 3:
        return x
    batch_axes, seq_axes = cfg.act_pspec
    from jax.sharding import PartitionSpec as P

    spec = P(batch_axes, seq_axes, None)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):  # no mesh context (CPU smoke paths)
        return x


def stack_apply(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    *,
    enc_kv_list: Optional[List] = None,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Apply the whole stack; returns (x, total_aux_loss).

    ``enc_kv_list``: for enc-dec decoders, per-position cross K/V. The scanned
    groups receive stacked cross K/V is not supported — whisper's uniform
    decoder computes cross K/V inside the block from a closed-over encoder
    output instead (see ``encdec_apply``)."""
    aux = jnp.zeros((), jnp.float32)
    enc_out = enc_kv_list  # only used via closure in group body for enc-dec
    x = constrain_acts(cfg, x)

    for i, spec in enumerate(cfg.head_pattern):
        x, a = block_apply(cfg, spec, params["head"][i], x,
                           enc_kv=_cross_kv_for(cfg, params["head"][i], enc_out),
                           positions=positions)
        x = constrain_acts(cfg, x)
        aux = aux + a

    if cfg.n_groups > 0:
        def group_body(carry, group_params):
            x, aux = carry
            for j, spec in enumerate(cfg.pattern):
                p = group_params[f"p{j}"]
                x, a = block_apply(cfg, spec, p, x,
                                   enc_kv=_cross_kv_for(cfg, p, enc_out),
                                   positions=positions)
                x = constrain_acts(cfg, x)
                aux = aux + a
            return (x, aux), None

        body = _maybe_remat(cfg, group_body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])
        else:  # unrolled: every layer visible to the XLA cost model
            for g in range(cfg.n_groups):
                (x, aux), _ = body((x, aux), jax.tree.map(lambda t: t[g], params["groups"]))

    for i, spec in enumerate(cfg.tail_pattern):
        x, a = block_apply(cfg, spec, params["tail"][i], x,
                           enc_kv=_cross_kv_for(cfg, params["tail"][i], enc_out),
                           positions=positions)
        x = constrain_acts(cfg, x)
        aux = aux + a
    return x, aux


def _cross_kv_for(cfg: ModelConfig, block_params: Params, enc_out) -> Optional[Tuple]:
    if enc_out is None or "cross" not in block_params:
        return None
    return attn.cross_kv(block_params["cross"], enc_out, cfg.n_kv_heads, cfg.resolved_head_dim)
