"""Attention mixers: GQA (full / sliding-window) and MLA (DeepSeek-V2).

All functions are pure; caches are explicit pytrees.  Shapes:
  x        (B, S, D)
  q        (B, S, K, G, h)   K = kv heads, G = query heads per kv head
  k, v     (B, T, K, h)
Decode steps take a cache pytree + scalar ``index`` (tokens already cached).
Batched serving decodes one token for every sequence per call; all sequences
in the batch share the cache length (continuous batching is handled a level
up, in ``repro.dist.serve``).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, rms_norm, rope_tables

Params = Dict[str, Any]

NEG_INF = -2.3819763e38  # large negative for masking (fits bf16/f32)


def _constrain(x: jax.Array, spec) -> jax.Array:
    """Best-effort sharding constraint (no-op without a mesh context).

    GSPMD's propagation gives up on the 5D grouped-GQA einsums and falls
    back to full replication of q/scores (a multi-GB all-gather per layer at
    32k context); pinning q and the score tensor to sequence-sharding keeps
    attention in the Megatron-SP regime: each device computes its query
    slice against (gathered, cheap) K/V."""
    if spec is None:
        return x
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError):
        return x


# ----------------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------------

def gqa_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool = False,
    qk_norm: bool = False,
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, d_model, n_heads * head_dim),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim),
        "wo": dense_init(k4, n_heads * head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


def _project_qkv(params: Params, x: jax.Array, n_heads: int, n_kv_heads: int, head_dim: int):
    dtype = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k, v


def _sdpa(
    q: jax.Array,  # (B, S, K, G, h)
    k: jax.Array,  # (B, T, K, h)
    v: jax.Array,  # (B, T, K, h)
    mask: jax.Array,  # (S, T) or (B, S, T) additive fp32
    scale: float,
    act_pspec=None,
) -> jax.Array:
    dtype = q.dtype
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if act_pspec is not None and scores.shape[3] > 1:
        b_ax, s_ax = act_pspec
        scores = _constrain(scores, (b_ax, None, None, s_ax, None))
    while mask.ndim < scores.ndim:
        mask = mask[None]
    scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out


def causal_mask(s: int, t: int, offset: int = 0, window: Optional[int] = None) -> jax.Array:
    """Additive mask; query i (absolute position offset+i) sees key j<=i,
    and only keys within ``window`` positions when set (sliding window)."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_q_chunked(
    q: jax.Array,  # (B, S, K, G, h)
    k: jax.Array,  # (B, T, K, h)
    v: jax.Array,  # (B, T, K, h)
    scale: float,
    *,
    causal: bool,
    window: Optional[int],
    chunk: int,
    act_pspec=None,
) -> jax.Array:
    """Query-chunked attention: ``lax.scan`` over query blocks bounds the
    live score tensor to (B,K,G,chunk,T) — the XLA-level flash-attention
    adaptation used when the Pallas kernel path is off.  The scan body is
    ``jax.checkpoint``-ed so backward recomputes one block's scores at a
    time instead of saving them all.

    Note for cost accounting: XLA's cost model counts a scan body ONCE, so
    this path undercounts attention FLOPs by ~nq; the roofline harness
    therefore lowers with ``attn_chunk_q=0`` (identical math, fully costed)
    while dry-run memory proofs use this path (see benchmarks/roofline.py)."""
    b, s, kh, g, h = q.shape
    t = k.shape[1]
    assert s % chunk == 0, (s, chunk)
    nq = s // chunk
    qc = q.reshape(b, nq, chunk, kh, g, h).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(t)[None, :]

    @jax.checkpoint
    def body(carry, args):
        iq, qblk = args
        qpos = iq * chunk + jnp.arange(chunk)[:, None]
        ok = jnp.ones((chunk, t), bool)
        if causal:
            ok = ok & (kpos <= qpos)
        if window is not None:
            ok = ok & (kpos > qpos - window)
        mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        return carry, _sdpa(qblk, k, v, mask, scale, act_pspec=act_pspec)

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kh, g, h)


def gqa_apply(
    params: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    causal: bool = True,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,
    chunk_q: int = 0,
    use_flash_kernel: bool = False,
    act_pspec=None,
) -> jax.Array:
    """Self-attention over a full sequence (training / prefill)."""
    dtype = x.dtype
    b, s, d = x.shape
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)
    cos, sin = rope_tables(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if act_pspec is not None:
        b_ax, s_ax = act_pspec
        q = _constrain(q, (b_ax, s_ax, None, None))  # query: SP over seq
        k = _constrain(k, (b_ax, None, None, None))  # K/V: gathered once
        v = _constrain(v, (b_ax, None, None, None))
    scale = 1.0 / math.sqrt(head_dim)
    if use_flash_kernel:
        from repro.kernels import ops as _kops

        out = _kops.flash_attention(
            q, k, v, causal=causal, window=window
        ).reshape(b, s, n_kv_heads, g, head_dim)
    else:
        q = q.reshape(b, s, n_kv_heads, g, head_dim)
        if chunk_q and s > chunk_q and s % chunk_q == 0:
            out = _sdpa_q_chunked(q, k, v, scale, causal=causal, window=window,
                                  chunk=chunk_q, act_pspec=act_pspec)
        else:
            if causal:
                mask = causal_mask(s, s, window=window)
            else:
                mask = jnp.zeros((s, s), jnp.float32)
            out = _sdpa(q, k, v, mask, scale, act_pspec=act_pspec)
    out = out.reshape(b, s, n_heads * head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype))


def cross_attention_apply(
    params: Params,
    x: jax.Array,
    kv_source: Tuple[jax.Array, jax.Array],
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
) -> jax.Array:
    """Cross-attention with precomputed K/V (whisper decoder)."""
    dtype = x.dtype
    b, s, _ = x.shape
    g = n_heads // n_kv_heads
    k, v = kv_source
    t = k.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
    q = q.reshape(b, s, n_kv_heads, g, head_dim)
    mask = jnp.zeros((s, t), jnp.float32)
    out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(head_dim))
    out = out.reshape(b, s, n_heads * head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype))


def cross_kv(params: Params, enc: jax.Array, n_kv_heads: int, head_dim: int):
    dtype = enc.dtype
    b, t, _ = enc.shape
    k = jnp.einsum("btd,dh->bth", enc, params["wk"].astype(dtype))
    v = jnp.einsum("btd,dh->bth", enc, params["wv"].astype(dtype))
    if "bk" in params:
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    return k.reshape(b, t, n_kv_heads, head_dim), v.reshape(b, t, n_kv_heads, head_dim)


# -- caches -------------------------------------------------------------------

def gqa_cache_init(
    batch: int, max_len: int, n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16
) -> Params:
    shape = (batch, max_len, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_prefill_cache(
    params: Params,
    x: jax.Array,
    max_len: int,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    window: Optional[int] = None,
    cache_dtype=None,
) -> Params:
    """Compute K/V for a prompt and lay it into a fresh cache.

    Window layers keep a ring buffer of the last ``window`` positions, so the
    cache is (B, min(window, max_len), K, h) — this is what makes 500k-token
    contexts feasible for local-attention architectures."""
    b, s, _ = x.shape
    dtype = cache_dtype or x.dtype
    _, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    positions = jnp.arange(s)
    cos, sin = rope_tables(positions, head_dim, rope_theta)
    k = apply_rope(k, cos, sin)
    if window is not None and window < max_len:
        w = window
        cache = gqa_cache_init(b, w, n_kv_heads, head_dim, dtype)
        # last w positions land at slot p % w
        take = min(s, w)
        tail_k = k[:, -take:].astype(dtype)
        tail_v = v[:, -take:].astype(dtype)
        slot = (jnp.arange(s - take, s)) % w
        cache["k"] = cache["k"].at[:, slot].set(tail_k)
        cache["v"] = cache["v"].at[:, slot].set(tail_v)
        return cache
    cache = gqa_cache_init(b, max_len, n_kv_heads, head_dim, dtype)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k.astype(dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v.astype(dtype), (0, 0, 0, 0))
    return cache


def gqa_decode(
    params: Params,
    x: jax.Array,  # (B, 1, D)
    cache: Params,
    index: jax.Array,  # scalar int32: number of tokens already in cache
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    window: Optional[int] = None,
) -> Tuple[jax.Array, Params]:
    dtype = x.dtype
    b = x.shape[0]
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = jnp.asarray(index)[None]
    cos, sin = rope_tables(pos, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    t = cache["k"].shape[1]
    if window is not None and t <= window:
        slot = jnp.mod(index, t)
    else:
        slot = index
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    if window is not None and t <= window:
        # ring buffer: slot j holds absolute position p_j = index - ((index - j) mod t)
        j = jnp.arange(t)
        p = index - jnp.mod(index - j, t)
        mask = jnp.where(p >= 0, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # (1, t)
    else:
        j = jnp.arange(t)
        mask = jnp.where(j <= index, 0.0, NEG_INF).astype(jnp.float32)[None, :]

    q = q.reshape(b, 1, n_kv_heads, g, head_dim)
    out = _sdpa(q, ck.astype(dtype), cv.astype(dtype), mask, 1.0 / math.sqrt(head_dim))
    out = out.reshape(b, 1, n_heads * head_dim)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype))
    return out, {"k": ck, "v": cv}


# ----------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)
# ----------------------------------------------------------------------------

def mla_init(
    key,
    d_model: int,
    n_heads: int,
    q_lora_rank: int,
    kv_lora_rank: int,
    qk_nope_head_dim: int,
    qk_rope_head_dim: int,
    v_head_dim: int,
) -> Params:
    ks = jax.random.split(key, 6)
    dn, dr, dv = qk_nope_head_dim, qk_rope_head_dim, v_head_dim
    return {
        "wq_a": dense_init(ks[0], d_model, q_lora_rank),
        "q_norm": jnp.zeros((q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], q_lora_rank, n_heads * (dn + dr)),
        "wkv_a": dense_init(ks[2], d_model, kv_lora_rank + dr),
        "kv_norm": jnp.zeros((kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(ks[3], kv_lora_rank, n_heads * (dn + dv)),
        "wo": dense_init(ks[4], n_heads * dv, d_model),
    }


def _mla_qkv(params: Params, x: jax.Array, n_heads: int, dims: Tuple[int, int, int]):
    """Returns (q_nope, q_rope, c_kv, k_rope) before rope application."""
    dn, dr, dv = dims
    dtype = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(dtype))
    q = rms_norm(q, params["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", q, params["wq_b"].astype(dtype))
    q = q.reshape(b, s, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dtype))
    c_kv, k_rope = kv[..., : kv.shape[-1] - dr], kv[..., kv.shape[-1] - dr :]
    c_kv = rms_norm(c_kv, params["kv_norm"])
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params: Params, c_kv: jax.Array, n_heads: int, dims: Tuple[int, int, int]):
    dn, dr, dv = dims
    dtype = c_kv.dtype
    b, t, _ = c_kv.shape
    kv = jnp.einsum("btr,rh->bth", c_kv, params["wkv_b"].astype(dtype))
    kv = kv.reshape(b, t, n_heads, dn + dv)
    return kv[..., :dn], kv[..., dn:]  # k_nope, v


def mla_apply(
    params: Params,
    x: jax.Array,
    *,
    n_heads: int,
    qk_nope_head_dim: int,
    qk_rope_head_dim: int,
    v_head_dim: int,
    rope_theta: float = 10000.0,
    positions: Optional[jax.Array] = None,
    chunk_q: int = 0,
    act_pspec=None,
) -> jax.Array:
    dims = (qk_nope_head_dim, qk_rope_head_dim, v_head_dim)
    dn, dr, dv = dims
    dtype = x.dtype
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, n_heads, dims)
    if positions is None:
        positions = jnp.arange(s)
    cos, sin = rope_tables(positions, dr, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]  # shared rope head
    k_nope, v = _mla_expand_kv(params, c_kv, n_heads, dims)
    scale = 1.0 / math.sqrt(dn + dr)
    if act_pspec is not None:
        b_ax, s_ax = act_pspec
        q_nope = _constrain(q_nope, (b_ax, s_ax, None, None))
        q_rope = _constrain(q_rope, (b_ax, s_ax, None, None))
        k_nope = _constrain(k_nope, (b_ax, None, None, None))
        v = _constrain(v, (b_ax, None, None, None))

    def attend(qn, qr, offset):
        sq = qn.shape[1]
        scores = (
            jnp.einsum("bshn,bthn->bhst", qn, k_nope)
            + jnp.einsum("bshr,btr->bhst", qr, k_rope)
        ).astype(jnp.float32) * scale
        if act_pspec is not None and sq > 1:
            b_ax, s_ax = act_pspec
            scores = _constrain(scores, (b_ax, None, s_ax, None))
        scores = scores + causal_mask(sq, s, offset=offset)[None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        return jnp.einsum("bhst,bthv->bshv", probs, v)

    if chunk_q and s > chunk_q and s % chunk_q == 0:
        nq = s // chunk_q
        qn_c = q_nope.reshape(b, nq, chunk_q, n_heads, dn).transpose(1, 0, 2, 3, 4)
        qr_c = q_rope.reshape(b, nq, chunk_q, n_heads, dr).transpose(1, 0, 2, 3, 4)

        @jax.checkpoint
        def body(carry, args):
            iq, qn, qr = args
            return carry, attend(qn, qr, iq * chunk_q)

        _, out = jax.lax.scan(body, None, (jnp.arange(nq), qn_c, qr_c))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, n_heads * dv)
    else:
        out = attend(q_nope, q_rope, 0).reshape(b, s, n_heads * dv)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype))


def mla_cache_init(batch: int, max_len: int, kv_lora_rank: int, qk_rope_head_dim: int, dtype=jnp.bfloat16) -> Params:
    # The MLA selling point: cache only the compressed latent + shared rope key.
    return {
        "c_kv": jnp.zeros((batch, max_len, kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, qk_rope_head_dim), dtype),
    }


def mla_prefill_cache(
    params: Params,
    x: jax.Array,
    max_len: int,
    *,
    n_heads: int,
    qk_nope_head_dim: int,
    qk_rope_head_dim: int,
    v_head_dim: int,
    rope_theta: float = 10000.0,
    cache_dtype=None,
) -> Params:
    dims = (qk_nope_head_dim, qk_rope_head_dim, v_head_dim)
    b, s, _ = x.shape
    dtype = cache_dtype or x.dtype
    _, _, c_kv, k_rope = _mla_qkv(params, x, n_heads, dims)
    cos, sin = rope_tables(jnp.arange(s), qk_rope_head_dim, rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    cache = mla_cache_init(b, max_len, c_kv.shape[-1], qk_rope_head_dim, dtype)
    cache["c_kv"] = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(dtype), (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(dtype), (0, 0, 0))
    return cache


def mla_decode(
    params: Params,
    x: jax.Array,
    cache: Params,
    index: jax.Array,
    *,
    n_heads: int,
    qk_nope_head_dim: int,
    qk_rope_head_dim: int,
    v_head_dim: int,
    rope_theta: float = 10000.0,
) -> Tuple[jax.Array, Params]:
    dims = (qk_nope_head_dim, qk_rope_head_dim, v_head_dim)
    dn, dr, dv = dims
    dtype = x.dtype
    b = x.shape[0]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, n_heads, dims)
    pos = jnp.asarray(index)[None]
    cos, sin = rope_tables(pos, dr, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, index, 0))
    cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, index, 0))
    t = cc.shape[1]
    k_nope, v = _mla_expand_kv(params, cc.astype(dtype), n_heads, dims)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
        + jnp.einsum("bshr,btr->bhst", q_rope, cr.astype(dtype))
    ).astype(jnp.float32) * scale
    mask = jnp.where(jnp.arange(t) <= index, 0.0, NEG_INF).astype(jnp.float32)
    scores = scores + mask[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bhst,bthv->bshv", probs, v).reshape(b, 1, n_heads * dv)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype))
    return out, {"c_kv": cc, "k_rope": cr}
