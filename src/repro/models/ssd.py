"""Mamba-2 block — State Space Duality / SSD (arXiv:2405.21060).

Training/prefill use the chunked SSD algorithm (intra-chunk "attention-like"
einsums + inter-chunk linear recurrence over per-chunk states), which maps
onto the MXU as dense matmuls — exactly the duality the paper exploits; the
Pallas kernel in ``repro.kernels.ssd`` implements the fused chunk-scan for
the TPU target.  Decode keeps the O(1) recurrent state h (B, H, P, N).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

Params = Dict[str, Any]


def ssd_init(
    key,
    d_model: int,
    d_inner: int,
    head_dim: int,
    d_state: int,
    n_groups: int = 1,
    conv_width: int = 4,
) -> Params:
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 4)
    d_conv_in = d_inner + 2 * n_groups * d_state  # x, B, C share the conv
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads  # +z, +dt
    # dt bias init so softplus(dt_bias) ~ U[1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[0], (n_heads,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(u)))
    return {
        "in_proj": dense_init(ks[1], d_model, d_in_proj),
        "conv_w": jax.random.normal(ks[2], (conv_width, d_conv_in), jnp.float32)
        * (1.0 / math.sqrt(conv_width)),
        "conv_b": jnp.zeros((d_conv_in,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),  # A = -exp(a_log)
        "dt_bias": dt_bias.astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], d_inner, d_model),
    }


def _split_proj(params: Params, x: jax.Array, d_inner: int, n_groups: int, d_state: int, n_heads: int):
    dtype = x.dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dtype))
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n_groups * d_state], axis=-1)
    return z, xbc, dt


def _conv(xbc: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + xp[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    new_state = xp[:, -(width - 1) :]
    return out, new_state


def segsum(log_a: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} log_a[..., k],
    lower-triangular, -inf above the diagonal.  log_a (..., L)."""
    l = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # i row, j col: sum_{j+1..i}
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(
    x: jax.Array,  # (B, S, H, P) fp32
    dt: jax.Array,  # (B, S, H) fp32 (post-softplus)
    a: jax.Array,  # (H,) fp32 negative
    b_in: jax.Array,  # (B, S, G, N) fp32
    c_in: jax.Array,  # (B, S, G, N) fp32
    chunk: int = 64,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD; returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)  # (B,nc,L,H,N)
    cc = jnp.repeat(c_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    log_a = dtc * a  # (B,nc,L,H) negative increments
    log_a_h = log_a.transpose(0, 1, 3, 2)  # (B,nc,H,L)
    acs = jnp.cumsum(log_a_h, axis=-1)  # within-chunk cumulative

    # intra-chunk (diagonal block): Y_ij = C_i . B_j * exp(acs_i - acs_j) * dt_j x_j
    l_mat = jnp.exp(segsum(log_a_h))  # (B,nc,H,L,L)
    xdt = xc * dtc[..., None]  # (B,nc,L,H,P)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", cc, bc, l_mat, xdt)

    # per-chunk input states: sum_j exp(acs_L - acs_j) dt_j B_j x_j
    decay_states = jnp.exp(acs[..., -1:] - acs)  # (B,nc,H,L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", bc, decay_states, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(log_a_h, axis=-1))  # (B,nc,H)

    def body(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit PREVIOUS state (state entering this chunk)

    init = h0 if h0 is not None else jnp.zeros((bsz, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        body,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk contribution: C_i . (decay_in_i * prev_state)
    decay_in = jnp.exp(acs)  # (B,nc,H,L)
    y_off = jnp.einsum("bclhn,bchl,bchpn->bclhp", cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def ssd_apply(
    params: Params,
    x: jax.Array,
    *,
    d_inner: int,
    head_dim: int,
    d_state: int,
    n_groups: int = 1,
    chunk: int = 64,
    use_kernel: bool = False,
) -> jax.Array:
    """Full-sequence Mamba-2 block. x (B,S,D)."""
    dtype = x.dtype
    n_heads = d_inner // head_dim
    z, xbc, dt = _split_proj(params, x, d_inner, n_groups, d_state, n_heads)
    xbc, _ = _conv(xbc, params["conv_w"], params["conv_b"])
    xin, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n_groups * d_state], axis=-1)

    bsz, s, _ = x.shape
    xh = xin.astype(jnp.float32).reshape(bsz, s, n_heads, head_dim)
    bi = b_in.astype(jnp.float32).reshape(bsz, s, n_groups, d_state)
    ci = c_in.astype(jnp.float32).reshape(bsz, s, n_groups, d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,)

    if use_kernel:
        from repro.kernels import ops as _kops

        y, _ = _kops.ssd_chunk_scan(xh, dtv, a, bi, ci, chunk=chunk)
    else:
        y, _ = ssd_chunked_ref(xh, dtv, a, bi, ci, chunk=chunk)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype), params["norm"])
    return jnp.einsum("bsn,nd->bsd", y, params["out_proj"].astype(dtype))


# -- decode -------------------------------------------------------------------

def ssd_state_init(batch: int, d_inner: int, head_dim: int, d_state: int, n_groups: int = 1, conv_width: int = 4) -> Params:
    n_heads = d_inner // head_dim
    return {
        "h": jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner + 2 * n_groups * d_state), jnp.bfloat16),
    }


def ssd_prefill_state(
    params: Params,
    x: jax.Array,
    *,
    d_inner: int,
    head_dim: int,
    d_state: int,
    n_groups: int = 1,
    chunk: int = 64,
) -> Params:
    dtype = x.dtype
    n_heads = d_inner // head_dim
    z, xbc, dt = _split_proj(params, x, d_inner, n_groups, d_state, n_heads)
    xbc_conv, conv_state = _conv(xbc, params["conv_w"], params["conv_b"])
    xin, b_in, c_in = jnp.split(xbc_conv, [d_inner, d_inner + n_groups * d_state], axis=-1)
    bsz, s, _ = x.shape
    xh = xin.astype(jnp.float32).reshape(bsz, s, n_heads, head_dim)
    bi = b_in.astype(jnp.float32).reshape(bsz, s, n_groups, d_state)
    ci = c_in.astype(jnp.float32).reshape(bsz, s, n_groups, d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    _, h = ssd_chunked_ref(xh, dtv, a, bi, ci, chunk=chunk)
    return {"h": h, "conv": conv_state.astype(jnp.bfloat16)}


def ssd_decode(
    params: Params,
    x: jax.Array,  # (B, 1, D)
    state: Params,
    *,
    d_inner: int,
    head_dim: int,
    d_state: int,
    n_groups: int = 1,
) -> Tuple[jax.Array, Params]:
    dtype = x.dtype
    n_heads = d_inner // head_dim
    z, xbc, dt = _split_proj(params, x, d_inner, n_groups, d_state, n_heads)
    xbc, conv_state = _conv(xbc, params["conv_w"], params["conv_b"], state["conv"])
    xin, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n_groups * d_state], axis=-1)
    bsz = x.shape[0]
    xh = xin.astype(jnp.float32).reshape(bsz, n_heads, head_dim)
    bi = b_in.astype(jnp.float32).reshape(bsz, n_groups, d_state)
    ci = c_in.astype(jnp.float32).reshape(bsz, n_groups, d_state)
    rep = n_heads // n_groups
    bi = jnp.repeat(bi, rep, axis=1)  # (B,H,N)
    ci = jnp.repeat(ci, rep, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dtv * a)  # (B,H)
    h = state["h"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtv, xh, bi
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, ci) + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype), params["norm"])
    out = jnp.einsum("bsn,nd->bsd", y, params["out_proj"].astype(dtype))
    return out, {"h": h, "conv": conv_state.astype(jnp.bfloat16)}
