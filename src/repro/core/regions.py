"""Region registry — interned handles for instrumented code locations.

Score-P keeps a region-definition table and hands out integer region handles;
every runtime event carries only the handle.  This module is the Python
analogue: regions are interned on the CPython code object (or C-function
object), so the per-event cost is a single dict lookup.  Filter verdicts are
cached on the handle (filtered regions get handle ``-1``) so filtering costs
nothing per event after the first call.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

# Region kinds (mirrors Score-P's region roles).
KIND_PYTHON = "python"
KIND_C = "c"
KIND_USER = "user"

#: Handle returned for regions suppressed by the active filter.
FILTERED = -1


def _module_from_filename(filename: str) -> str:
    """Best-effort module name when no frame is available (sys.monitoring)."""
    if not filename or filename.startswith("<"):
        return filename or "?"
    stem = filename.rsplit("/", 1)[-1]
    if stem.endswith(".py"):
        stem = stem[:-3]
    return stem


def _qualname_via_gc(code) -> str:
    """Qualified name on interpreters without ``co_qualname`` (< 3.11).

    Walks the code object's referrers to the owning function and reads its
    ``__qualname__`` (so ``f.<locals>.g`` keys match across Python
    versions).  Runs only on the once-per-code-object intern miss path, so
    the gc walk is off the per-event fast path."""
    import gc

    for ref in gc.get_referrers(code):
        if getattr(ref, "__code__", None) is code:
            qualname = getattr(ref, "__qualname__", None)
            if qualname:
                return qualname
    return code.co_name


@dataclass(frozen=True)
class Region:
    """One entry of the region-definition table."""

    id: int
    name: str
    module: str
    file: str
    line: int
    kind: str

    def as_dict(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "name": self.name,
            "module": self.module,
            "file": self.file,
            "line": self.line,
            "kind": self.kind,
        }


class RegionRegistry:
    """Thread-safe interning registry for regions.

    The hot-path dicts (``by_code`` / ``by_cfunc``) are exposed directly so
    instrumenters can bind them as closure locals; only registration (the
    cold path for each distinct code object) takes the lock.
    """

    def __init__(self, decide: Optional[Callable[[str, str, str], bool]] = None):
        # decide(module, name, file) -> True if the region should be recorded.
        self._decide = decide or (lambda module, name, file: True)
        # RLock, not Lock: registration runs in user context (e.g. user-region
        # interning), and C calls made while holding the lock fire c_call
        # events whose handling re-enters registration on the same thread.
        self._lock = threading.RLock()
        # Dict keyed by id (NOT a list): registration can re-enter on the
        # same thread via instrumentation events fired by its own C calls;
        # a list's len()/append() window would corrupt the id<->slot
        # invariant.  itertools.count allocation + dict storage is immune.
        self._regions: Dict[int, Region] = {}
        self._next_id = itertools.count()
        # Hot-path lookup tables.  Keys: code objects / builtin callables.
        self.by_code: Dict[Any, int] = {}
        self.by_cfunc: Dict[Any, int] = {}
        self._user: Dict[str, int] = {}
        # Called after refilter() flips verdicts.  PEP 669 instrumenters
        # register sys.monitoring.restart_events here: their DISABLE state
        # caches the *old* verdicts on code locations, and without a re-arm a
        # tightened filter would only take effect on locations that happen to
        # fire again before being retired.
        self._refilter_hooks: List[Callable[[], None]] = []

    # -- cold paths -------------------------------------------------------

    def _intern(self, name: str, module: str, file: str, line: int, kind: str) -> int:
        if not self._decide(module, name, file):
            return FILTERED
        rid = next(self._next_id)
        self._regions[rid] = Region(rid, name, module, file, line, kind)
        return rid

    def register_code(self, code, frame) -> int:
        """Intern a Python code object (miss path of an instrumenter).

        ``frame`` may be None (``sys.monitoring`` callbacks receive only the
        code object); the module is then derived from the filename.
        """
        with self._lock:
            rid = self.by_code.get(code)
            if rid is not None:
                return rid
            if frame is not None:
                module = frame.f_globals.get("__name__", "?")
            else:
                module = _module_from_filename(code.co_filename)
            name = getattr(code, "co_qualname", None) or _qualname_via_gc(code)
            rid = self._intern(name, module, code.co_filename, code.co_firstlineno, KIND_PYTHON)
            self.by_code[code] = rid
            return rid

    def register_cfunction(self, func) -> int:
        """Intern a builtin/C function object."""
        with self._lock:
            rid = self.by_cfunc.get(func)
            if rid is not None:
                return rid
            module = getattr(func, "__module__", None) or "builtins"
            name = getattr(func, "__qualname__", None) or getattr(func, "__name__", repr(func))
            rid = self._intern(name, module, "<C>", 0, KIND_C)
            self.by_cfunc[func] = rid
            return rid

    def register_user(self, name: str, module: str = "user") -> int:
        """Intern a user region (``with repro.core.region("..."):``)."""
        with self._lock:
            key = f"{module}:{name}"
            rid = self._user.get(key)
            if rid is not None:
                return rid
            rid = self._intern(name, module, "<user>", 0, KIND_USER)
            self._user[key] = rid
            return rid

    # -- verdict invalidation (runtime filter tightening) ------------------

    def refilter(self) -> List[int]:
        """Re-evaluate cached filter verdicts against the current ``decide``.

        Instrumenters bind ``by_code`` / ``by_cfunc`` as closure locals, so
        tightening the filter after registration would otherwise never take
        effect: verdicts are cached in those dicts.  This mutates them *in
        place* (same dict objects the closures hold), flipping newly-excluded
        handles to ``FILTERED``.  One-directional by construction: handles
        that were filtered at registration never produced a Region entry, so
        there is nothing to re-admit — the governor only ever tightens.

        Returns the region ids that were invalidated.
        """
        changed: List[int] = []
        with self._lock:
            for table in (self.by_code, self.by_cfunc, self._user):
                # Iterate a snapshot: refilter runs in user context with the
                # hook still active, so C calls inside ``decide`` fire
                # c_call events whose handling re-enters registration on
                # this thread (the RLock lets it through) and inserts into
                # these very dicts.  Entries registered mid-pass already got
                # their verdict from the tightened ``decide``.
                for key, rid in list(table.items()):
                    if rid == FILTERED:
                        continue
                    r = self._regions[rid]
                    if not self._decide(r.module, r.name, r.file):
                        table[key] = FILTERED
                        changed.append(rid)
            hooks = list(self._refilter_hooks) if changed else []
        for hook in hooks:
            # Outside the lock: restart_events() re-dispatches retired
            # locations whose callbacks re-enter registration.
            hook()
        return changed

    def add_refilter_hook(self, hook: Callable[[], None]) -> None:
        """Register ``hook`` to run after :meth:`refilter` flips verdicts."""
        with self._lock:
            if hook not in self._refilter_hooks:
                self._refilter_hooks.append(hook)

    def remove_refilter_hook(self, hook: Callable[[], None]) -> None:
        with self._lock:
            try:
                self._refilter_hooks.remove(hook)
            except ValueError:
                pass

    # -- introspection ----------------------------------------------------

    def __len__(self) -> int:
        return len(self._regions)

    def get(self, rid: int) -> Region:
        return self._regions[rid]

    def snapshot(self) -> List[Dict[str, Any]]:
        """Dense region table, index == id (every allocated id is stored)."""
        with self._lock:
            return [self._regions[i].as_dict() for i in range(len(self._regions))]
