"""Measurement manager — lifecycle, user instrumentation API, buffers.

This is the Python-side equivalent of the Score-P measurement system: it owns
the region registry, the per-thread event buffers, the instrumenter, and the
substrates, and provides the user-instrumentation API (paper: Score-P user
regions):

    import repro.core as rmon
    rmon.init(instrumenter="profile", substrates=("profiling", "tracing"))
    with rmon.region("train_step"):
        ...
    rmon.metric("tokens", 4096)
    rmon.finalize()

All public entry points are safe no-ops when measurement is inactive, so
library code can be annotated unconditionally.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
import warnings
from dataclasses import dataclass, replace
from functools import wraps
from typing import Any, Dict, List, Optional, Tuple

from .buffer import BUFFER_STRATEGIES, EV_ENTER, EV_EXIT
from .filtering import Filter
from .instrumenters import make_instrumenter
from .memsys.substrate import DEFAULT_PERIOD_S, DEFAULT_TOPN
from .regions import RegionRegistry
from .schema import stamp
from .substrates import make_substrate
from .topology import ENV_PREFIX, ProcessTopology  # noqa: F401  (re-exported)


@dataclass
class MeasurementConfig:
    """Everything one measurement run is parameterized by.

    Round-trips through the process environment (``from_env``/``to_env``,
    ``REPRO_MONITOR_*`` variables) so the two-phase bootstrap and any
    forked worker see an identical configuration; see docs/CLI.md for the
    CLI flags each field maps to and docs/ARTIFACTS.md for the artifacts
    the substrate selection produces.
    """

    instrumenter: str = "profile"
    substrates: Tuple[str, ...] = ("profiling", "tracing", "metrics")
    out_dir: str = "repro-traces"
    run_dir: Optional[str] = None  # explicit run dir (tests); else derived
    filter_spec: str = ""
    flush_threshold: int = 1 << 16
    sampling_period: int = 97
    # Target recorded-pair rate (samples/s) for the "adaptive" instrumenter
    # (PEP 669 epoch sampler, 3.12+); also caps the governor's projected
    # cost for the adaptive ladder rung.
    adaptive_rate: float = 4000.0
    buffer_strategy: str = "list"
    # Memory monitoring (repro.core.memsys): poller period / top-N region
    # table size.  The substrate itself is off unless "memory" appears in
    # ``substrates`` (or REPRO_MONITOR_MEMORY=1 adds it via from_env).
    memory_period: float = DEFAULT_PERIOD_S
    memory_topn: int = DEFAULT_TOPN
    # Overhead budget as fractional dilation (0.05 = 5%); > 0 enables the
    # runtime governor (repro.core.governor), which calibrates per-event
    # cost at startup and escalates (exclude regions -> raise sampling
    # period -> downgrade instrumenter) to keep estimated overhead under
    # budget.  0 disables it.
    budget: float = 0.0
    # ``rank`` is kept as a convenience init arg; ``topology`` is the source
    # of truth (rank + world size + local rank + mesh shape) and the two are
    # synchronized in __post_init__.  ``rank=None`` (the default) means
    # "take it from topology"; an explicit integer — including 0 — wins.
    rank: Optional[int] = None
    topology: Optional[ProcessTopology] = None
    experiment: str = "run"
    chrome_export: bool = True
    keep_series: bool = True
    # Emit the unified HTML report (repro.core.report) into the run dir at
    # finalize.  Off by default: report generation re-reads every artifact
    # just written, which launch scripts may prefer to do offline via
    # ``python -m repro.core.analysis report``.
    report: bool = False
    # Path to a static_plan.json (repro.core.staticpass) produced by
    # ``analysis plan``.  When set, the plan's exclude patterns merge into
    # the filter as runtime excludes (same ``exclude!`` precedence the
    # governor uses) and its predicted offenders warm-start the governor.
    # The plan is copied into the run dir at start() for provenance.
    static_plan: str = ""
    # Live continuous-monitoring agent (repro.agent): publish flush batches
    # into a shared-memory ring; rank 0 additionally runs the sidecar
    # aggregator + HTTP endpoint (/report, /stats.json, /healthz) on
    # ``agent_port`` (0 = ephemeral).
    agent: bool = False
    agent_port: int = 0

    def __post_init__(self):
        if self.topology is None:
            # world size is unknown here; rank+1 is the smallest valid value
            r = self.rank or 0
            self.topology = ProcessTopology(rank=r, world_size=r + 1)
        if self.rank is None:
            self.rank = self.topology.rank
        elif self.topology.rank != self.rank:
            self.topology = self.topology.with_rank(self.rank)

    # -- env round-trip (used by the two-phase bootstrap) -------------------

    @classmethod
    def from_env(cls, environ=os.environ) -> "MeasurementConfig":
        def get(name, default):
            return environ.get(ENV_PREFIX + name, default)

        topology = ProcessTopology.from_env(environ)
        substrates = tuple(
            s.strip()
            for s in get("SUBSTRATES", "profiling,tracing,metrics").split(",")
            if s.strip()
        )
        # REPRO_MONITOR_MEMORY=1 is the one-knob switch for the memory
        # subsystem: it appends the substrate without the user re-listing
        # the default substrate set.
        if get("MEMORY", "0") not in ("0", "false", "") and "memory" not in substrates:
            substrates = substrates + ("memory",)
        return cls(
            instrumenter=get("INSTRUMENTER", cls.instrumenter),
            substrates=substrates,
            out_dir=get("OUT", cls.out_dir),
            run_dir=environ.get(ENV_PREFIX + "RUN_DIR") or None,
            filter_spec=get("FILTER", cls.filter_spec),
            flush_threshold=int(get("FLUSH", cls.flush_threshold)),
            sampling_period=int(get("SAMPLING_PERIOD", cls.sampling_period)),
            adaptive_rate=float(get("ADAPTIVE_RATE", cls.adaptive_rate)),
            buffer_strategy=get("BUFFER", cls.buffer_strategy),
            memory_period=float(get("MEMORY_PERIOD", cls.memory_period)),
            memory_topn=int(get("MEMORY_TOPN", cls.memory_topn)),
            budget=float(get("BUDGET", cls.budget)),
            rank=topology.rank,
            topology=topology,
            experiment=get("EXPERIMENT", cls.experiment),
            chrome_export=get("CHROME", "1") not in ("0", "false", ""),
            keep_series=get("SERIES", "1") not in ("0", "false", ""),
            report=get("REPORT", "0") not in ("0", "false", ""),
            static_plan=get("STATIC_PLAN", cls.static_plan),
            agent=get("AGENT", "0") not in ("0", "false", ""),
            agent_port=int(get("AGENT_PORT", cls.agent_port)),
        )

    def to_env(self) -> Dict[str, str]:
        env = {
            ENV_PREFIX + "INSTRUMENTER": self.instrumenter,
            ENV_PREFIX + "SUBSTRATES": ",".join(self.substrates),
            ENV_PREFIX + "OUT": self.out_dir,
            ENV_PREFIX + "FILTER": self.filter_spec,
            ENV_PREFIX + "FLUSH": str(self.flush_threshold),
            ENV_PREFIX + "SAMPLING_PERIOD": str(self.sampling_period),
            ENV_PREFIX + "ADAPTIVE_RATE": str(self.adaptive_rate),
            ENV_PREFIX + "BUFFER": self.buffer_strategy,
            ENV_PREFIX + "MEMORY": "1" if "memory" in self.substrates else "0",
            ENV_PREFIX + "MEMORY_PERIOD": str(self.memory_period),
            ENV_PREFIX + "MEMORY_TOPN": str(self.memory_topn),
            ENV_PREFIX + "BUDGET": str(self.budget),
            ENV_PREFIX + "EXPERIMENT": self.experiment,
            ENV_PREFIX + "CHROME": "1" if self.chrome_export else "0",
            ENV_PREFIX + "SERIES": "1" if self.keep_series else "0",
            ENV_PREFIX + "REPORT": "1" if self.report else "0",
            ENV_PREFIX + "AGENT": "1" if self.agent else "0",
            ENV_PREFIX + "AGENT_PORT": str(self.agent_port),
        }
        env.update(self.topology.to_env())  # RANK / WORLD_SIZE / LOCAL_RANK / MESH
        if self.run_dir:
            env[ENV_PREFIX + "RUN_DIR"] = self.run_dir
        if self.static_plan:
            env[ENV_PREFIX + "STATIC_PLAN"] = self.static_plan
        return env


class Measurement:
    """One measurement run: regions + buffers + instrumenter + substrates.

    Owns the full lifecycle (``start`` → event recording → ``finalize``)
    and the artifact contract of a run directory.  After ``finalize()``
    the run dir contains, per enabled substrate (see docs/ARTIFACTS.md
    for the field tables; every JSON carries ``report_schema_version``):

    ======================  =====================================================
    artifact                writer / contents
    ======================  =====================================================
    meta.json               always — topology, epochs, event counts
    profile.json (+ .txt)   "profiling" — call tree + flat per-region table
    defs.json + streams     "tracing" — raw event streams + region definitions
    trace.json              "tracing" — Chrome/Perfetto trace (unless disabled)
    metrics.json            "metrics" — metric aggregates + time series
    memory.json             "memory" — per-region allocation attribution,
                            RSS/heap/GC/fd timelines
    governor.json           budget > 0 — calibration, actions, suggested filter
    report.html             ``config.report`` — self-contained HTML report
                            fusing all of the above (repro.core.report)
    ======================  =====================================================

    Thread-safe event intake: each thread appends to its own buffer; flushes
    fan batches out to the substrates under one lock.
    """

    def __init__(self, config: MeasurementConfig):
        self.config = config
        self.filter = Filter.from_spec(config.filter_spec)
        self.regions = RegionRegistry(decide=self.filter.decide)
        self._local = threading.local()
        self._buffers: List[Any] = []
        self._buffer_tids: set = set()
        self._buffers_lock = threading.RLock()
        self._flush_lock = threading.RLock()
        self._substrates = []
        for name in config.substrates:
            if name == "tracing":
                self._substrates.append(make_substrate(name, chrome_export=config.chrome_export))
            elif name == "metrics":
                self._substrates.append(make_substrate(name, keep_series=config.keep_series))
            elif name == "memory":
                self._substrates.append(
                    make_substrate(name, period=config.memory_period, topn=config.memory_topn)
                )
            else:
                self._substrates.append(make_substrate(name))
        if config.instrumenter == "sampling":
            self.instrumenter = make_instrumenter("sampling", period=config.sampling_period)
        elif config.instrumenter == "adaptive":
            self.instrumenter = make_instrumenter("adaptive", target_rate=config.adaptive_rate)
        else:
            self.instrumenter = make_instrumenter(config.instrumenter)
        if config.budget > 0:
            from .governor import Governor  # late import: governor imports core modules

            self.governor: Optional[Governor] = Governor(self, config.budget)
        else:
            self.governor = None
        #: The loaded static plan dict (repro.core.staticpass), or None.
        #: Set by apply_plan — either here via config.static_plan or later
        #: by a caller holding an already-loaded plan.
        self.static_plan: Optional[Dict[str, Any]] = None
        if config.static_plan:
            from .staticpass import apply_plan, load_plan

            # Before the instrumenter installs: plan excludes must be in the
            # filter before any region verdict is cached.  A bad plan path
            # raises MissingArtifact here, at construction, not mid-run.
            apply_plan(self, load_plan(config.static_plan))
        #: Live-monitoring runtime (repro.agent.runtime.AgentRuntime), or
        #: None.  Created in start() when config.agent is set, or later via
        #: attach_agent(); the flush path fans out to it like a substrate.
        self.agent = None
        self._buffer_cls = BUFFER_STRATEGIES[config.buffer_strategy]
        self.run_dir = config.run_dir or os.path.join(
            config.out_dir,
            f"{config.experiment}-{time.strftime('%Y%m%d-%H%M%S')}"
            f"-p{os.getpid()}-{config.topology.tag()}",
        )
        self.started = False
        self.finalized = False
        self.epoch_time_ns = 0
        self.epoch_perf_ns = 0

    # -- buffers -------------------------------------------------------------

    def thread_buffer(self):
        buf = getattr(self._local, "buf", None)
        if buf is None:
            tid = threading.get_ident()
            with self._buffers_lock:
                # CPython reuses thread idents once a thread exits; each
                # buffer must keep its own event stream (one OTF2 location
                # per thread lifetime), so de-collide reused idents.
                while tid in self._buffer_tids:
                    tid += 1
                self._buffer_tids.add(tid)
                buf = self._buffer_cls(
                    thread_id=tid,
                    flush_threshold=self.config.flush_threshold,
                    on_flush=self._on_flush,
                )
                self._local.buf = buf
                self._buffers.append(buf)
        return buf

    def _on_flush(self, thread_id: int, columns) -> None:
        with self._flush_lock:
            for sub in self._substrates:
                sub.on_flush(thread_id, columns)
            if self.agent is not None:
                # Before the governor: the governor's very next on_flush
                # pulls this publish's cost (take_publish_cost_ns) into the
                # window it is about to score.
                self.agent.on_flush(thread_id, columns)
            if self.governor is not None:
                # After the substrates: the governor may mutate the filter,
                # the sampling period, or the instrumenter itself, and the
                # batch at hand should be interpreted under the settings it
                # was recorded with.
                self.governor.on_flush(thread_id, columns)

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> None:
        if self.started:
            return
        os.makedirs(self.run_dir, exist_ok=True)
        self.epoch_time_ns = time.time_ns()
        self.epoch_perf_ns = time.perf_counter_ns()
        meta = {
            "rank": self.config.rank,
            "topology": self.config.topology.as_dict(),
            "pid": os.getpid(),
            "experiment": self.config.experiment,
            "instrumenter": self.config.instrumenter,
            "substrates": list(self.config.substrates),
            "epoch_time_ns": self.epoch_time_ns,
            "epoch_perf_ns": self.epoch_perf_ns,
        }
        for sub in self._substrates:
            sub.open(self.run_dir, meta)
        if self.static_plan is not None:
            # Provenance copy: the run dir records exactly which plan shaped
            # this run's filter, next to the artifacts it shaped.
            from .staticpass import ARTIFACT as _PLAN_ARTIFACT

            with open(os.path.join(self.run_dir, _PLAN_ARTIFACT), "w") as fh:
                json.dump(self.static_plan, fh, indent=1)
        self.started = True
        if self.config.agent:
            self.attach_agent()
        if self.governor is not None:
            # Calibrate before the instrumenter installs: the probe runs
            # throwaway instrumenter instances on a stub host and must not
            # race the real hook.
            self.governor.calibrate_startup()
        self.instrumenter.install(self)
        if self.governor is not None:
            self.governor.open()

    def attach_agent(self, port: Optional[int] = None):
        """Turn on the live-monitoring agent for a started measurement.

        Idempotent: returns the existing runtime if one is live.  Normally
        invoked from :meth:`start` via ``config.agent``; callers that decide
        late (e.g. ``launch serve --agent`` joining an active measurement)
        use this directly."""
        if not self.started or self.finalized:
            raise RuntimeError("attach_agent requires a started measurement")
        if self.agent is not None:
            return self.agent
        if port is not None:
            self.config.agent_port = int(port)
        self.config.agent = True
        from repro.agent.runtime import AgentRuntime  # late: agent imports core

        self.agent = AgentRuntime(self)
        return self.agent

    def stop(self) -> None:
        """Uninstall the instrumenter but keep the run open (re-startable)."""
        if self.started:
            if self.governor is not None:
                # Freeze BEFORE uninstalling: a watchdog tick racing this
                # could otherwise escalate and re-install hooks the user is
                # in the middle of removing.
                self.governor.frozen = True
                self.governor.stop_watchdog()
            self.instrumenter.uninstall()

    def _best_effort(self, label: str, fn, advice: str = "") -> bool:
        """Run one finalize hook in isolation.

        Finalize is a sequence of independent artifact writers; one failing
        hook (a substrate close, the chrome export, the agent shutdown, the
        report) must neither skip the hooks after it nor corrupt the run dir
        — whatever already hit disk stays, whatever comes next still runs.
        Each failure surfaces as a RuntimeWarning naming the hook."""
        try:
            fn()
            return True
        except Exception as exc:
            suffix = f" ({advice})" if advice else ""
            warnings.warn(
                f"{label} failed for {self.run_dir}: {exc!r}{suffix}",
                RuntimeWarning,
            )
            return False

    def finalize(self) -> Optional[str]:
        if not self.started or self.finalized:
            return None
        if self.governor is not None:
            # Freeze BEFORE uninstalling (a racing watchdog tick could
            # swap in fresh hooks on a finalizing measurement) and before
            # draining (the drain flushes partial buffers, which must be
            # accounted without escalating a shutdown).
            self.governor.frozen = True
            self.governor.stop_watchdog()
        self.instrumenter.uninstall()
        with self._buffers_lock:
            buffers = list(self._buffers)
        for buf in buffers:
            self._best_effort(f"buffer flush (thread {buf.thread_id})", buf.flush)
        region_table = self.regions.snapshot()
        for sub in self._substrates:
            self._best_effort(
                f"substrate close ({sub.name})",
                lambda s=sub: s.close(region_table),
            )
        if self.governor is not None:
            self._best_effort(
                "governor report", lambda: self.governor.close(self.run_dir)
            )
        for sub in self._substrates:
            # Chrome export runs after *all* substrates closed so the trace
            # can embed metric series (metrics.json) as counter tracks.  An
            # export failure must not abort finalize: the raw artifacts are
            # already on disk and re-exportable offline via to_chrome().
            export_chrome = getattr(sub, "export_chrome", None)
            if export_chrome is not None:
                self._best_effort(
                    f"chrome trace export ({sub.name})",
                    export_chrome,
                    advice="raw streams kept; re-run repro.core.export.export_run",
                )
        if self.agent is not None:
            # After the exports (the last flush above still published), and
            # before meta.json: the ring's writer_closed flag and the final
            # definitions sidecar are part of the run dir contract.
            self._best_effort("agent shutdown", self.agent.close)
        meta = stamp({
            "rank": self.config.rank,
            "topology": self.config.topology.as_dict(),
            "pid": os.getpid(),
            "experiment": self.config.experiment,
            "instrumenter": self.config.instrumenter,
            "buffer_strategy": self.config.buffer_strategy,
            "epoch_time_ns": self.epoch_time_ns,
            "epoch_perf_ns": self.epoch_perf_ns,
            "finalize_time_ns": time.time_ns(),
            "n_regions": len(region_table),
            "events_flushed": sum(getattr(b, "n_flushed", 0) for b in buffers),
        })
        with open(os.path.join(self.run_dir, "meta.json"), "w") as fh:
            json.dump(meta, fh, indent=1)
        self.finalized = True
        if self.config.report:
            # Last: the report generator re-reads every artifact finalized
            # above.  Best-effort for the same reason as the chrome export —
            # raw artifacts are on disk and the report is re-generatable.
            def _report():
                from .report import write_report

                write_report(self.run_dir)

            self._best_effort(
                "report generation",
                _report,
                advice="re-run `python -m repro.core.analysis report`",
            )
        return self.run_dir

    def swap_instrumenter(self, name: str, **kwargs) -> None:
        """Replace the live instrumenter (governor downgrade path).

        Uninstalls the current hook and installs the new one on the calling
        thread (plus threads started afterwards).  Threads that already had
        the old hook lose instrumentation — their stale callbacks self-remove
        via the generation flag; re-hooking a foreign thread's profile slot
        is not possible from here.
        """
        self.instrumenter.uninstall()
        if name == "sampling" and "period" not in kwargs:
            kwargs["period"] = self.config.sampling_period
        elif name == "adaptive" and "target_rate" not in kwargs:
            kwargs["target_rate"] = self.config.adaptive_rate
        self.instrumenter = make_instrumenter(name, **kwargs)
        self.config.instrumenter = name
        if self.started and not self.finalized:
            self.instrumenter.install(self)

    # -- user instrumentation API ---------------------------------------------

    def region(self, name: str, module: str = "user"):
        rid = self.regions.register_user(name, module)
        return _RegionContext(self, rid)

    def metric(self, name: str, value: float) -> None:
        t = time.perf_counter_ns()
        for sub in self._substrates:
            sub.on_metric(name, float(value), t)
        if self.agent is not None:
            self.agent.on_metric(name, float(value), t)

    def substrate(self, name: str):
        for sub in self._substrates:
            if sub.name == name:
                return sub
        return None


class _RegionContext:
    """Reusable enter/exit context for one user region (cheap hot path)."""

    __slots__ = ("_m", "_rid")

    def __init__(self, measurement: Measurement, rid: int):
        self._m = measurement
        self._rid = rid

    def __enter__(self):
        if self._rid >= 0:
            buf = self._m.thread_buffer()
            buf.events.append((EV_ENTER, self._rid, time.perf_counter_ns(), 0))
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._rid >= 0:
            buf = self._m.thread_buffer()
            buf.events.append((EV_EXIT, self._rid, time.perf_counter_ns(), 0))
            if len(buf.events) >= buf.flush_threshold:
                buf.flush()
        return False


class _NullContext:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CONTEXT = _NullContext()

# ----------------------------------------------------------------------------
# Module-level singleton API
# ----------------------------------------------------------------------------

_active: Optional[Measurement] = None
_atexit_registered = False


def init(config: Optional[MeasurementConfig] = None, **overrides) -> Measurement:
    """Initialize and start measurement (idempotent-per-process)."""
    global _active, _atexit_registered
    if _active is not None and not _active.finalized:
        raise RuntimeError("measurement already active; call finalize() first")
    config = replace(config, **overrides) if config else MeasurementConfig(**overrides)
    _active = Measurement(config)
    _active.start()
    if not _atexit_registered:
        atexit.register(finalize)
        _atexit_registered = True
    return _active


def init_from_env() -> Optional[Measurement]:
    """Start measurement if the bootstrap environment is present."""
    if os.environ.get(ENV_PREFIX + "ENABLE") != "1":
        return None
    return init(MeasurementConfig.from_env())


def active() -> Optional[Measurement]:
    """The live :class:`Measurement`, or ``None`` when none is running
    (not initialized, not started, or already finalized).  Library code
    uses this to make instrumentation unconditional-but-free."""
    return _active if (_active is not None and _active.started and not _active.finalized) else None


def region(name: str, module: str = "user"):
    """User-region context manager (paper: ``scorep.user.region``).

    ``with rmon.region("train_step"): ...`` records an enter/exit event
    pair attributed to ``module:name``.  A safe no-op (shared null context)
    when measurement is inactive, so annotations can stay in library code
    permanently.  User regions are never auto-excluded by filters or the
    overhead governor."""
    m = active()
    if m is None:
        return _NULL_CONTEXT
    return m.region(name, module)


def metric(name: str, value: float) -> None:
    """Record one sample of a named metric (paper: Score-P metric plugin
    / user counter).  Lands in metrics.json (aggregates + optional time
    series) and as a Perfetto counter track in trace.json.  No-op when
    measurement is inactive; non-finite values are tolerated (counted,
    serialized as ``null``)."""
    m = active()
    if m is not None:
        m.metric(name, value)


def current_topology() -> ProcessTopology:
    """This process's topology: the active measurement's when one is live,
    otherwise detected from the launcher environment.  Dist modules use this
    to annotate events without reaching into globals."""
    m = _active
    if m is not None:
        return m.config.topology
    return ProcessTopology.from_env()


def instrument(fn=None, *, name: Optional[str] = None, module: str = "user"):
    """Decorator form of :func:`region` (resolves the region per call so the
    decorated function works whether or not measurement is active)."""

    def deco(f):
        region_name = name or getattr(f, "__qualname__", f.__name__)

        @wraps(f)
        def wrapper(*args, **kwargs):
            with region(region_name, module):
                return f(*args, **kwargs)

        return wrapper

    return deco(fn) if fn is not None else deco


def finalize() -> Optional[str]:
    """Finalize the active measurement: uninstall hooks, drain buffers,
    close every substrate (writing their artifacts — see docs/ARTIFACTS.md),
    export the Chrome trace, and return the run directory path (``None``
    when no measurement was active).  Registered via ``atexit`` by
    :func:`init`, so an unexceptional interpreter exit always produces
    complete artifacts."""
    global _active
    m = _active
    if m is None:
        return None
    path = m.finalize()
    _active = None
    return path
