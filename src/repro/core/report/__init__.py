"""repro.core.report — unified HTML performance report.

The human-facing end of the toolchain: one self-contained ``report.html``
that fuses every artifact a run (or merged multi-rank run root) produced —
per-region time joined with memory attribution, RSS/heap/GC and metric
timelines as inline SVG sparklines, the overhead governor's action timeline
and suggested filter, the cross-rank imbalance heatmap, and an optional
run-vs-run diff.  Zero dependencies, no network/CDN references; the full
data model is embedded as a JSON payload inside the page.

Entry points::

    from repro.core.report import build_report, render_report, write_report
    write_report(run_dir)                      # -> <run_dir>/report.html
    write_report(run_dir, diff_base=base_dir)  # adds the regression section

    python -m repro.core.analysis report RUN_DIR [--diff BASE] [--open]
    python -m repro.scorep --report app.py     # emit at finalize
"""

from ..schema import REPORT_SCHEMA_VERSION  # noqa: F401
from .html import PAYLOAD_ID, extract_payload, render_report  # noqa: F401
from .model import build_report  # noqa: F401

import os
from typing import Optional

__all__ = [
    "REPORT_SCHEMA_VERSION",
    "PAYLOAD_ID",
    "build_report",
    "extract_payload",
    "render_report",
    "write_report",
]


def write_report(
    run_dir: str,
    out_path: Optional[str] = None,
    diff_base: Optional[str] = None,
) -> str:
    """Build and write the HTML report for ``run_dir``.

    ``out_path`` defaults to ``<run_dir>/report.html``.  Returns the path
    written.  Raises :class:`repro.core.analysis.MissingArtifact` when the
    directory holds no known artifact.
    """
    doc = build_report(run_dir, diff_base=diff_base)
    out_path = out_path or os.path.join(run_dir, "report.html")
    page = render_report(doc)
    with open(out_path, "w", encoding="utf-8") as fh:
        fh.write(page)
    return out_path
