"""Report data model — one versioned document joining every run artifact.

:func:`build_report` reads whatever artifacts a run directory contains
(profile.json, memory.json, metrics.json, governor.json, meta.json,
merged_trace_summary.json) and produces a single JSON-serializable dict —
the payload embedded verbatim in report.html for client-side sorting, and
the contract tests round-trip against.  Every section is optional: a
profile-only run reports time, a merge root reports the cross-rank view,
and missing substrates simply leave their section ``None``.

Layout (``report_schema_version`` stamped at the top level)::

    run_dir, generated_time_ns, meta
    regions     [{region, kind, visits, incl_ns, excl_ns, mean_ns,
                  alloc_bytes, net_bytes, alloc_blocks,   # None w/o memsys
                  governor_excluded, est_cost_ns}]        # None w/o governor
    memory      scalar overview (memsys.overview) or None
    metrics     {name: aggregate row} or None
    timelines   {name: [[t_ns, value], ...]} — mem.* + metrics series,
                decimated to <= MAX_TIMELINE_POINTS points each
    governor    {overview..., "actions": [...]} or None
    merge       merged_trace_summary.json content or None
    diff        {"base", "profile": rows, "memory": rows or None} or None
    fleet       fleet_summary.json content or None (run-population verdicts)
"""

from __future__ import annotations

import json
import os
import time
import warnings
from typing import Any, Dict, List, Optional

from .. import governor as governor_mod
from .. import memsys
from ..schema import REPORT_SCHEMA_VERSION, MissingArtifact, schema_version, stamp

#: Per-series cap on embedded timeline points; longer series are strided
#: down.  Keeps report.html small for long runs without losing the shape.
MAX_TIMELINE_POINTS = 240

MERGE_SUMMARY = "merged_trace_summary.json"


def _load_json(run_dir: str, name: str) -> Optional[Dict[str, Any]]:
    path = os.path.join(run_dir, name)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


def decimate(series: List[List[float]], max_points: int = MAX_TIMELINE_POINTS):
    """Stride a ``[[t, v], ...]`` series down to at most ``max_points``,
    always keeping the final point (the end state matters most)."""
    n = len(series)
    if n <= max_points:
        return series
    step = -(-n // max_points)  # ceil division
    out = series[::step]
    if out[-1] is not series[-1]:
        # Keep the final point without ever exceeding the cap (striding
        # can already yield exactly max_points rows).
        if len(out) >= max_points:
            out[-1] = series[-1]
        else:
            out.append(series[-1])
    return out


def region_rows(
    profile: Optional[Dict[str, Any]],
    memory: Optional[Dict[str, Any]],
    governor: Optional[Dict[str, Any]],
) -> List[Dict[str, Any]]:
    """The joined per-region table: profile time columns, memsys allocation
    columns, governor exclusion flags — one row per region name."""
    rows: Dict[str, Dict[str, Any]] = {}

    def row(name: str) -> Dict[str, Any]:
        r = rows.get(name)
        if r is None:
            r = rows[name] = {
                "region": name,
                "kind": None,
                "visits": 0,
                "incl_ns": 0,
                "excl_ns": 0,
                "mean_ns": None,
                "alloc_bytes": None,
                "net_bytes": None,
                "alloc_blocks": None,
                "governor_excluded": None,
                "est_cost_ns": None,
            }
        return r

    for name, vals in (profile or {}).get("flat", {}).items():
        r = row(name)
        r["kind"] = vals.get("kind")
        r["visits"] = int(vals.get("visits", 0))
        r["incl_ns"] = int(vals.get("incl_ns", 0))
        r["excl_ns"] = int(vals.get("excl_ns", 0))
        if r["visits"]:
            r["mean_ns"] = round(r["excl_ns"] / r["visits"], 1)
    if memory is not None:
        for m in memsys.region_rows(memory):
            r = row(m["region"])
            r["alloc_bytes"] = m["alloc_bytes"]
            r["net_bytes"] = m["net_bytes"]
            r["alloc_blocks"] = m["alloc_blocks"]
    if governor is not None:
        for g in governor_mod.region_rows(governor):
            r = rows.get(g["region"])
            # Governor rows for regions the profile never saw (excluded
            # before their first flush) still matter — they explain where
            # the time table's gaps come from.
            if r is None:
                r = row(g["region"])
                r["kind"] = g["kind"]
                r["visits"] = g["visits"]
            r["governor_excluded"] = g["excluded"]
            r["est_cost_ns"] = g["est_cost_ns"]
    out = list(rows.values())
    out.sort(key=lambda r: -r["excl_ns"])
    return out


def _timelines(
    memory: Optional[Dict[str, Any]], metrics: Optional[Dict[str, Any]]
) -> Dict[str, List[List[float]]]:
    series: Dict[str, List[List[float]]] = {}
    if memory is not None:
        series.update(memsys.timelines(memory))
    if metrics is not None:
        for name, vals in (metrics.get("series") or {}).items():
            series.setdefault(name, vals)
    out: Dict[str, List[List[float]]] = {}
    for name, vals in series.items():
        # Drop null samples (serialized non-finite values) *before* the
        # emptiness check: an all-NaN series must not claim a sparkline
        # slot or a payload entry.
        pts = [[t, v] for t, v in vals if v is not None]
        if pts:
            out[name] = decimate(pts)
    return out


def _diff_section(run_dir: str, base_dir: str) -> Dict[str, Any]:
    # Imported here: analysis imports the report package for its subcommand.
    from ..analysis import diff_memory, diff_profiles

    # Both halves are optional (a metrics+memory-only run has no
    # profile.json); a side missing in either run leaves its half None
    # rather than failing the whole report.
    section: Dict[str, Any] = {"base": base_dir}
    try:
        section["profile"] = diff_profiles(base_dir, run_dir)
    except MissingArtifact:
        section["profile"] = None
    try:
        section["memory"] = diff_memory(base_dir, run_dir)
    except MissingArtifact:
        section["memory"] = None
    return section


def build_report(run_dir: str, diff_base: Optional[str] = None) -> Dict[str, Any]:
    """Assemble the report data model for ``run_dir``.

    ``run_dir`` may be a single run directory or a merge root containing
    ``merged_trace_summary.json`` (both at once also works: a rank dir that
    was itself the merge output root).  ``diff_base`` adds the run-vs-run
    regression section (this run is B, the base is A).  Raises
    :class:`repro.core.analysis.MissingArtifact` when the directory contains
    *no* known artifact at all.
    """
    profile = _load_json(run_dir, "profile.json")
    memory = _load_json(run_dir, "memory.json")
    metrics = _load_json(run_dir, "metrics.json")
    governor = _load_json(run_dir, "governor.json")
    meta = _load_json(run_dir, "meta.json")
    merge = _load_json(run_dir, MERGE_SUMMARY)
    fleet = _load_json(run_dir, "fleet_summary.json")
    if all(doc is None for doc in (profile, memory, metrics, governor, merge, fleet)):
        raise MissingArtifact(
            f"no artifacts in {run_dir or '.'} — expected at least one of "
            f"profile.json / memory.json / metrics.json / governor.json / "
            f"{MERGE_SUMMARY} / fleet_summary.json (is this a run dir, merge "
            f"root or fleet root?)"
        )
    if meta is None:
        meta = (profile or memory or metrics or {}).get("meta") or {}
    # Versioning policy: newer-than-us documents are reported, not guessed
    # at (the sections still render best-effort — fields we know may have
    # moved, which the warning makes diagnosable).
    newest = max(
        (schema_version(doc)
         for doc in (profile, memory, metrics, governor, meta, merge, fleet)
         if doc is not None),
        default=0,
    )
    if newest > REPORT_SCHEMA_VERSION:
        warnings.warn(
            f"artifacts in {run_dir} were written at report_schema_version "
            f"{newest}, newer than this reader ({REPORT_SCHEMA_VERSION}) — "
            "upgrade the tools; rendering best-effort",
            RuntimeWarning,
            stacklevel=2,
        )

    doc: Dict[str, Any] = stamp(
        {
            "run_dir": run_dir,
            "generated_time_ns": time.time_ns(),
            "meta": meta,
            "regions": region_rows(profile, memory, governor),
            "memory": memsys.overview(memory) if memory is not None else None,
            "metrics": (metrics or {}).get("metrics") or None,
            "timelines": _timelines(memory, metrics),
            "governor": (
                dict(
                    governor_mod.estimate_overview(governor),
                    actions=governor_mod.action_rows(governor),
                )
                if governor is not None
                else None
            ),
            "merge": merge,
            "plan": _plan_section(run_dir, governor),
            "diff": _diff_section(run_dir, diff_base) if diff_base else None,
            "fleet": fleet,
        }
    )
    return doc


def _plan_section(
    run_dir: str, governor: Optional[Dict[str, Any]]
) -> Optional[Dict[str, Any]]:
    """Plan-vs-observed: what the static plan predicted against what the
    governor actually did.  ``None`` for runs without a static_plan.json
    (the measurement copies the applied plan into the run dir at start)."""
    from ..staticpass import ARTIFACT as PLAN_ARTIFACT
    from ..staticpass import plan_vs_observed

    plan = _load_json(run_dir, PLAN_ARTIFACT)
    if plan is None:
        return None
    conc = plan.get("concurrency") or None
    return {
        "files": plan.get("files", 0),
        "functions": plan.get("functions", 0),
        "verdicts": plan.get("verdicts", {}),
        "patterns": len(plan.get("filter", {}).get("patterns", [])),
        "vs_observed": plan_vs_observed(plan, governor),
        # Concurrency summary (SP4xx rule counts + wait-point census) rides
        # along when the plan carries one — counts only, the full witness
        # paths live in concurrency_plan.json.
        "concurrency": (
            {
                "entrypoints": conc.get("entrypoints", 0),
                "locks": conc.get("locks", 0),
                "wait_points": len(conc.get("wait_points", [])),
                "findings": dict(conc.get("findings", {})),
            }
            if conc
            else None
        ),
    }
