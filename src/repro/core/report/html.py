"""HTML templating for the unified performance report — zero dependencies.

:func:`render_report` turns the data model from :mod:`.model` into one
self-contained page: no CDN, no external script/style/font, every chart is
inline SVG or CSS-painted table cells.  The full data model is embedded in
a ``<script type="application/json" id="repro-report-data">`` block — the
machine-readable contract (tests round-trip it, tools can scrape it) and
the source the in-page sorter reads.

Visual system (kept deliberately small): one accent hue for single-series
sparklines, a single-hue sequential ramp for the cross-rank heatmap, a
blue/red diverging pair for diff deltas, and ink tokens for all text.
Light and dark surfaces are both defined; the page follows
``prefers-color-scheme``.
"""

from __future__ import annotations

import html as html_mod
import json
from typing import Any, Dict, List, Optional

from ..schema import SCHEMA_KEY

PAYLOAD_ID = "repro-report-data"

#: Regions rendered into the table; the embedded payload always carries all
#: of them (the truncation note points there).
MAX_TABLE_ROWS = 200
#: Sparkline sections rendered; additional series stay in the payload.
MAX_TIMELINES = 12

# Sequential blue ramp (reference palette steps 100..650).  On the light
# surface low values recede toward white; the dark-mode classes below use
# the same steps with luminance order reversed so low values recede toward
# the dark surface instead.
_HEAT_LIGHT = ["#cde2fb", "#b7d3f6", "#9ec5f4", "#6da7ec",
               "#3987e5", "#256abf", "#184f95", "#104281"]
_HEAT_DARK = list(reversed(_HEAT_LIGHT))
_N_HEAT = len(_HEAT_LIGHT)

_CSS = """
:root {
  color-scheme: light;
  --surface: #fcfcfb; --surface-2: #f0efec; --border: #dddbd4;
  --ink: #0b0b0b; --ink-2: #52514e; --ink-3: #878680;
  --series-1: #2a78d6; --series-fill: rgba(42, 120, 214, 0.12);
  --pos: #e34948; --neg: #2a78d6;  /* diverging: red = slower, blue = faster */
  --ok: #008300; --bad: #e34948;
""" + "".join(
    f"  --heat-{i}: {c};\n" for i, c in enumerate(_HEAT_LIGHT)
) + "".join(
    f"  --heat-ink-{i}: {'#0b0b0b' if i < 4 else '#ffffff'};\n"
    for i in range(_N_HEAT)
) + """
}
@media (prefers-color-scheme: dark) {
  :root {
    color-scheme: dark;
    --surface: #1a1a19; --surface-2: #262625; --border: #3a3935;
    --ink: #ffffff; --ink-2: #c3c2b7; --ink-3: #8a897f;
    --series-1: #3987e5; --series-fill: rgba(57, 135, 229, 0.18);
    --pos: #e66767; --neg: #3987e5;
    --ok: #4dbd4d; --bad: #e66767;
""" + "".join(
    f"    --heat-{i}: {c};\n" for i, c in enumerate(_HEAT_DARK)
) + "".join(
    f"    --heat-ink-{i}: {'#ffffff' if i < 4 else '#0b0b0b'};\n"
    for i in range(_N_HEAT)
) + """
  }
}
* { box-sizing: border-box; }
body {
  margin: 0 auto; padding: 24px 28px 64px; max-width: 1080px;
  background: var(--surface); color: var(--ink);
  font: 14px/1.5 system-ui, -apple-system, "Segoe UI", sans-serif;
}
h1 { font-size: 22px; margin: 0 0 4px; }
h2 { font-size: 16px; margin: 36px 0 8px; }
code, .mono { font-family: ui-monospace, SFMono-Regular, Menlo, monospace; font-size: 12.5px; }
.sub { color: var(--ink-2); margin: 0 0 2px; }
.note { color: var(--ink-3); font-size: 12.5px; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; margin: 18px 0 6px; }
.tile {
  background: var(--surface-2); border: 1px solid var(--border);
  border-radius: 8px; padding: 10px 14px; min-width: 120px;
}
.tile .v { font-size: 20px; font-weight: 600; }
.tile .k { color: var(--ink-2); font-size: 12px; }
.tile .v.ok { color: var(--ok); } .tile .v.bad { color: var(--bad); }
table { border-collapse: collapse; width: 100%; margin: 8px 0; }
th, td { padding: 4px 10px 4px 0; text-align: right; white-space: nowrap; }
th { color: var(--ink-2); font-weight: 600; border-bottom: 1px solid var(--border); }
td { border-bottom: 1px solid var(--surface-2); }
th.l, td.l { text-align: left; }
td.l { max-width: 420px; overflow: hidden; text-overflow: ellipsis; }
table.sortable th { cursor: pointer; user-select: none; }
table.sortable th:hover { color: var(--ink); }
th .dir { color: var(--ink-3); font-size: 10px; }
.spark-line { fill: none; stroke: var(--series-1); stroke-width: 2; stroke-linejoin: round; }
.spark-area { fill: var(--series-fill); }
.spark-hit { fill: transparent; }
.spark-hit:hover { fill: var(--series-1); fill-opacity: 0.5; }
.sparkrow { display: flex; align-items: center; gap: 16px; margin: 10px 0; }
.sparkrow .name { width: 180px; text-align: right; color: var(--ink-2); }
.sparkrow .range { color: var(--ink-3); font-size: 12px; }
.heat td.cell { text-align: right; padding: 4px 8px; border-bottom: 2px solid var(--surface); }
""" + "".join(
    f".hc{i} {{ background: var(--heat-{i}); color: var(--heat-ink-{i}); }}\n"
    for i in range(_N_HEAT)
) + """
.bar { display: inline-block; height: 10px; border-radius: 2px; vertical-align: middle; }
.bar.pos { background: var(--pos); }
.bar.neg { background: var(--neg); }
pre.spec {
  background: var(--surface-2); border: 1px solid var(--border); border-radius: 6px;
  padding: 10px 12px; overflow-x: auto; white-space: pre-wrap; word-break: break-all;
}
"""

_JS = """
var REPRO_REPORT = JSON.parse(document.getElementById("%s").textContent);
document.querySelectorAll("table.sortable").forEach(function (table) {
  var ths = table.querySelectorAll("th");
  ths.forEach(function (th, col) {
    th.addEventListener("click", function () {
      var tbody = table.tBodies[0];
      var rows = Array.prototype.slice.call(tbody.rows);
      var dir = th.dataset.dir === "desc" ? "asc" : "desc";
      ths.forEach(function (o) { delete o.dataset.dir;
        var d = o.querySelector(".dir"); if (d) d.textContent = ""; });
      th.dataset.dir = dir;
      var mark = th.querySelector(".dir");
      if (mark) mark.textContent = dir === "desc" ? "\\u25BE" : "\\u25B4";
      rows.sort(function (a, b) {
        var x = a.cells[col].dataset.v, y = b.cells[col].dataset.v, r;
        if (x !== undefined && y !== undefined) r = Number(x) - Number(y);
        else r = a.cells[col].textContent.localeCompare(b.cells[col].textContent);
        return dir === "desc" ? -r : r;
      });
      rows.forEach(function (r) { tbody.appendChild(r); });
    });
  });
});
""" % PAYLOAD_ID


def esc(value: Any) -> str:
    return html_mod.escape(str(value), quote=True)


def _payload_script(doc: Dict[str, Any]) -> str:
    # "</" must not appear inside the script element (a literal "</script>"
    # in a region name would end the block early); JSON allows the escape.
    blob = json.dumps(doc, separators=(",", ":"), allow_nan=False)
    blob = blob.replace("</", "<\\/")
    return f'<script type="application/json" id="{PAYLOAD_ID}">{blob}</script>'


def _ms(ns: Optional[float]) -> str:
    return "—" if ns is None else f"{ns / 1e6:,.3f}"


def _mb(b: Optional[float]) -> str:
    return "—" if b is None else f"{b / 1e6:,.2f}"


def _num(v: Optional[float], fmt: str = ",.0f") -> str:
    return "—" if v is None else format(v, fmt)


def _cellv(v: Optional[float]) -> str:
    return "" if v is None else f' data-v="{v}"'


def _tile(label: str, value: str, cls: str = "") -> str:
    cls = f" {cls}" if cls else ""
    return (
        f'<div class="tile"><div class="v{cls}">{esc(value)}</div>'
        f'<div class="k">{esc(label)}</div></div>'
    )


def _header(doc: Dict[str, Any]) -> str:
    meta = doc.get("meta") or {}
    topo = meta.get("topology") or {}
    bits = []
    if meta.get("experiment"):
        bits.append(f"experiment <b>{esc(meta['experiment'])}</b>")
    if meta.get("instrumenter"):
        bits.append(f"instrumenter {esc(meta['instrumenter'])}")
    if topo.get("world_size", 1) and int(topo.get("world_size", 1)) > 1:
        bits.append(f"rank {topo.get('rank', 0)}/{topo.get('world_size')}")
    sub = " · ".join(bits)
    return (
        "<h1>Performance report</h1>"
        f'<p class="sub">{sub}</p>'
        f'<p class="sub mono">{esc(doc.get("run_dir", ""))}</p>'
    )


def _overview_tiles(doc: Dict[str, Any]) -> str:
    meta = doc.get("meta") or {}
    mem = doc.get("memory")
    gov = doc.get("governor")
    tiles = []
    t0, t1 = meta.get("epoch_time_ns"), meta.get("finalize_time_ns")
    if t0 and t1 and t1 > t0:
        tiles.append(_tile("wall time", f"{(t1 - t0) / 1e9:,.2f} s"))
    if meta.get("events_flushed") is not None:
        tiles.append(_tile("events recorded", f"{meta['events_flushed']:,}"))
    regions = doc.get("regions") or []
    if regions:
        tiles.append(_tile("regions", f"{len(regions):,}"))
    if mem:
        tiles.append(_tile("peak RSS", f"{_mb(mem['rss_peak_bytes'])} MB"))
        tiles.append(_tile("GC pause", f"{mem['gc_pause_ns_total'] / 1e6:,.1f} ms"))
    if gov:
        ok = gov.get("under_budget", True)
        tiles.append(
            _tile(
                f"overhead vs {gov['budget']:.0%} budget",
                f"{gov['overhead_fraction']:.2%} "
                + ("✓ under" if ok else "✗ over"),
                "ok" if ok else "bad",
            )
        )
    merge = doc.get("merge")
    if merge:
        tiles.append(_tile("ranks merged", f"{len(merge.get('ranks', []))}"))
        tiles.append(_tile("span events", f"{merge.get('total_events', 0):,}"))
    return f'<div class="tiles">{"".join(tiles)}</div>' if tiles else ""


_REGION_COLS = [
    ("region", "region", "l"),
    ("kind", "kind", "l"),
    ("visits", "visits", ""),
    ("excl ms", "excl_ns", ""),
    ("incl ms", "incl_ns", ""),
    ("mean µs", "mean_ns", ""),
    ("alloc MB", "alloc_bytes", ""),
    ("net MB", "net_bytes", ""),
    ("blocks", "alloc_blocks", ""),
    ("gov cost ms", "est_cost_ns", ""),
]


def _regions_table(doc: Dict[str, Any]) -> str:
    rows = doc.get("regions") or []
    if not rows:
        return ""
    head = "".join(
        f'<th class="{cls}">{esc(label)} <span class="dir"></span></th>'
        for label, _, cls in _REGION_COLS
    )
    body = []
    for r in rows[:MAX_TABLE_ROWS]:
        name = esc(r["region"]) + (
            ' <span class="note">[gov-excluded]</span>'
            if r.get("governor_excluded")
            else ""
        )
        cells = [
            f'<td class="l" title="{esc(r["region"])}">{name}</td>',
            f'<td class="l">{esc(r.get("kind") or "—")}</td>',
            f'<td{_cellv(r["visits"])}>{r["visits"]:,}</td>',
            f'<td{_cellv(r["excl_ns"])}>{_ms(r["excl_ns"])}</td>',
            f'<td{_cellv(r["incl_ns"])}>{_ms(r["incl_ns"])}</td>',
            f'<td{_cellv(r["mean_ns"])}>'
            + ("—" if r["mean_ns"] is None else f"{r['mean_ns'] / 1e3:,.2f}")
            + "</td>",
            f'<td{_cellv(r["alloc_bytes"])}>{_mb(r["alloc_bytes"])}</td>',
            f'<td{_cellv(r["net_bytes"])}>{_mb(r["net_bytes"])}</td>',
            f'<td{_cellv(r["alloc_blocks"])}>{_num(r["alloc_blocks"])}</td>',
            f'<td{_cellv(r["est_cost_ns"])}>{_ms(r["est_cost_ns"])}</td>',
        ]
        body.append("<tr>" + "".join(cells) + "</tr>")
    note = (
        f'<p class="note">showing {MAX_TABLE_ROWS} of {len(rows)} regions by '
        f"exclusive time — the full table is in the embedded JSON payload.</p>"
        if len(rows) > MAX_TABLE_ROWS
        else ""
    )
    return (
        "<h2>Regions — time &amp; memory</h2>"
        '<p class="note">click a column header to sort; time from profile.json, '
        "allocation columns from memory.json, governor columns from governor.json.</p>"
        f'<table class="sortable"><thead><tr>{head}</tr></thead>'
        f'<tbody>{"".join(body)}</tbody></table>{note}'
    )


def _timeline_section(doc: Dict[str, Any]) -> str:
    from .svg import sparkline

    series = doc.get("timelines") or {}
    if not series:
        return ""
    shown = sorted(series)[:MAX_TIMELINES]
    rows = []
    for name in shown:
        pts = series[name]
        svg = sparkline(pts)
        if not svg:
            continue
        vals = [v for _, v in pts]
        rows.append(
            f'<div class="sparkrow"><div class="name mono">{esc(name)}</div>{svg}'
            f'<div class="range">min {min(vals):,.2f} · max {max(vals):,.2f} · '
            f"last {vals[-1]:,.2f}</div></div>"
        )
    if not rows:
        return ""
    note = (
        f'<p class="note">showing {len(shown)} of {len(series)} series — '
        f"the rest are in the embedded JSON payload.</p>"
        if len(series) > len(shown)
        else ""
    )
    return "<h2>Timelines</h2>" + "".join(rows) + note


def _governor_section(doc: Dict[str, Any]) -> str:
    gov = doc.get("governor")
    if not gov:
        return ""
    out = ["<h2>Overhead governor</h2>"]
    out.append(
        '<p class="sub">'
        f"budget {gov['budget']:.1%} · calibrated {esc(gov['calibrated_instrumenter'])} "
        f"at {gov['cost_full_ns']:,.0f} ns/pair · final instrumenter "
        f"{esc(gov['final_instrumenter'])}"
        + (f" (period {gov['final_period']})" if gov.get("final_period") else "")
        + f" · estimated distortion {gov['overhead_fraction']:.2%} "
        + ("(under budget)" if gov["under_budget"] else "(<b>over budget</b>)")
        + "</p>"
    )
    actions = gov.get("actions") or []
    if actions:
        rows = "".join(
            f'<tr><td data-v="{a["t_ns"]}">{a["t_ns"] / 1e6:,.1f}</td>'
            f'<td data-v="{a["window_overhead"]}">{a["window_overhead"]:.1%}</td>'
            f'<td data-v="{a["projected_overhead"]}">{a["projected_overhead"]:.1%}</td>'
            f'<td class="l">{esc("; ".join(a["steps"]))}</td></tr>'
            for a in actions
        )
        out.append(
            "<table><thead><tr><th>t ms</th><th>measured</th><th>projected</th>"
            '<th class="l">escalation</th></tr></thead>'
            f"<tbody>{rows}</tbody></table>"
        )
    else:
        out.append('<p class="note">no escalations — the run stayed under budget.</p>')
    if gov.get("suggested_filter"):
        out.append(
            '<p class="sub">suggested filter for the next run '
            "(<code>--filter</code> / <code>REPRO_MONITOR_FILTER</code>):</p>"
            f'<pre class="spec">{esc(gov["suggested_filter"])}</pre>'
        )
    return "".join(out)


def _plan_section(doc: Dict[str, Any]) -> str:
    plan = doc.get("plan")
    if not plan:
        return ""
    v = plan.get("verdicts", {})
    out = ["<h2>Static plan vs observed</h2>"]
    out.append(
        '<p class="sub">'
        f"planned ahead of run over {plan.get('files', 0)} files / "
        f"{plan.get('functions', 0)} functions: "
        f"{v.get('exclude', 0)} auto-excluded, {v.get('sample', 0)} "
        f"sampler-friendly, {v.get('keep', 0)} kept "
        f"({plan.get('patterns', 0)} filter patterns)</p>"
    )
    conc = plan.get("concurrency")
    if conc:
        counts = conc.get("findings", {})
        flagged = sum(counts.values())
        detail = ", ".join(
            f"{rule} ×{n}" for rule, n in sorted(counts.items()) if n
        )
        out.append(
            "<h3>Concurrency</h3>"
            '<p class="sub">'
            f"{conc.get('entrypoints', 0)} concurrent entrypoints, "
            f"{conc.get('locks', 0)} locks, "
            f"{conc.get('wait_points', 0)} wait points "
            "(never auto-excluded — their spans are the wait-state signal)"
            "</p>"
        )
        if flagged:
            out.append(
                '<p class="note">'
                f"{flagged} static SP4xx finding(s): {esc(detail)} — "
                "run <code>analysis concurrency</code> for call-path "
                "witnesses.</p>"
            )
        else:
            out.append(
                '<p class="note">no static concurrency findings '
                "(SP401–SP405 clean).</p>"
            )
    vs = plan.get("vs_observed") or {}
    if not vs.get("governed"):
        out.append(
            '<p class="note">no governor ran — the plan\'s excludes applied, '
            "but there is no runtime verdict to compare against.</p>"
        )
        return "".join(out)
    rows = []
    for label, names, note in (
        ("pre-excluded", vs.get("pre_excluded", []),
         "excluded by the plan before any event fired"),
        ("confirmed", vs.get("confirmed", []),
         "predicted offenders the governor also excluded at runtime"),
        ("unconfirmed", vs.get("unconfirmed", []),
         "predicted offenders the governor observed but left alone"),
        ("unpredicted", vs.get("unpredicted", []),
         "runtime excludes the plan missed"),
    ):
        shown = ", ".join(names[:8]) + ("…" if len(names) > 8 else "")
        rows.append(
            f'<tr><td class="l">{esc(label)}</td>'
            f'<td data-v="{len(names)}">{len(names)}</td>'
            f'<td class="l">{esc(shown or "—")}</td>'
            f'<td class="l">{esc(note)}</td></tr>'
        )
    out.append(
        '<table><thead><tr><th class="l">bucket</th><th>n</th>'
        '<th class="l">regions</th><th class="l"></th></tr></thead>'
        f"<tbody>{''.join(rows)}</tbody></table>"
    )
    return "".join(out)


def _heat_class(value: float, row_max: float) -> str:
    if row_max <= 0:
        return "hc0"
    idx = min(int((value / row_max) * _N_HEAT), _N_HEAT - 1)
    return f"hc{idx}"


def _merge_section(doc: Dict[str, Any]) -> str:
    merge = doc.get("merge")
    if not merge:
        return ""
    out = ["<h2>Cross-rank view</h2>"]
    ranks = merge.get("ranks") or []
    if ranks:
        rows = "".join(
            f'<tr><td data-v="{r["rank"]}">{r["rank"]}</td>'
            f'<td data-v="{r["events"]}">{r["events"]:,}</td>'
            f'<td class="l mono">{esc(r["run_dir"])}</td></tr>'
            for r in ranks
        )
        out.append(
            "<table><thead><tr><th>rank</th><th>events</th>"
            '<th class="l">run dir</th></tr></thead>'
            f"<tbody>{rows}</tbody></table>"
        )
    dropped = merge.get("dropped_runs") or []
    if dropped:
        out.append(
            f'<p class="note">dropped {len(dropped)} stale duplicate run dir(s): '
            + ", ".join(esc(d["run_dir"]) for d in dropped)
            + "</p>"
        )
    profile = merge.get("profile") or {}
    if profile.get("regions"):
        heat_ranks = profile["ranks"]
        header = '<th class="l">region</th>' + "".join(
            f"<th>r{r}</th>" for r in heat_ranks
        ) + "<th>imbalance</th>"
        body = []
        imbalance = profile.get("imbalance") or {}
        for name, row in zip(profile["regions"], profile["excl_ns"]):
            row_max = max(row) if row else 0
            cells = "".join(
                f'<td class="cell {_heat_class(v, row_max)}" '
                f'title="{esc(name)} @ rank {r}: {v / 1e6:,.3f} ms">'
                f"{v / 1e6:,.1f}</td>"
                for r, v in zip(heat_ranks, row)
            )
            imb = imbalance.get(name)
            body.append(
                f'<tr><td class="l" title="{esc(name)}">{esc(name)}</td>{cells}'
                f"<td>{_num(imb, '.2f') if imb is not None else '—'}</td></tr>"
            )
        out.append(
            "<h2>Per-region exclusive time by rank (ms)</h2>"
            '<p class="note">cell shade is relative to the region&#39;s own '
            "max across ranks — darker = closer to the slowest rank; "
            "imbalance = max/mean.</p>"
            f'<table class="heat"><thead><tr>{header}</tr></thead>'
            f'<tbody>{"".join(body)}</tbody></table>'
        )
    memory = merge.get("memory") or {}
    if memory.get("peak_rss"):
        peak = memory["peak_rss"]
        imb = peak.get("imbalance")
        out.append(
            '<p class="sub">peak RSS: '
            f"max {_mb(peak.get('max_bytes'))} MB (rank {peak.get('max_rank')}) / "
            f"min {_mb(peak.get('min_bytes'))} MB (rank {peak.get('min_rank')}), "
            f"imbalance {_num(imb, '.2f') if imb else '—'}×</p>"
        )
    governor = merge.get("governor") or {}
    if governor:
        out.append(
            f'<p class="sub">governor: {governor.get("actions_total", 0)} actions '
            f'across {len(governor.get("ranks", []))} ranks, '
            f'{governor.get("ranks_over_budget", 0)} rank(s) over budget.</p>'
        )
        if governor.get("suggested_filter"):
            out.append(
                f'<pre class="spec">{esc(governor["suggested_filter"])}</pre>'
            )
    return "".join(out)


def _delta_bar(delta: float, max_abs: float, width: int = 90) -> str:
    if max_abs <= 0 or delta == 0:
        return ""
    w = max(2, int(abs(delta) / max_abs * width))
    cls = "pos" if delta > 0 else "neg"
    return f'<span class="bar {cls}" style="width:{w}px"></span> '


def _diff_section(doc: Dict[str, Any]) -> str:
    diff = doc.get("diff")
    if not diff:
        return ""
    out = [
        "<h2>Run-vs-run diff</h2>",
        f'<p class="sub">base (A): <span class="mono">{esc(diff["base"])}</span> '
        f'→ this run (B): <span class="mono">{esc(doc["run_dir"])}</span>. '
        "Red bars mark regressions (B slower / allocating more), blue bars "
        "improvements.</p>",
    ]
    rows = diff.get("profile") or []
    if rows:
        shown = rows[:40]
        max_abs = max(abs(r["delta_ns"]) for r in shown)
        body = "".join(
            f'<tr><td class="l" title="{esc(r["region"])}">{esc(r["region"])}</td>'
            f'<td data-v="{r["delta_ns"]}">{_delta_bar(r["delta_ns"], max_abs)}'
            f'{r["delta_ns"] / 1e6:+,.3f}</td>'
            f'<td data-v="{r["excl_ns_a"]}">{_ms(r["excl_ns_a"])}</td>'
            f'<td data-v="{r["excl_ns_b"]}">{_ms(r["excl_ns_b"])}</td>'
            f'<td>{"new" if r["ratio"] is None else format(r["ratio"], ".2f")}</td></tr>'
            for r in shown
        )
        out.append(
            "<h2>Exclusive-time deltas (ms)</h2>"
            '<table class="sortable"><thead><tr><th class="l">region '
            '<span class="dir"></span></th><th>Δ ms <span class="dir"></span></th>'
            '<th>A ms <span class="dir"></span></th><th>B ms <span class="dir"></span></th>'
            '<th>ratio <span class="dir"></span></th></tr></thead>'
            f"<tbody>{body}</tbody></table>"
        )
        if len(rows) > len(shown):
            out.append(
                f'<p class="note">showing 40 of {len(rows)} changed regions — '
                "full rows in the embedded JSON payload.</p>"
            )
    mem_rows = diff.get("memory") or []
    if mem_rows:
        shown = mem_rows[:25]
        max_abs = max(abs(r["delta_bytes"]) for r in shown)
        body = "".join(
            f'<tr><td class="l" title="{esc(r["region"])}">{esc(r["region"])}</td>'
            f'<td data-v="{r["delta_bytes"]}">{_delta_bar(r["delta_bytes"], max_abs)}'
            f'{r["delta_bytes"] / 1e6:+,.2f}</td>'
            f'<td data-v="{r["alloc_bytes_a"]}">{_mb(r["alloc_bytes_a"])}</td>'
            f'<td data-v="{r["alloc_bytes_b"]}">{_mb(r["alloc_bytes_b"])}</td></tr>'
            for r in shown
        )
        out.append(
            "<h2>Allocation deltas (MB)</h2>"
            '<table class="sortable"><thead><tr><th class="l">region '
            '<span class="dir"></span></th><th>Δ MB <span class="dir"></span></th>'
            '<th>A MB <span class="dir"></span></th><th>B MB <span class="dir"></span></th>'
            "</tr></thead>"
            f"<tbody>{body}</tbody></table>"
        )
    return "".join(out)


def _fleet_verdict_cls(verdict: str) -> str:
    return "ok" if verdict in ("ok", "seeding", "improvement", "stable") else "bad"


def _fleet_findings_table(findings, value_fmt) -> str:
    body = []
    for f in findings[:25]:
        name = f.get("region") or f.get("metric") or "?"
        rel = f.get("rel_change")
        p = f.get("p")
        body.append(
            f'<tr><td class="l"><span class="v {_fleet_verdict_cls(f["verdict"])}">'
            f'{esc(f["verdict"])}</span></td>'
            f'<td class="l" title="{esc(name)}">{esc(name)}</td>'
            f'<td data-v="{f["baseline"]["median"]}">{value_fmt(f["baseline"]["median"])}</td>'
            f'<td data-v="{f["candidate"]["median"]}">{value_fmt(f["candidate"]["median"])}</td>'
            f'<td data-v="{rel if rel is not None else 0}">'
            + ("new" if rel is None else f"{rel:+.1%}") + "</td>"
            f'<td data-v="{f["effect_size"]}">{f["effect_size"]:+.2f} '
            f'({esc(f["effect"])})</td>'
            f'<td class="l">{"p=" + format(p, ".2g") if p is not None else esc(f.get("method") or "—")}'
            f' · {esc(f["confidence"])}</td></tr>'
        )
    return (
        '<table class="sortable"><thead><tr>'
        '<th class="l">verdict <span class="dir"></span></th>'
        '<th class="l">region / metric <span class="dir"></span></th>'
        '<th>baseline <span class="dir"></span></th>'
        '<th>candidate <span class="dir"></span></th>'
        '<th>Δ <span class="dir"></span></th>'
        '<th>effect <span class="dir"></span></th>'
        '<th class="l">evidence</th></tr></thead>'
        f"<tbody>{''.join(body)}</tbody></table>"
    )


def _fleet_sparklines(series: Dict[str, Any], note: str, limit: int = 8) -> str:
    from .svg import sparkline

    rows = []
    for name in list(series)[:limit]:
        pts = [
            (float(i) * 1e9, float(v))
            for i, v in enumerate(series[name] or [])
            if v is not None
        ]
        svg = sparkline(pts)
        if not svg:
            continue
        vals = [v for _, v in pts]
        rows.append(
            f'<div class="sparkrow"><div class="name mono">{esc(name)}</div>{svg}'
            f'<div class="range">min {min(vals):,.3g} · max {max(vals):,.3g} · '
            f"last {vals[-1]:,.3g}</div></div>"
        )
    if not rows:
        return ""
    return f'<p class="note">{esc(note)}</p>' + "".join(rows)


def _fleet_section(doc: Dict[str, Any]) -> str:
    fleet = doc.get("fleet")
    if not fleet:
        return ""
    verdict = fleet.get("verdict", "?")
    badge = (
        f'<span class="v {_fleet_verdict_cls(verdict)}">{esc(verdict)}</span>'
    )
    w = fleet.get("windows") or {}
    out = ["<h2>Fleet — run-population analytics</h2>"]
    if fleet.get("mode") == "gate":
        out.append(
            f'<p class="sub">perf gate: {len(fleet.get("snapshots", []))} '
            f"trajectory snapshot(s), {w.get('baseline_n', 0)} baseline / "
            f"{w.get('candidate_n', 0)} candidate · "
            f"{fleet.get('metrics_watched', 0)} watched metric(s) · verdict "
            + badge + "</p>"
        )
        findings = fleet.get("findings") or []
        if findings:
            out.append(_fleet_findings_table(findings, lambda v: f"{v:,.4g}"))
        out.append(
            _fleet_sparklines(
                fleet.get("series") or {},
                "watched metrics across trajectory snapshots (x = snapshot index)",
            )
        )
        return "".join(out)
    out.append(
        f'<p class="sub">{len(fleet.get("runs", []))} run(s), '
        f"{w.get('baseline_n', 0)} baseline / {w.get('candidate_n', 0)} "
        f"candidate (effect-size windows) · verdict " + badge + "</p>"
    )
    for title, key, fmt in (
        ("Exclusive-time shifts", "time", _ms),
        ("Allocation shifts", "alloc", _mb),
    ):
        section = fleet.get(key) or {}
        findings = section.get("findings") or []
        if findings:
            out.append(f"<h3>{title}</h3>")
            out.append(_fleet_findings_table(findings, fmt))
    leaks = fleet.get("leaks") or {}
    leak_rows = [r for r in leaks.get("regions", []) if r.get("verdict") == "leak"]
    process = leaks.get("process") or {}
    process_leaks = {k: v for k, v in sorted(process.items()) if v.get("verdict") == "leak"}
    if leak_rows or process_leaks:
        out.append("<h3>Leak verdicts</h3>")
        body = []
        for r in leak_rows:
            body.append(
                f'<tr><td class="l">{esc(r["region"])}</td>'
                f'<td data-v="{r["alloc_velocity_bytes"]}">{_mb(r["alloc_velocity_bytes"])}</td>'
                f'<td data-v="{r["reclaim_rate"]}">{r["reclaim_rate"]:.1%}</td>'
                f'<td data-v="{r["net_median_bytes"]}">{_mb(r["net_median_bytes"])}</td>'
                f'<td>{r["net_positive_runs"]}/{r["runs"]}</td>'
                f'<td class="l">p={r["p"]:.2g} · {esc(r["confidence"])}</td></tr>'
            )
        for name, sig in process_leaks.items():
            body.append(
                f'<tr><td class="l">process {esc(name)}</td>'
                f'<td data-v="{sig["median_slope_bytes_s"]}">'
                f'{sig["median_slope_bytes_s"] / 1e3:,.1f} kB/s</td>'
                f"<td>—</td><td>—</td>"
                f'<td>{sig["positive_runs"]}/{sig["runs"]}</td>'
                f'<td class="l">p={sig["p"]:.2g} · {esc(sig["confidence"])}</td></tr>'
            )
        out.append(
            '<table><thead><tr><th class="l">region</th>'
            "<th>alloc velocity /run</th><th>reclaim</th><th>net median /run</th>"
            '<th>runs climbing</th><th class="l">evidence</th></tr></thead>'
            f"<tbody>{''.join(body)}</tbody></table>"
        )
    elif leaks:
        out.append(
            f'<p class="note">no leak verdicts over '
            f"{leaks.get('checked_regions', 0)} region(s) + process "
            f"heap/RSS timelines.</p>"
        )
    series = fleet.get("series") or {}
    out.append(
        _fleet_sparklines(
            (series.get("time") or {}),
            "per-region exclusive time across the population (x = run index)",
        )
    )
    return "".join(out)


def _metrics_section(doc: Dict[str, Any]) -> str:
    metrics = doc.get("metrics")
    if not metrics:
        return ""
    body = []
    for name in sorted(metrics):
        m = metrics[name]
        body.append(
            f'<tr><td class="l mono">{esc(name)}</td>'
            f'<td data-v="{m.get("count", 0)}">{m.get("count", 0):,}</td>'
            f'<td{_cellv(m.get("mean"))}>{_num(m.get("mean"), ",.4g")}</td>'
            f'<td{_cellv(m.get("min"))}>{_num(m.get("min"), ",.4g")}</td>'
            f'<td{_cellv(m.get("max"))}>{_num(m.get("max"), ",.4g")}</td>'
            f'<td{_cellv(m.get("p99"))}>{_num(m.get("p99"), ",.4g")}</td></tr>'
        )
    return (
        "<h2>Metrics</h2>"
        '<table class="sortable"><thead><tr><th class="l">metric '
        '<span class="dir"></span></th><th>count <span class="dir"></span></th>'
        '<th>mean <span class="dir"></span></th><th>min <span class="dir"></span></th>'
        '<th>max <span class="dir"></span></th><th>p99 <span class="dir"></span></th>'
        f'</tr></thead><tbody>{"".join(body)}</tbody></table>'
    )


def render_report(doc: Dict[str, Any]) -> str:
    """Render the data model into one self-contained HTML page."""
    title = (doc.get("meta") or {}).get("experiment") or "run"
    sections = [
        _header(doc),
        _overview_tiles(doc),
        _regions_table(doc),
        _timeline_section(doc),
        _metrics_section(doc),
        _governor_section(doc),
        _plan_section(doc),
        _merge_section(doc),
        _fleet_section(doc),
        _diff_section(doc),
        f'<p class="note">generated by repro.core.report · schema '
        f"v{doc.get(SCHEMA_KEY, '?')} · data: embedded JSON payload "
        f'<code>#{PAYLOAD_ID}</code></p>',
    ]
    return (
        "<!DOCTYPE html>\n"
        '<html lang="en"><head><meta charset="utf-8">'
        f"<title>repro report — {esc(title)}</title>"
        '<meta name="viewport" content="width=device-width, initial-scale=1">'
        f"<style>{_CSS}</style></head><body>"
        + "".join(s for s in sections if s)
        + _payload_script(doc)
        + f"<script>{_JS}</script></body></html>"
    )


def extract_payload(page: str) -> Dict[str, Any]:
    """Parse the embedded JSON payload back out of a rendered report page —
    the round-trip the contract tests exercise."""
    marker = f'<script type="application/json" id="{PAYLOAD_ID}">'
    start = page.index(marker) + len(marker)
    end = page.index("</script>", start)
    return json.loads(page[start:end])
