"""Inline-SVG primitives for the HTML report — zero dependencies.

Only what the report needs: a timeline sparkline (RSS / heap / GC / metric
series) with native ``<title>`` hover tooltips, so the generated page stays
fully self-contained (no charting library, no network).  Colors are CSS
custom properties supplied by the page style (``--series-1`` etc.), so the
SVG follows the page's light/dark mode for free.
"""

from __future__ import annotations

from typing import Sequence, Tuple

__all__ = ["sparkline"]


def _scale(values: Sequence[float], lo: float, hi: float, out_lo: float, out_hi: float):
    span = hi - lo
    if span <= 0:  # constant series: park everything mid-range
        mid = (out_lo + out_hi) / 2.0
        return [mid for _ in values]
    k = (out_hi - out_lo) / span
    return [out_lo + (v - lo) * k for v in values]


def sparkline(
    points: Sequence[Tuple[float, float]],
    width: int = 560,
    height: int = 64,
    pad: float = 6.0,
    unit: str = "",
) -> str:
    """A single-series sparkline for ``[(t_ns, value), ...]``.

    2px line + translucent area fill (both from CSS vars), invisible hover
    targets carrying ``<title>`` tooltips with the exact value and the
    offset from the first sample in seconds.  Returns ``""`` for an empty
    series so callers can drop the section cleanly.
    """
    pts = [(float(t), float(v)) for t, v in points]
    if not pts:
        return ""
    ts = [t for t, _ in pts]
    vs = [v for _, v in pts]
    t0 = ts[0]
    xs = _scale(ts, min(ts), max(ts), pad, width - pad)
    # SVG y grows downward: map the max value to the top padding.
    ys = _scale(vs, min(vs), max(vs), height - pad, pad)
    line = " ".join(f"{x:.1f},{y:.1f}" for x, y in zip(xs, ys))
    area = (
        f"M{xs[0]:.1f},{height - pad:.1f} "
        + " ".join(f"L{x:.1f},{y:.1f}" for x, y in zip(xs, ys))
        + f" L{xs[-1]:.1f},{height - pad:.1f} Z"
    )
    hovers = []
    for (t, v), x, y in zip(pts, xs, ys):
        label = f"{v:,.2f}{unit} @ +{(t - t0) / 1e9:.2f}s"
        hovers.append(
            f'<circle class="spark-hit" cx="{x:.1f}" cy="{y:.1f}" r="7">'
            f"<title>{label}</title></circle>"
        )
    return (
        f'<svg class="spark" viewBox="0 0 {width} {height}" width="{width}" '
        f'height="{height}" role="img">'
        f'<path class="spark-area" d="{area}"/>'
        f'<polyline class="spark-line" points="{line}"/>'
        + "".join(hovers)
        + "</svg>"
    )
