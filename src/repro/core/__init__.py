"""repro.core — Score-P-style performance monitoring for Python/JAX.

Public API (paper §2: user instrumentation + measurement lifecycle):

    import repro.core as rmon

    rmon.init(instrumenter="profile")      # or: run under `python -m repro.scorep`
    with rmon.region("phase"):
        ...
    rmon.metric("tokens", 4096.0)
    run_dir = rmon.finalize()
"""

from .buffer import (  # noqa: F401
    EV_C_ENTER,
    EV_C_EXIT,
    EV_ENTER,
    EV_EXCEPTION,
    EV_EXIT,
    EV_LINE,
    BUFFER_STRATEGIES,
    ListEventBuffer,
    NumpyEventBuffer,
)
from .filtering import Filter  # noqa: F401
from .governor import Governor, load_governor  # noqa: F401
from .instrumenters import INSTRUMENTERS, make_instrumenter  # noqa: F401
from .measurement import (  # noqa: F401
    Measurement,
    MeasurementConfig,
    active,
    current_topology,
    finalize,
    init,
    init_from_env,
    instrument,
    metric,
    region,
)
from .regions import Region, RegionRegistry  # noqa: F401
from .substrates import SUBSTRATES, make_substrate  # noqa: F401
from .topology import ProcessTopology  # noqa: F401

__all__ = [
    "Measurement",
    "MeasurementConfig",
    "ProcessTopology",
    "current_topology",
    "init",
    "init_from_env",
    "finalize",
    "active",
    "region",
    "metric",
    "instrument",
    "Filter",
    "Governor",
    "load_governor",
    "Region",
    "RegionRegistry",
    "INSTRUMENTERS",
    "SUBSTRATES",
    "make_instrumenter",
    "make_substrate",
    "ListEventBuffer",
    "NumpyEventBuffer",
    "BUFFER_STRATEGIES",
]
