"""Metrics substrate — counters and per-step series (Score-P metric plugins).

Collects user metrics (``repro.core.metric(name, value)``) as time series and
aggregates; the JAX integration layer feeds per-step wall times, HLO FLOPs /
bytes from ``cost_analysis`` and collective-byte counters through this
substrate.  Events themselves are summarized only by count (cheap).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List

import numpy as np

from .base import Substrate


class MetricsSubstrate(Substrate):
    name = "metrics"

    def __init__(self, keep_series: bool = True):
        self.keep_series = keep_series
        self._series: Dict[str, List] = {}
        self._agg: Dict[str, Dict[str, float]] = {}
        self._event_counts: Dict[int, int] = {}
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta

    def on_flush(self, thread_id: int, columns) -> None:
        n = int(len(columns["kind"]))
        self._event_counts[thread_id] = self._event_counts.get(thread_id, 0) + n

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        agg = self._agg.get(name)
        if agg is None:
            agg = self._agg[name] = {"count": 0, "sum": 0.0, "min": float("inf"), "max": float("-inf")}
        agg["count"] += 1
        agg["sum"] += value
        agg["min"] = min(agg["min"], value)
        agg["max"] = max(agg["max"], value)
        if self.keep_series:
            self._series.setdefault(name, []).append((t_ns, value))

    def summary(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name, agg in self._agg.items():
            mean = agg["sum"] / max(agg["count"], 1)
            entry = dict(agg, mean=mean)
            series = self._series.get(name)
            if series:
                vals = np.asarray([v for _, v in series], dtype=np.float64)
                entry["median"] = float(np.median(vals))
                entry["p99"] = float(np.percentile(vals, 99))
            out[name] = entry
        return out

    def close(self, region_table) -> None:
        doc = {
            "meta": self._meta,
            "events_per_thread": {str(k): v for k, v in self._event_counts.items()},
            "metrics": self.summary(),
        }
        if self.keep_series:
            doc["series"] = {
                name: [[int(t), float(v)] for t, v in vals] for name, vals in self._series.items()
            }
        with open(os.path.join(self._run_dir, "metrics.json"), "w") as fh:
            json.dump(doc, fh, indent=1)
