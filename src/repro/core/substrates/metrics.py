"""Metrics substrate — counters and per-step series (Score-P metric plugins).

Collects user metrics (``repro.core.metric(name, value)``) as time series and
aggregates; the JAX integration layer feeds per-step wall times, HLO FLOPs /
bytes from ``cost_analysis`` and collective-byte counters through this
substrate.  Events themselves are summarized only by count (cheap).

Non-finite metric values (a NaN loss is a fact of life in training) must not
poison the artifacts: aggregates are computed over the finite samples (with a
``nonfinite`` count alongside), series entries serialize non-finite values as
``null``, and ``metrics.json`` is written with ``allow_nan=False`` so it is
always strictly-parseable JSON (bare ``NaN``/``Infinity`` are not JSON).
"""

from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List, Optional

import numpy as np

from ..schema import stamp
from .base import Substrate


def _finite_or_none(value: float) -> Optional[float]:
    return float(value) if math.isfinite(value) else None


class MetricsSubstrate(Substrate):
    name = "metrics"

    def __init__(self, keep_series: bool = True):
        self.keep_series = keep_series
        self._series: Dict[str, List] = {}
        self._agg: Dict[str, Dict[str, float]] = {}
        self._event_counts: Dict[int, int] = {}
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta

    def on_flush(self, thread_id: int, columns) -> None:
        n = int(len(columns["kind"]))
        self._event_counts[thread_id] = self._event_counts.get(thread_id, 0) + n

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        agg = self._agg.get(name)
        if agg is None:
            agg = self._agg[name] = {
                "count": 0, "nonfinite": 0, "sum": 0.0,
                "min": float("inf"), "max": float("-inf"),
            }
        agg["count"] += 1
        if math.isfinite(value):
            agg["sum"] += value
            agg["min"] = min(agg["min"], value)
            agg["max"] = max(agg["max"], value)
        else:
            agg["nonfinite"] += 1
        if self.keep_series:
            self._series.setdefault(name, []).append((t_ns, value))

    def summary(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name, agg in self._agg.items():
            finite = agg["count"] - agg["nonfinite"]
            entry = dict(agg, mean=agg["sum"] / finite if finite else None)
            if finite == 0:  # min/max stayed at their +-inf sentinels
                entry["min"] = entry["max"] = None
            series = self._series.get(name)
            if series:
                vals = np.asarray([v for _, v in series], dtype=np.float64)
                vals = vals[np.isfinite(vals)]
                if len(vals):
                    entry["median"] = float(np.median(vals))
                    entry["p99"] = float(np.percentile(vals, 99))
            out[name] = entry
        return out

    def close(self, region_table) -> None:
        doc = stamp({
            "meta": self._meta,
            "events_per_thread": {str(k): v for k, v in self._event_counts.items()},
            "metrics": self.summary(),
        })
        if self.keep_series:
            doc["series"] = {
                name: [[int(t), _finite_or_none(v)] for t, v in vals]
                for name, vals in self._series.items()
            }
        with open(os.path.join(self._run_dir, "metrics.json"), "w") as fh:
            json.dump(doc, fh, indent=1, allow_nan=False)
