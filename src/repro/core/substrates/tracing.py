"""Tracing substrate — OTF2-analogue event streams + Chrome trace export.

Artifact layout (one run directory per process, mirroring OTF2's
one-archive-per-run with per-location event streams):

    <run_dir>/
      defs.json            region table + process meta + clock epoch
      stream_t<tid>.npz    per-thread event columns (kind/region/t/aux)
      trace.json           Chrome trace-event export (the "Vampir" view)

Streams store raw columns; conversion to viewable form happens offline
(`to_chrome`, backed by the streaming vectorized engine in
``repro.core.export``) — the measurement-time cost is a numpy concatenate
per flush.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from ..schema import stamp
from .base import Substrate


class TracingSubstrate(Substrate):
    name = "tracing"

    def __init__(self, chrome_export: bool = True):
        self._chunks: Dict[int, List[Dict[str, np.ndarray]]] = {}
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}
        self.chrome_export = chrome_export
        self.export_stats: Optional[Dict[str, Any]] = None

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta

    def on_flush(self, thread_id: int, columns: Dict[str, np.ndarray]) -> None:
        self._chunks.setdefault(thread_id, []).append(columns)

    def close(self, region_table: List[Dict[str, Any]]) -> None:
        streams = {}
        for tid, chunks in sorted(self._chunks.items()):
            cols = {
                key: np.concatenate([c[key] for c in chunks]) if chunks else np.empty(0)
                for key in ("kind", "region", "t", "aux")
            }
            path = os.path.join(self._run_dir, f"stream_t{tid}.npz")
            np.savez_compressed(path, **cols)
            streams[str(tid)] = {"file": os.path.basename(path), "events": int(len(cols["kind"]))}
        defs = stamp({
            "meta": self._meta,
            "streams": streams,
            "regions": region_table,
        })
        with open(os.path.join(self._run_dir, "defs.json"), "w") as fh:
            json.dump(defs, fh, indent=1)

    def export_chrome(self) -> Optional[Dict[str, Any]]:
        """Run the streaming Chrome export.  Called by the measurement
        manager *after* every substrate has closed, so the exporter can pick
        up metric series from ``metrics.json`` as counter tracks."""
        if not self.chrome_export or not self._run_dir:
            return None
        from ..export import export_run

        self.export_stats = export_run(self._run_dir)
        return self.export_stats


# ----------------------------------------------------------------------------
# Offline conversion (the "Vampir" role is played by chrome://tracing/Perfetto)
# ----------------------------------------------------------------------------

def load_run(run_dir: str):
    """Load (defs, {tid: columns}) from a trace run directory."""
    with open(os.path.join(run_dir, "defs.json")) as fh:
        defs = json.load(fh)
    streams = {}
    for tid, info in defs.get("streams", {}).items():
        with np.load(os.path.join(run_dir, info["file"])) as z:
            streams[int(tid)] = {k: z[k] for k in z.files}
    return defs, streams


def to_chrome(run_dir: str, out_path: Optional[str] = None, chunk: Optional[int] = None) -> str:
    """Export a run directory to Chrome trace-event JSON ("B"/"E" spans,
    metadata and counter tracks) via the streaming vectorized engine."""
    from ..export import export_run

    return export_run(run_dir, out_path=out_path, chunk=chunk)["out"]
