"""Profiling substrate — call-path profile construction (Cube4 analogue).

Builds a call tree with per-node metrics (visits, inclusive/exclusive ns)
by replaying buffered event batches with a per-thread shadow stack.  Unlike
Score-P (which updates the profile online per event), construction happens
at *flush* granularity; the per-event cost stays a single buffer append.
The stack discipline itself (including orphan/mismatched-exit handling)
lives in :mod:`repro.core.replay`, shared with the memory substrate.

Artifacts:
    profile.json   call tree + flat per-region table (the Cube data model:
                   call-path × metric)
    profile.txt    human-readable tree + hotspot table
"""

from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List

import numpy as np

from ..buffer import EV_EXCEPTION, EV_LINE
from ..replay import ReplayState, replay, unwind
from ..schema import stamp
from .base import Substrate


class _Node:
    __slots__ = ("region", "parent", "children", "visits", "incl_ns", "excl_ns")

    def __init__(self, region: int, parent: "_Node | None"):
        self.region = region
        self.parent = parent
        self.children: Dict[int, _Node] = {}
        self.visits = 0
        self.incl_ns = 0
        self.excl_ns = 0

    def child(self, region: int) -> "_Node":
        node = self.children.get(region)
        if node is None:
            node = _Node(region, self)
            self.children[region] = node
        return node


class _ThreadState:
    __slots__ = ("root", "node", "replay", "lines", "exceptions")

    def __init__(self):
        self.root = _Node(-1, None)
        self.node = self.root
        self.replay = ReplayState()
        self.lines: Dict[int, int] = {}
        self.exceptions = 0

    # Compatibility accessors (tests and tools read these off the state).
    @property
    def stack(self) -> List[List[int]]:
        return self.replay.stack

    @property
    def last_t(self) -> int:
        return self.replay.last_t

    @property
    def orphan_exits(self) -> int:
        return self.replay.orphan_exits

    @property
    def mismatched_exits(self) -> int:
        return self.replay.mismatched_exits

    # Replay callbacks: descend/ascend the call tree in lock-step with the
    # shared shadow stack and accumulate the timing metrics.
    def _on_enter(self, region: int, t: int) -> None:
        self.node = self.node.child(region)

    def _on_close(self, region: int, enter_t: int, exit_t: int, child_ns: int) -> None:
        node = self.node
        dur = exit_t - enter_t
        node.visits += 1
        node.incl_ns += dur
        node.excl_ns += dur - child_ns
        if node.parent is not None:
            self.node = node.parent

    def _on_other(self, kind: int, region: int, t: int, aux: int) -> None:
        if kind == EV_LINE:
            self.lines[region] = self.lines.get(region, 0) + 1
        elif kind == EV_EXCEPTION:
            self.exceptions += 1


class ProfilingSubstrate(Substrate):
    name = "profiling"

    def __init__(self):
        self._threads: Dict[int, _ThreadState] = {}
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}
        self._metrics: Dict[str, float] = {}

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        # Skip non-finite samples: one NaN would poison the running sum and
        # make profile.json unparseable (bare NaN is not valid JSON).
        if math.isfinite(value):
            self._metrics[name] = self._metrics.get(name, 0.0) + value

    def on_flush(self, thread_id: int, columns: Dict[str, np.ndarray]) -> None:
        state = self._threads.get(thread_id)
        if state is None:
            state = self._threads[thread_id] = _ThreadState()
        replay(
            state.replay,
            columns["kind"],
            columns["region"],
            columns["t"],
            auxs=columns.get("aux"),
            on_enter=state._on_enter,
            on_close=state._on_close,
            on_other=state._on_other,
        )

    # -- finalize -----------------------------------------------------------

    def close(self, region_table: List[Dict[str, Any]]) -> None:
        def name_of(rid: int) -> str:
            if rid < 0:
                return "<root>"
            r = region_table[rid]
            return f"{r['module']}:{r['name']}"

        flat: Dict[int, Dict[str, Any]] = {}

        def tree_dict(node: _Node) -> Dict[str, Any]:
            if node.region >= 0:
                agg = flat.setdefault(
                    node.region,
                    # kind rides along so offline tools (analysis
                    # suggest-filter) can honor the "user regions are never
                    # auto-excluded" invariant without defs.json.
                    {
                        "visits": 0,
                        "incl_ns": 0,
                        "excl_ns": 0,
                        "kind": region_table[node.region]["kind"],
                    },
                )
                agg["visits"] += node.visits
                agg["incl_ns"] += node.incl_ns
                agg["excl_ns"] += node.excl_ns
            return {
                "region": node.region,
                "name": name_of(node.region),
                "visits": node.visits,
                "incl_ns": node.incl_ns,
                "excl_ns": node.excl_ns,
                "children": [tree_dict(c) for c in node.children.values()],
            }

        threads_doc = {}
        for tid, state in sorted(self._threads.items()):
            unwind(state.replay, state._on_close)
            threads_doc[str(tid)] = {
                "calltree": tree_dict(state.root),
                "orphan_exits": state.orphan_exits,
                "mismatched_exits": state.mismatched_exits,
                "exceptions": state.exceptions,
                "lines_executed": {str(k): v for k, v in state.lines.items()},
            }

        doc = stamp({
            "meta": self._meta,
            "metrics": self._metrics,
            "threads": threads_doc,
            "flat": {
                name_of(rid): vals
                for rid, vals in sorted(flat.items(), key=lambda kv: -kv[1]["excl_ns"])
            },
        })
        with open(os.path.join(self._run_dir, "profile.json"), "w") as fh:
            json.dump(doc, fh, indent=1, allow_nan=False)
        with open(os.path.join(self._run_dir, "profile.txt"), "w") as fh:
            fh.write(render_text(doc))

    # kept for tests / tools
    @property
    def threads(self) -> Dict[int, _ThreadState]:
        return self._threads


def render_text(doc: Dict[str, Any], max_depth: int = 12, top: int = 30) -> str:
    """Pretty text rendering: per-thread call tree + hotspot table."""
    out: List[str] = []
    for tid, tdoc in doc["threads"].items():
        out.append(f"== thread {tid} ==")

        def walk(node, depth):
            if depth > max_depth:
                return
            if node["region"] >= 0:
                out.append(
                    f"{'  ' * depth}{node['name']}  visits={node['visits']} "
                    f"incl={node['incl_ns'] / 1e6:.3f}ms excl={node['excl_ns'] / 1e6:.3f}ms"
                )
            for ch in node["children"]:
                walk(ch, depth + (node["region"] >= 0))

        walk(tdoc["calltree"], 0)
    out.append("")
    out.append("== hotspots (by exclusive time) ==")
    for i, (name, vals) in enumerate(doc["flat"].items()):
        if i >= top:
            break
        out.append(
            f"{vals['excl_ns'] / 1e6:12.3f}ms excl {vals['incl_ns'] / 1e6:12.3f}ms incl "
            f"{vals['visits']:10d}x  {name}"
        )
    if doc.get("metrics"):
        out.append("")
        out.append("== metrics ==")
        for name, val in sorted(doc["metrics"].items()):
            out.append(f"{name} = {val}")
    return "\n".join(out) + "\n"
