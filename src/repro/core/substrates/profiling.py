"""Profiling substrate — call-path profile construction (Cube4 analogue).

Builds a call tree with per-node metrics (visits, inclusive/exclusive ns)
by replaying buffered event batches with a per-thread shadow stack.  Unlike
Score-P (which updates the profile online per event), construction happens
at *flush* granularity; the per-event cost stays a single buffer append.

Artifacts:
    profile.json   call tree + flat per-region table (the Cube data model:
                   call-path × metric)
    profile.txt    human-readable tree + hotspot table
"""

from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List

import numpy as np

from ..buffer import (
    EV_C_ENTER,
    EV_C_EXIT,
    EV_ENTER,
    EV_EXCEPTION,
    EV_EXIT,
    EV_LINE,
)
from .base import Substrate


class _Node:
    __slots__ = ("region", "parent", "children", "visits", "incl_ns", "excl_ns")

    def __init__(self, region: int, parent: "_Node | None"):
        self.region = region
        self.parent = parent
        self.children: Dict[int, _Node] = {}
        self.visits = 0
        self.incl_ns = 0
        self.excl_ns = 0

    def child(self, region: int) -> "_Node":
        node = self.children.get(region)
        if node is None:
            node = _Node(region, self)
            self.children[region] = node
        return node


class _ThreadState:
    __slots__ = (
        "root",
        "node",
        "stack",
        "last_t",
        "orphan_exits",
        "mismatched_exits",
        "lines",
        "exceptions",
    )

    def __init__(self):
        self.root = _Node(-1, None)
        self.node = self.root
        # stack holds (enter_t, child_ns_accumulator) parallel to node depth
        self.stack: List[List[int]] = []
        self.last_t = 0
        self.orphan_exits = 0
        self.mismatched_exits = 0
        self.lines: Dict[int, int] = {}
        self.exceptions = 0


class ProfilingSubstrate(Substrate):
    name = "profiling"

    def __init__(self):
        self._threads: Dict[int, _ThreadState] = {}
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}
        self._metrics: Dict[str, float] = {}

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        # Skip non-finite samples: one NaN would poison the running sum and
        # make profile.json unparseable (bare NaN is not valid JSON).
        if math.isfinite(value):
            self._metrics[name] = self._metrics.get(name, 0.0) + value

    def on_flush(self, thread_id: int, columns: Dict[str, np.ndarray]) -> None:
        state = self._threads.get(thread_id)
        if state is None:
            state = self._threads[thread_id] = _ThreadState()
        kinds = columns["kind"].tolist()
        regions = columns["region"].tolist()
        ts = columns["t"].tolist()
        auxs = columns["aux"].tolist()
        node = state.node
        stack = state.stack
        for i, kind in enumerate(kinds):
            t = ts[i]
            if kind == EV_ENTER or kind == EV_C_ENTER:
                node = node.child(regions[i])
                stack.append([t, 0])
            elif kind == EV_EXIT or kind == EV_C_EXIT:
                if not stack:
                    state.orphan_exits += 1
                    continue
                if node.region != regions[i]:
                    # Defensive: an exit that doesn't match the open region.
                    # If the parent matches, the inner frame lost its exit —
                    # close it implicitly; otherwise count and pop anyway.
                    if (
                        node.parent is not None
                        and node.parent.region == regions[i]
                        and len(stack) >= 2
                    ):
                        enter_t, child_ns = stack.pop()
                        dur = t - enter_t
                        node.visits += 1
                        node.incl_ns += dur
                        node.excl_ns += dur - child_ns
                        node = node.parent
                        stack[-1][1] += dur
                    else:
                        state.mismatched_exits += 1
                enter_t, child_ns = stack.pop()
                dur = t - enter_t
                node.visits += 1
                node.incl_ns += dur
                node.excl_ns += dur - child_ns
                node = node.parent
                if stack:
                    stack[-1][1] += dur
            elif kind == EV_LINE:
                rid = regions[i]
                state.lines[rid] = state.lines.get(rid, 0) + 1
            elif kind == EV_EXCEPTION:
                state.exceptions += 1
            state.last_t = t
        state.node = node

    # -- finalize -----------------------------------------------------------

    def _unwind(self, state: _ThreadState) -> None:
        """Close regions still on the stack at finalize (paper: the program
        is always inside ``__main__`` etc. when measurement stops)."""
        node = state.node
        t = state.last_t
        while state.stack:
            enter_t, child_ns = state.stack.pop()
            dur = t - enter_t
            node.visits += 1
            node.incl_ns += dur
            node.excl_ns += dur - child_ns
            node = node.parent
            if state.stack:
                state.stack[-1][1] += dur
        state.node = node

    def close(self, region_table: List[Dict[str, Any]]) -> None:
        def name_of(rid: int) -> str:
            if rid < 0:
                return "<root>"
            r = region_table[rid]
            return f"{r['module']}:{r['name']}"

        flat: Dict[int, Dict[str, int]] = {}

        def tree_dict(node: _Node) -> Dict[str, Any]:
            if node.region >= 0:
                agg = flat.setdefault(node.region, {"visits": 0, "incl_ns": 0, "excl_ns": 0})
                agg["visits"] += node.visits
                agg["incl_ns"] += node.incl_ns
                agg["excl_ns"] += node.excl_ns
            return {
                "region": node.region,
                "name": name_of(node.region),
                "visits": node.visits,
                "incl_ns": node.incl_ns,
                "excl_ns": node.excl_ns,
                "children": [tree_dict(c) for c in node.children.values()],
            }

        threads_doc = {}
        for tid, state in sorted(self._threads.items()):
            self._unwind(state)
            threads_doc[str(tid)] = {
                "calltree": tree_dict(state.root),
                "orphan_exits": state.orphan_exits,
                "mismatched_exits": state.mismatched_exits,
                "exceptions": state.exceptions,
                "lines_executed": {str(k): v for k, v in state.lines.items()},
            }

        doc = {
            "meta": self._meta,
            "metrics": self._metrics,
            "threads": threads_doc,
            "flat": {
                name_of(rid): vals
                for rid, vals in sorted(flat.items(), key=lambda kv: -kv[1]["excl_ns"])
            },
        }
        with open(os.path.join(self._run_dir, "profile.json"), "w") as fh:
            json.dump(doc, fh, indent=1, allow_nan=False)
        with open(os.path.join(self._run_dir, "profile.txt"), "w") as fh:
            fh.write(render_text(doc))

    # kept for tests / tools
    @property
    def threads(self) -> Dict[int, _ThreadState]:
        return self._threads


def render_text(doc: Dict[str, Any], max_depth: int = 12, top: int = 30) -> str:
    """Pretty text rendering: per-thread call tree + hotspot table."""
    out: List[str] = []
    for tid, tdoc in doc["threads"].items():
        out.append(f"== thread {tid} ==")

        def walk(node, depth):
            if depth > max_depth:
                return
            if node["region"] >= 0:
                out.append(
                    f"{'  ' * depth}{node['name']}  visits={node['visits']} "
                    f"incl={node['incl_ns'] / 1e6:.3f}ms excl={node['excl_ns'] / 1e6:.3f}ms"
                )
            for ch in node["children"]:
                walk(ch, depth + (node["region"] >= 0))

        walk(tdoc["calltree"], 0)
    out.append("")
    out.append("== hotspots (by exclusive time) ==")
    for i, (name, vals) in enumerate(doc["flat"].items()):
        if i >= top:
            break
        out.append(
            f"{vals['excl_ns'] / 1e6:12.3f}ms excl {vals['incl_ns'] / 1e6:12.3f}ms incl "
            f"{vals['visits']:10d}x  {name}"
        )
    if doc.get("metrics"):
        out.append("")
        out.append("== metrics ==")
        for name, val in sorted(doc["metrics"].items()):
            out.append(f"{name} = {val}")
    return "\n".join(out) + "\n"
