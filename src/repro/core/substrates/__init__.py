"""Substrate registry."""

from __future__ import annotations

from typing import Dict, Type

from .base import Substrate
from .metrics import MetricsSubstrate
from .profiling import ProfilingSubstrate
from .tracing import TracingSubstrate

SUBSTRATES: Dict[str, Type[Substrate]] = {
    ProfilingSubstrate.name: ProfilingSubstrate,
    TracingSubstrate.name: TracingSubstrate,
    MetricsSubstrate.name: MetricsSubstrate,
}

#: Substrates registered on first use.  The memory substrate lives in the
#: sibling ``repro.core.memsys`` package, which itself depends on
#: ``substrates.base`` — lazy registration keeps the import graph acyclic.
_LAZY = {"memory": "repro.core.memsys.substrate"}


def make_substrate(name: str, **kwargs) -> Substrate:
    cls = SUBSTRATES.get(name)
    if cls is None and name in _LAZY:
        import importlib

        module = importlib.import_module(_LAZY[name])
        cls = getattr(module, "MemorySubstrate")
        SUBSTRATES[cls.name] = cls
    if cls is None:
        available = sorted(set(SUBSTRATES) | set(_LAZY))
        raise ValueError(f"unknown substrate {name!r}; available: {available}")
    return cls(**kwargs)


__all__ = [
    "Substrate",
    "SUBSTRATES",
    "make_substrate",
    "ProfilingSubstrate",
    "TracingSubstrate",
    "MetricsSubstrate",
]
