"""Substrate registry."""

from __future__ import annotations

from typing import Dict, Type

from .base import Substrate
from .metrics import MetricsSubstrate
from .profiling import ProfilingSubstrate
from .tracing import TracingSubstrate

SUBSTRATES: Dict[str, Type[Substrate]] = {
    ProfilingSubstrate.name: ProfilingSubstrate,
    TracingSubstrate.name: TracingSubstrate,
    MetricsSubstrate.name: MetricsSubstrate,
}


def make_substrate(name: str, **kwargs) -> Substrate:
    try:
        cls = SUBSTRATES[name]
    except KeyError:
        raise ValueError(f"unknown substrate {name!r}; available: {sorted(SUBSTRATES)}") from None
    return cls(**kwargs)


__all__ = [
    "Substrate",
    "SUBSTRATES",
    "make_substrate",
    "ProfilingSubstrate",
    "TracingSubstrate",
    "MetricsSubstrate",
]
