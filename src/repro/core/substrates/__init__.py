"""Substrate registry — the measurement back-ends and their artifacts.

A *substrate* consumes flushed event batches (and user metrics) and writes
one artifact family into the run directory at finalize (the Score-P
analogue: profiling and tracing substrates behind one measurement core).
Registered here:

    ``profiling``  profile.json / profile.txt — call-path profile
    ``tracing``    defs.json + per-thread event streams + trace.json
    ``metrics``    metrics.json — metric aggregates and time series
    ``memory``     memory.json — allocation attribution + RSS/GC timelines
                   (lazily imported from repro.core.memsys)

Select substrates per run via ``MeasurementConfig.substrates``,
``--substrates`` on the CLI, or ``REPRO_MONITOR_SUBSTRATES``.  Every JSON
artifact carries ``report_schema_version`` (see repro.core.schema and
docs/ARTIFACTS.md for the field tables).
"""

from __future__ import annotations

from typing import Dict, Type

from .base import Substrate
from .metrics import MetricsSubstrate
from .profiling import ProfilingSubstrate
from .tracing import TracingSubstrate

SUBSTRATES: Dict[str, Type[Substrate]] = {
    ProfilingSubstrate.name: ProfilingSubstrate,
    TracingSubstrate.name: TracingSubstrate,
    MetricsSubstrate.name: MetricsSubstrate,
}

#: Substrates registered on first use.  The memory substrate lives in the
#: sibling ``repro.core.memsys`` package, which itself depends on
#: ``substrates.base`` — lazy registration keeps the import graph acyclic.
_LAZY = {"memory": "repro.core.memsys.substrate"}


def make_substrate(name: str, **kwargs) -> Substrate:
    """Instantiate a registered substrate by name (kwargs go to the
    constructor, e.g. ``period=``/``topn=`` for ``memory``).  Raises
    ``ValueError`` naming the available substrates on an unknown name."""
    cls = SUBSTRATES.get(name)
    if cls is None and name in _LAZY:
        import importlib

        module = importlib.import_module(_LAZY[name])
        cls = getattr(module, "MemorySubstrate")
        SUBSTRATES[cls.name] = cls
    if cls is None:
        available = sorted(set(SUBSTRATES) | set(_LAZY))
        raise ValueError(f"unknown substrate {name!r}; available: {available}")
    return cls(**kwargs)


__all__ = [
    "Substrate",
    "SUBSTRATES",
    "make_substrate",
    "ProfilingSubstrate",
    "TracingSubstrate",
    "MetricsSubstrate",
]
