"""Substrate plugin API.

Score-P fans measurement events out to "substrates" (profiling, tracing,
plugins for online interpretation).  Substrates here receive *batched*
event flushes as numpy columns — per-event work in the instrumentation fast
path is limited to one buffer append; everything expensive happens at flush
granularity.  (Score-P builds profiles online per event; our deferred design
is a deliberate, measured overhead optimization — EXPERIMENTS.md §Perf.)
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from typing import Any, Dict, List

import numpy as np


class Substrate(ABC):
    """Receives event batches and definition tables; writes artifacts."""

    name: str = "?"

    @abstractmethod
    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        """Called once before any events; ``meta`` holds process/clock info."""

    @abstractmethod
    def on_flush(self, thread_id: int, columns: Dict[str, np.ndarray]) -> None:
        """Receive one flushed batch of events from one thread (in order)."""

    def on_metric(self, name: str, value: float, t_ns: int) -> None:
        """Receive one user metric sample (counters, FLOPs, bytes, ...)."""

    @abstractmethod
    def close(self, region_table: List[Dict[str, Any]]) -> None:
        """Flush artifacts; called once at finalize with the region table."""
