"""Measurement filtering — Score-P filter files, Python edition.

Score-P lets users restrict instrumentation with include/exclude rules so the
event rate (and thus overhead) stays manageable.  Rules here match on the
*module* name (fnmatch globs) and optionally on the function name.  Verdicts
are evaluated once per distinct code object at region-registration time and
cached on the region handle (see ``regions.py``), so filtering adds zero
per-event cost.

Spec grammar (used by ``--filter`` on the CLI and ``REPRO_MONITOR_FILTER``):

    spec      := clause (';' clause)*
    clause    := ('include' | 'exclude') ':' pattern (',' pattern)*
    pattern   := fnmatch glob matched against "module" or "module.function"

Semantics (same as Score-P filter files): exclude rules are applied first;
include rules re-admit matching regions.  With no include rules everything
not excluded is recorded.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import List, Sequence

# Internals that must never instrument themselves.  The CPython hook is not
# re-entered while the callback runs, but regions of the measurement core
# would still pollute profiles via user-API calls, so they are always dropped.
_SELF_MODULES = ("repro.core",)


@dataclass
class Filter:
    include: List[str] = field(default_factory=list)
    exclude: List[str] = field(default_factory=list)

    @classmethod
    def from_spec(cls, spec: str | None) -> "Filter":
        flt = cls()
        if not spec:
            return flt
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if ":" not in clause:
                raise ValueError(f"bad filter clause (missing ':'): {clause!r}")
            verb, _, pats = clause.partition(":")
            verb = verb.strip().lower()
            patterns = [p.strip() for p in pats.split(",") if p.strip()]
            if verb == "include":
                flt.include.extend(patterns)
            elif verb == "exclude":
                flt.exclude.extend(patterns)
            else:
                raise ValueError(f"bad filter verb {verb!r} (want include/exclude)")
        return flt

    def to_spec(self) -> str:
        parts = []
        if self.include:
            parts.append("include:" + ",".join(self.include))
        if self.exclude:
            parts.append("exclude:" + ",".join(self.exclude))
        return ";".join(parts)

    # -- verdicts (cold path: once per distinct region) --------------------

    def decide(self, module: str, name: str, file: str) -> bool:
        """Return True if a region in ``module`` named ``name`` is recorded."""
        for self_mod in _SELF_MODULES:
            if module.startswith(self_mod):
                return False
        # Frameless registration (sys.monitoring) can't see the module name;
        # suppress the measurement core by path as well.
        if "repro/core/" in file or "repro\\core\\" in file:
            return False
        qualified = f"{module}.{name}"
        excluded = any(
            fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.exclude
        )
        if excluded:
            return any(
                fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.include
            )
        if self.include:
            # Include rules alone act as an allow-list.
            return any(
                fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.include
            )
        return True
