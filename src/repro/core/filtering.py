"""Measurement filtering — Score-P filter files, Python edition.

Score-P lets users restrict instrumentation with include/exclude rules so the
event rate (and thus overhead) stays manageable.  Rules here match on the
*module* name (fnmatch globs) and optionally on the function name.  Verdicts
are evaluated once per distinct code object at region-registration time and
cached on the region handle (see ``regions.py``), so filtering adds zero
per-event cost.

Spec grammar (used by ``--filter`` on the CLI and ``REPRO_MONITOR_FILTER``):

    spec      := clause (';' clause)*
    clause    := ('include' | 'exclude' | 'exclude!') ':' pattern (',' pattern)*
    pattern   := fnmatch glob matched against "module" or "module.function"

Semantics (same as Score-P filter files), by rule combination:

    no rules               everything is recorded
    exclude only           everything not excluded is recorded
    exclude + include      exclude applies first; include re-admits matching
                           regions; everything not excluded is recorded
    include only           allow-list: only matching regions are recorded

Note the asymmetry: include rules act as a global allow-list *only when no
exclude rules exist*.  In a mixed spec they merely re-admit from the
excluded set — a region matching neither rule kind is still recorded.

``exclude!`` rules are *absolute* excludes (the overhead governor's
runtime exclusions, serialized): they win over include re-admission and
do not participate in the allow-list/mixed determination above, so adding
them to any spec only ever removes regions — an include-only spec stays
an allow-list.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import List, Sequence

# Internals that must never instrument themselves.  The CPython hook is not
# re-entered while the callback runs, but regions of the measurement core
# would still pollute profiles via user-API calls, so they are always dropped.
_SELF_MODULES = ("repro.core",)


@dataclass
class Filter:
    include: List[str] = field(default_factory=list)
    exclude: List[str] = field(default_factory=list)
    #: Excludes added at runtime (overhead governor).  Kept separate from the
    #: spec's exclude rules for two reasons: they take precedence over include
    #: re-admission (a region the governor dropped for cost must stay
    #: dropped), and they must not flip an include-only spec out of its
    #: allow-list semantics for regions seen later.
    runtime_exclude: List[str] = field(default_factory=list)

    @classmethod
    def from_spec(cls, spec: str | None) -> "Filter":
        """Parse a filter spec string into a :class:`Filter`.

        The grammar (also accepted by ``--filter`` and
        ``REPRO_MONITOR_FILTER``) is clauses separated by ``;``, each
        ``include:``/``exclude:``/``exclude!:`` followed by comma-separated
        fnmatch globs matched against ``module`` or ``module.function``::

            exclude:numpy.*,scipy.*;include:numpy.linalg.*
            include:mypkg.*                  # allow-list
            exclude!:hot.leaf                # absolute (governor) exclude

        Empty/None specs yield a record-everything filter.  Round-trips
        with :meth:`to_spec` (clause order normalized, semantics exact).
        Raises ``ValueError`` on an unknown verb or a clause without
        ``:``."""
        flt = cls()
        if not spec:
            return flt
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if ":" not in clause:
                raise ValueError(f"bad filter clause (missing ':'): {clause!r}")
            verb, _, pats = clause.partition(":")
            verb = verb.strip().lower()
            patterns = [p.strip() for p in pats.split(",") if p.strip()]
            if verb == "include":
                flt.include.extend(patterns)
            elif verb == "exclude":
                flt.exclude.extend(patterns)
            elif verb == "exclude!":
                flt.runtime_exclude.extend(patterns)
            else:
                raise ValueError(
                    f"bad filter verb {verb!r} (want include/exclude/exclude!)"
                )
        return flt

    def to_spec(self) -> str:
        # Runtime excludes keep their own verb so the round-trip is exact:
        # folding them into the exclude clause would both let include rules
        # re-admit them and flip an include-only spec out of its allow-list
        # semantics.
        parts = []
        if self.include:
            parts.append("include:" + ",".join(self.include))
        if self.exclude:
            parts.append("exclude:" + ",".join(self.exclude))
        if self.runtime_exclude:
            parts.append("exclude!:" + ",".join(self.runtime_exclude))
        return ";".join(parts)

    # -- verdicts (cold path: once per distinct region) --------------------

    def decide(self, module: str, name: str, file: str) -> bool:
        """Return True if a region in ``module`` named ``name`` is recorded."""
        for self_mod in _SELF_MODULES:
            if module.startswith(self_mod):
                return False
        # Frameless registration (sys.monitoring) can't see the module name;
        # suppress the measurement core by path as well.
        if "repro/core/" in file or "repro\\core\\" in file:
            return False
        qualified = f"{module}.{name}"
        if any(
            fnmatchcase(module, pat) or fnmatchcase(qualified, pat)
            for pat in self.runtime_exclude
        ):
            # Governor excludes are absolute: no include re-admission.
            return False
        excluded = any(
            fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.exclude
        )
        if excluded:
            # Include rules re-admit from the excluded set.
            return any(
                fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.include
            )
        if self.include and not self.exclude:
            # Include rules *alone* act as an allow-list.  With exclude rules
            # present they only re-admit (Score-P semantics: everything not
            # excluded is recorded).
            return any(
                fnmatchcase(module, pat) or fnmatchcase(qualified, pat) for pat in self.include
            )
        return True

    # -- runtime tightening (used by the overhead governor) ----------------

    def add_runtime_excludes(self, patterns: Sequence[str]) -> List[str]:
        """Append runtime exclude patterns; returns the ones actually added.

        Only ever *tightens* the filter, so verdicts cached on region handles
        stay valid for still-recorded regions; callers must re-evaluate the
        rest via ``RegionRegistry.refilter``.
        """
        added = []
        for pat in patterns:
            if pat and pat not in self.runtime_exclude:
                self.runtime_exclude.append(pat)
                added.append(pat)
        return added
