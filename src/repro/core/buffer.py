"""Per-thread event buffers — the "C-bindings" analogue.

Score-P's C bindings exist to make the per-event path as cheap as possible.
In a pure-CPython environment the equivalent engineering decision is *which
append primitive is cheapest*.  Two strategies are provided and benchmarked
(``benchmarks/event_throughput.py``); the list strategy wins on CPython
(``list.append`` is a single C call) and is the default.

Event record: ``(kind, region, t_ns, aux)``
  kind   u1   see ``EV_*`` constants
  region i4   region handle (``regions.FILTERED`` events are never appended)
  t_ns   u8   ``time.perf_counter_ns()``
  aux    u4   line number for LINE events, else 0

Buffers flush to the measurement manager (which fans out to substrates) when
``flush_threshold`` records accumulate, keeping memory bounded.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

# Event kinds.
EV_ENTER = 0
EV_EXIT = 1
EV_C_ENTER = 2
EV_C_EXIT = 3
EV_LINE = 4
EV_EXCEPTION = 5

EVENT_KIND_NAMES = {
    EV_ENTER: "enter",
    EV_EXIT: "exit",
    EV_C_ENTER: "c_enter",
    EV_C_EXIT: "c_exit",
    EV_LINE: "line",
    EV_EXCEPTION: "exception",
}

EventTuple = Tuple[int, int, int, int]

#: Column dtypes of a flushed batch.
COLUMNS = (("kind", np.uint8), ("region", np.int32), ("t", np.uint64), ("aux", np.uint32))


def columns_from_events(events: List[EventTuple]) -> Dict[str, np.ndarray]:
    """Convert a list of event tuples into named numpy columns."""
    if not events:
        return {name: np.empty(0, dtype=dt) for name, dt in COLUMNS}
    arr = np.asarray(events, dtype=np.uint64)
    return {
        "kind": arr[:, 0].astype(np.uint8),
        "region": arr[:, 1].astype(np.int64).astype(np.int32),
        "t": arr[:, 2],
        "aux": arr[:, 3].astype(np.uint32),
    }


class ListEventBuffer:
    """Default buffer: plain Python list of tuples (fastest append on CPython).

    Instrumenters bind ``self.events.append`` as a closure local; this class
    only manages flushing.
    """

    strategy = "list"

    def __init__(
        self,
        thread_id: int,
        flush_threshold: int = 1 << 16,
        on_flush: Optional[Callable[[int, Dict[str, np.ndarray]], None]] = None,
    ):
        self.thread_id = thread_id
        self.flush_threshold = flush_threshold
        self.on_flush = on_flush
        self.events: List[EventTuple] = []
        self.n_flushed = 0
        self._flushing = False

    def __len__(self) -> int:
        return len(self.events)

    def flush(self) -> None:
        # Identity of ``self.events`` must be preserved (instrumenter
        # closures bind ``events.append``), hence copy + in-place clear.
        # The _flushing guard stops recursion when flush work itself emits
        # events (flush can run in user context via region __exit__).
        if self._flushing or not self.events:
            return
        self._flushing = True
        try:
            batch = self.events[:]
            self.events.clear()
            self.n_flushed += len(batch)
            if self.on_flush is not None:
                self.on_flush(self.thread_id, columns_from_events(batch))
        finally:
            self._flushing = False


class NumpyEventBuffer:
    """Preallocated column-array buffer (Score-P-style fixed memory).

    Slower per event on CPython than :class:`ListEventBuffer` (four element
    stores vs one ``list.append``) but allocation-free in steady state; kept
    for the measured comparison in EXPERIMENTS.md §Perf.
    """

    strategy = "numpy"

    #: Hard ceiling on growth, as a multiple of flush_threshold.  Events
    #: past it are dropped (counted in ``n_dropped``) rather than letting a
    #: flush-callback feedback loop grow the buffer without bound.
    MAX_GROWTH = 8

    def __init__(
        self,
        thread_id: int,
        flush_threshold: int = 1 << 16,
        on_flush: Optional[Callable[[int, Dict[str, np.ndarray]], None]] = None,
    ):
        self.thread_id = thread_id
        self.flush_threshold = flush_threshold
        self.on_flush = on_flush
        n = flush_threshold
        self._kind = np.empty(n, dtype=np.uint8)
        self._region = np.empty(n, dtype=np.int32)
        self._t = np.empty(n, dtype=np.uint64)
        self._aux = np.empty(n, dtype=np.uint32)
        self.cursor = 0
        self.n_flushed = 0
        self.n_dropped = 0
        self._flushing = False

    def __len__(self) -> int:
        return self.cursor

    @property
    def capacity(self) -> int:
        return self._kind.shape[0]

    def _grow(self) -> bool:
        """Double the column arrays in place; False once MAX_GROWTH is hit."""
        cap = self.capacity
        if cap >= self.flush_threshold * self.MAX_GROWTH:
            return False
        new_cap = min(cap * 2, self.flush_threshold * self.MAX_GROWTH)
        for name in ("_kind", "_region", "_t", "_aux"):
            old = getattr(self, name)
            arr = np.empty(new_cap, dtype=old.dtype)
            arr[:cap] = old
            setattr(self, name, arr)
        return True

    def append(self, kind: int, region: int, t: int, aux: int) -> None:
        i = self.cursor
        if i >= self.capacity:
            # Appends can outrun the preallocated columns when a flush is in
            # progress (the re-entrancy guard makes the threshold-triggered
            # flush a no-op, so the cursor keeps climbing): grow, or drop
            # once the growth ceiling is reached — never IndexError.
            if not self._grow():
                self.n_dropped += 1
                return
        self._kind[i] = kind
        self._region[i] = region
        self._t[i] = t
        self._aux[i] = aux
        self.cursor = i + 1
        if self.cursor >= self.flush_threshold:
            self.flush()

    def flush(self) -> None:
        n = self.cursor
        if self._flushing or n == 0:
            return
        self._flushing = True
        try:
            # Copy before resetting the cursor so events emitted during
            # on_flush (user-context flushes) don't clobber the batch.
            batch = {
                "kind": self._kind[:n].copy(),
                "region": self._region[:n].copy(),
                "t": self._t[:n].copy(),
                "aux": self._aux[:n].copy(),
            }
            self.cursor = 0
            self.n_flushed += n
            if self.on_flush is not None:
                self.on_flush(self.thread_id, batch)
        finally:
            self._flushing = False


BUFFER_STRATEGIES = {
    "list": ListEventBuffer,
    "numpy": NumpyEventBuffer,
}
