"""The CI perf gate — the repo's own perf trajectory as a monitored fleet.

A *trajectory* is a directory of snapshots, one per CI run / PR, each
snapshot a copy of the benchmark artifacts (``benchmarks/artifacts/*.json``)
produced by that run::

    .perf-trajectory/
        00000-a1b2c3d4/   governed_overhead.json  memory_overhead.json ...
        00001-e5f6a7b8/   ...

Every numeric scalar leaf of every artifact becomes a metric series across
snapshots (``governed_overhead.beta_us.governed``, ...).  Metrics whose
name reveals a *worse direction* (``beta``/``dilation``/``overhead``/
``.._ns``/``drop``/... -> higher is worse; ``..per_s``/``throughput`` ->
lower is worse) are gated with the same effect-size machinery as the run
analyzer; everything else (configuration echoes, counts) is left
unwatched.  The newest snapshot is the candidate window — usually a single
run, so the comparison takes :func:`compare_windows`'s robust MAD-outlier
path rather than pretending one sample has a distribution.

Exit-code contract (via ``analysis fleet gate``): 0 = no confirmed
regression (including the seeding phase while the baseline is shorter than
``min_baseline``), 1 = confirmed regression, 2 = missing/corrupt inputs.
"""

from __future__ import annotations

import glob
import json
import math
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

from ..schema import MissingArtifact, stamp
from .stats import EFFECT_MEDIUM, compare_windows

#: Snapshots needed before the gate starts judging; until then every run
#: seeds the baseline and passes.
MIN_BASELINE = 4

#: Gate-mode relative-change floor — CI timing noise is larger than a
#: controlled population's, so the gate asks for a bigger median move.
GATE_MIN_REL = 0.10

_LOWER_WORSE = ("per_s", "throughput", "records_per", "events_per")
_HIGHER_WORSE = (
    "beta", "dilation", "overhead", "fraction", "drop", "pause", "lag",
    "publish", "_ns", "_us", "_ms",
)

_SNAP_RE = re.compile(r"^(\d{5})(?:-(.+))?$")


def metric_direction(name: str) -> int:
    """+1 = higher is worse, -1 = lower is worse, 0 = unwatched.

    Matched on the lowercase dotted metric name; lower-is-worse patterns
    win first so ``records_per_s`` is throughput, not a ``.._s`` timing.
    """
    low = name.lower()
    leaf = low.rsplit(".", 1)[-1]
    if any(p in low for p in _LOWER_WORSE):
        return -1
    if any(p in low for p in _HIGHER_WORSE) or leaf.endswith("_s"):
        return 1
    return 0


def flatten_metrics(stem: str, doc: Any, prefix: str = "") -> Dict[str, float]:
    """Numeric scalar leaves of ``doc`` as ``{stem.dotted.path: value}``.

    Lists (config arrays, per-size medians) and bools are skipped; only
    int/float leaves become trajectory metrics."""
    out: Dict[str, float] = {}
    if isinstance(doc, dict):
        for key, value in doc.items():
            if key == "report_schema_version":
                continue
            path = f"{prefix}.{key}" if prefix else f"{stem}.{key}"
            out.update(flatten_metrics(stem, value, prefix=path))
    elif isinstance(doc, bool):
        pass
    elif isinstance(doc, (int, float)) and prefix:
        if math.isfinite(doc):
            out[prefix] = float(doc)
    return out


def _snapshot_key(name: str) -> Optional[Tuple[int, str]]:
    m = _SNAP_RE.match(name)
    if m is None:
        return None
    return int(m.group(1)), name


def load_trajectory(traj_dir: str) -> List[Dict[str, Any]]:
    """The trajectory's snapshots, oldest first: ``[{"name", "metrics"}]``.

    Raises :class:`MissingArtifact` when the directory does not exist or a
    snapshot artifact is corrupt JSON (a truncated upload must fail the
    gate loudly with exit 2, not silently shrink the baseline)."""
    if not os.path.isdir(traj_dir):
        raise MissingArtifact(
            f"no trajectory directory at {traj_dir or '.'} — create it (or "
            f"pass --append to seed the first snapshot)"
        )
    snaps: List[Tuple[int, str]] = []
    for entry in sorted(os.listdir(traj_dir)):
        key = _snapshot_key(entry)
        if key is not None and os.path.isdir(os.path.join(traj_dir, entry)):
            snaps.append(key)
    snaps.sort()
    out = []
    for _, name in snaps:
        metrics: Dict[str, float] = {}
        for path in sorted(glob.glob(os.path.join(traj_dir, name, "*.json"))):
            stem = os.path.splitext(os.path.basename(path))[0]
            try:
                with open(path) as fh:
                    doc = json.load(fh)
            except (OSError, ValueError) as exc:
                raise MissingArtifact(
                    f"corrupt trajectory artifact {path}: {exc}"
                ) from exc
            metrics.update(flatten_metrics(stem, doc))
        out.append({"name": name, "metrics": metrics})
    return out


def append_snapshot(traj_dir: str, src_dir: str, label: Optional[str] = None) -> str:
    """Copy ``src_dir``'s ``*.json`` artifacts into the next snapshot slot
    (``NNNNN[-label]``) and return the snapshot name.  Raises
    :class:`MissingArtifact` when the source has no artifacts."""
    paths = sorted(glob.glob(os.path.join(src_dir, "*.json")))
    if not paths:
        raise MissingArtifact(
            f"no *.json benchmark artifacts in {src_dir or '.'} — run the "
            f"benchmarks/*.py --smoke set first"
        )
    os.makedirs(traj_dir, exist_ok=True)
    indices = [
        key[0]
        for entry in os.listdir(traj_dir)
        if (key := _snapshot_key(entry)) is not None
    ]
    nxt = (max(indices) + 1) if indices else 0
    safe_label = re.sub(r"[^A-Za-z0-9_.-]", "-", label) if label else None
    name = f"{nxt:05d}" + (f"-{safe_label}" if safe_label else "")
    dst = os.path.join(traj_dir, name)
    os.makedirs(dst, exist_ok=True)
    for path in paths:
        shutil.copy(path, os.path.join(dst, os.path.basename(path)))
    return name


def gate_summary(
    traj_dir: str,
    candidate: int = 1,
    min_baseline: int = MIN_BASELINE,
    alpha: float = 0.05,
    min_effect: float = EFFECT_MEDIUM,
    min_rel: float = GATE_MIN_REL,
) -> Dict[str, Any]:
    """Judge the newest ``candidate`` snapshots against the rest.

    Returns the gate-mode fleet summary document (schema-stamped,
    deterministic for a given trajectory).  ``verdict``: ``seeding`` while
    the baseline is shorter than ``min_baseline``, else ``regressed`` /
    ``ok``."""
    snaps = load_trajectory(traj_dir)
    if not snaps:
        raise MissingArtifact(
            f"trajectory {traj_dir} has no snapshots — append one with "
            f"--append DIR"
        )
    c = max(1, min(candidate, len(snaps) - 1)) if len(snaps) > 1 else 0
    base_snaps = snaps[: len(snaps) - c]
    cand_snaps = snaps[len(snaps) - c:]
    seeding = len(base_snaps) < min_baseline
    names = sorted({m for s in snaps for m in s["metrics"]})
    findings: List[Dict[str, Any]] = []
    watched = unwatched = 0
    for name in names:
        direction = metric_direction(name)
        if direction == 0:
            unwatched += 1
            continue
        base = [s["metrics"][name] for s in base_snaps if name in s["metrics"]]
        cand = [s["metrics"][name] for s in cand_snaps if name in s["metrics"]]
        # A metric must exist in most of the baseline and in the candidate
        # to be judged (benchmarks come and go across PRs).
        if not cand or len(base) < max(min_baseline, (len(base_snaps) + 1) // 2):
            continue
        watched += 1
        if seeding:
            continue
        verdict = compare_windows(
            base,
            cand,
            higher_is_worse=direction > 0,
            alpha=alpha,
            min_effect=min_effect,
            min_rel=min_rel,
        )
        if verdict["verdict"] in ("regression", "improvement"):
            findings.append(dict(verdict, metric=name, direction=direction))
    findings.sort(
        key=lambda f: (
            f["verdict"] != "regression",
            -abs(f.get("mad_z") or 0.0),
            -abs(f["effect_size"]),
            f["metric"],
        )
    )
    regressions = sum(1 for f in findings if f["verdict"] == "regression")
    doc = stamp(
        {
            "kind": "fleet",
            "mode": "gate",
            "trajectory": traj_dir,
            "snapshots": [s["name"] for s in snaps],
            "windows": {
                "baseline_n": len(base_snaps),
                "candidate_n": len(cand_snaps),
                "min_baseline": min_baseline,
            },
            "params": {
                "alpha": alpha,
                "min_effect": min_effect,
                "min_rel": min_rel,
                "candidate": candidate,
            },
            "metrics_watched": watched,
            "metrics_unwatched": unwatched,
            "findings": findings,
            "findings_total": regressions,
            "series": {
                f["metric"]: [
                    s["metrics"].get(f["metric"]) for s in snaps
                ]
                for f in findings
            },
            "verdict": "seeding" if seeding else ("regressed" if regressions else "ok"),
        }
    )
    return doc
