"""Run-population ingestion for the fleet analyzer.

A "population" is N run directories of the same workload over time — CI
runs, cron'd smoke runs, canary deployments.  Discovery reuses the merge
layer's :func:`repro.core.merge.find_runs` (with ``meta.json`` as the
marker so profile-only runs, which never write ``defs.json``, are still
found) and its dedup semantics: exact duplicates — same experiment, rank
and clock epoch, i.e. the same launch copied into the root twice — keep
one deterministic survivor and report the rest as dropped, mirroring
``merge_runs``'s newest-epoch-wins rank dedup.

Per run, only the population-level statistics are kept resident (exclusive
ns / visits per region, allocation columns per region, whole-process
heap/RSS timeline slopes) — ingesting thousands of runs holds a few
hundred bytes per run per region, never the event streams.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..schema import MissingArtifact
from .stats import slope_per_second


@dataclass
class RunStat:
    """One population member, reduced to its per-region statistics."""

    run_dir: str
    experiment: str = ""
    rank: int = 0
    epoch_time_ns: int = 0
    #: region -> exclusive ns / visits (profile.json flat table)
    excl_ns: Dict[str, int] = field(default_factory=dict)
    visits: Dict[str, int] = field(default_factory=dict)
    kinds: Dict[str, str] = field(default_factory=dict)
    #: region -> allocation columns (memory.json heap.regions)
    alloc_bytes: Dict[str, int] = field(default_factory=dict)
    freed_bytes: Dict[str, int] = field(default_factory=dict)
    net_bytes: Dict[str, int] = field(default_factory=dict)
    #: whole-process memsys signals (0.0 / None when memsys was off)
    heap_slope_bytes_s: float = 0.0
    rss_slope_bytes_s: float = 0.0
    rss_peak_bytes: int = 0
    heap_end_bytes: int = 0
    has_profile: bool = False
    has_memory: bool = False

    def label(self) -> str:
        return os.path.basename(self.run_dir.rstrip(os.sep)) or self.run_dir


def load_run(run_dir: str) -> Optional[RunStat]:
    """Reduce one run dir to a :class:`RunStat` (``None`` when it has
    neither a readable profile.json nor memory.json — not a run)."""
    # Local imports: analysis/memsys are the stable artifact seams.
    from ..analysis import _load_artifact
    from ..memsys import load_memory, overview, reclaim_rows, timelines

    stat = RunStat(run_dir=run_dir)
    try:
        profile = _load_artifact(run_dir, "profile.json", "profiling")
    except MissingArtifact:
        profile = None
    if profile is not None:
        stat.has_profile = True
        for name, vals in profile.get("flat", {}).items():
            stat.excl_ns[name] = int(vals.get("excl_ns", 0))
            stat.visits[name] = int(vals.get("visits", 0))
            kind = vals.get("kind")
            if kind:
                stat.kinds[name] = str(kind)
        meta = profile.get("meta") or {}
    else:
        meta = {}
    memory = load_memory(run_dir)
    if memory is not None:
        stat.has_memory = True
        for row in reclaim_rows(memory):
            stat.alloc_bytes[row["region"]] = row["alloc_bytes"]
            stat.freed_bytes[row["region"]] = row["freed_bytes"]
            stat.net_bytes[row["region"]] = row["net_bytes"]
        ov = overview(memory)
        stat.rss_peak_bytes = ov["rss_peak_bytes"]
        stat.heap_end_bytes = ov["heap_end_bytes"]
        series = timelines(memory)
        # The series store MB (for Perfetto counter tracks); slopes are
        # reported in bytes/s, the leak literature's unit.
        stat.heap_slope_bytes_s = slope_per_second(series.get("mem.heap_mb", [])) * 1e6
        stat.rss_slope_bytes_s = slope_per_second(series.get("mem.rss_mb", [])) * 1e6
        meta = meta or (memory.get("meta") or {})
    if profile is None and memory is None:
        return None
    # meta.json is authoritative when present (always written); profile /
    # memory carry an embedded copy as fallback for partial run dirs.
    from ..report.model import _load_json

    meta = _load_json(run_dir, "meta.json") or meta
    topo = meta.get("topology") or {}
    stat.rank = int(topo.get("rank", meta.get("rank", 0)) or 0)
    stat.experiment = str(meta.get("experiment") or "")
    stat.epoch_time_ns = int(meta.get("epoch_time_ns", 0) or 0)
    return stat


def discover(roots: Sequence[str], experiment: Optional[str] = None) -> List[str]:
    """Candidate run dirs under ``roots``: every root that is itself a run
    dir plus every run found by the merge layer's discovery (``meta.json``
    marker).  Raises :class:`MissingArtifact` for a nonexistent root."""
    from ..merge import find_runs

    dirs: List[str] = []
    for root in roots:
        if not os.path.isdir(root):
            raise MissingArtifact(
                f"no such run population root: {root or '.'} — pass run "
                f"directories or a directory containing them"
            )
        if os.path.exists(os.path.join(root, "meta.json")):
            dirs.append(root)
        dirs.extend(find_runs(root, experiment=experiment, marker="meta.json"))
    # De-dup paths while keeping them sorted for deterministic ingestion.
    return sorted(set(os.path.normpath(d) for d in dirs))


def ingest(
    roots: Sequence[str], experiment: Optional[str] = None
) -> Tuple[List[RunStat], List[Dict[str, Any]]]:
    """Load every run under ``roots`` into the population.

    Returns ``(runs, dropped)`` with ``runs`` ordered by clock epoch (ties
    broken by path, so ingestion order never changes the result) and
    ``dropped`` the exact-duplicate run dirs removed by dedup.  Raises
    :class:`MissingArtifact` when no usable run is found at all.
    """
    stats: List[RunStat] = []
    for d in discover(roots, experiment=experiment):
        stat = load_run(d)
        if stat is not None:
            stats.append(stat)
    if not stats:
        raise MissingArtifact(
            f"no runs with profile.json or memory.json under "
            f"{', '.join(roots) or '.'} — is this a run population root?"
        )
    stats.sort(key=lambda s: (s.epoch_time_ns, s.label(), s.run_dir))
    survivors: Dict[Tuple[str, int, int], RunStat] = {}
    dropped: List[Dict[str, Any]] = []
    for stat in stats:
        key = (stat.experiment, stat.rank, stat.epoch_time_ns)
        cur = survivors.get(key)
        if cur is None:
            survivors[key] = stat
        else:
            # Same launch present twice: the lexically-first path (already
            # in ``survivors`` thanks to the sort) wins deterministically.
            dropped.append(
                {"run_dir": stat.run_dir, "duplicate_of": cur.run_dir}
            )
    runs = sorted(
        survivors.values(), key=lambda s: (s.epoch_time_ns, s.label(), s.run_dir)
    )
    return runs, dropped
