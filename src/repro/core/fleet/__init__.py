"""repro.core.fleet — fleet-scale regression service over run populations.

PR 5's ``--diff`` answers "did run B regress vs run A?"; at production
scale the question is "did the *population* shift?".  This package ingests
N run directories (CI runs, canaries, cron'd smokes — discovery and dedup
shared with the merge layer), maintains per-region exclusive-time and
allocation distributions across runs, and turns them into verdicts:

* **Regressions by effect size** — baseline-window vs candidate-window
  Mann-Whitney + Cliff's delta per region (:mod:`.regress`, kernel in
  :mod:`.stats`), never raw thresholds.
* **Leaks** — allocation-velocity + reclaim-rate tests per region and
  whole-process timeline-slope tests (:mod:`.leaks`), the scalene
  leak-analysis shape over memsys artifacts.
* **The CI perf gate** (:mod:`.gate`) — the same machinery pointed at the
  repo's own ``benchmarks/artifacts/*.json`` trajectory, so every PR is a
  candidate window against the project's history.

Everything lands in a schema-stamped ``fleet_summary.json`` whose bytes
are deterministic: ingestion order, wall-clock time, and dict ordering
never change the artifact (the determinism tests diff raw bytes).

CLI: ``python -m repro.core.analysis fleet [analyze|gate|show] ...``.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence

from ..schema import MissingArtifact, stamp
from .gate import append_snapshot, gate_summary, load_trajectory, metric_direction
from .ingest import RunStat, ingest, load_run
from .leaks import leak_section
from .regress import default_candidate, region_findings, sparkline_series, split_windows
from .stats import (
    EFFECT_LARGE,
    EFFECT_MEDIUM,
    EFFECT_SMALL,
    cliffs_delta,
    compare_windows,
    mann_whitney,
    sign_test_p,
)

__all__ = [
    "ARTIFACT",
    "EFFECT_LARGE",
    "EFFECT_MEDIUM",
    "EFFECT_SMALL",
    "RunStat",
    "append_snapshot",
    "build_fleet_summary",
    "cliffs_delta",
    "compare_windows",
    "gate_summary",
    "ingest",
    "load_fleet_summary",
    "load_run",
    "load_trajectory",
    "mann_whitney",
    "metric_direction",
    "render_fleet_summary",
    "save_fleet_summary",
    "sign_test_p",
    "smoke",
]

ARTIFACT = "fleet_summary.json"

#: Noise floor for the time pass: regions whose median exclusive time sits
#: below this in both windows are not fleet events even when significant.
MIN_ABS_NS = 100_000

#: Same for the allocation pass (bytes).
MIN_ABS_BYTES = 16_384


def build_fleet_summary(
    roots: Sequence[str],
    experiment: Optional[str] = None,
    candidate: int = 0,
    alpha: float = 0.05,
    min_effect: float = EFFECT_MEDIUM,
    min_rel: float = 0.05,
    top: int = 25,
) -> Dict[str, Any]:
    """Analyze the run population under ``roots`` into the fleet summary
    document (runs mode).

    ``candidate`` is the candidate-window size in runs (newest first);
    ``<= 0`` picks a third of the population (clamped to [1, 8]).  Raises
    :class:`repro.core.schema.MissingArtifact` when no runs are found.
    """
    runs, dropped = ingest(roots, experiment=experiment)
    baseline, cand_runs = split_windows(runs, candidate=candidate)
    time_section = region_findings(
        baseline, cand_runs, column="excl_ns", metric="excl_ns",
        alpha=alpha, min_effect=min_effect, min_rel=min_rel, min_abs=MIN_ABS_NS,
    )
    alloc_section = region_findings(
        baseline, cand_runs, column="alloc_bytes", metric="alloc_bytes",
        alpha=alpha, min_effect=min_effect, min_rel=min_rel, min_abs=MIN_ABS_BYTES,
    )
    leaks = leak_section(runs, alpha=alpha, top=top)
    regressions = sum(
        1 for section in (time_section, alloc_section)
        for f in section["findings"] if f["verdict"] == "regression"
    )
    leak_count = leaks["region_leaks"] + sum(
        1 for sig in leaks["process"].values() if sig["verdict"] == "leak"
    )
    verdict = "+".join(
        part
        for part, hit in (("regressed", regressions), ("leaking", leak_count))
        if hit
    ) or "ok"
    doc = stamp(
        {
            "kind": "fleet",
            "mode": "runs",
            "roots": sorted(os.path.normpath(r) for r in roots),
            "experiment": experiment,
            "runs": [
                {
                    "run_dir": r.run_dir,
                    "label": r.label(),
                    "experiment": r.experiment,
                    "rank": r.rank,
                    "epoch_time_ns": r.epoch_time_ns,
                    "has_profile": r.has_profile,
                    "has_memory": r.has_memory,
                }
                for r in runs
            ],
            "dropped_runs": dropped,
            "windows": {
                "baseline_n": len(baseline),
                "candidate_n": len(cand_runs),
                "policy": "newest-N-candidate",
            },
            "params": {
                "alpha": alpha,
                "min_effect": min_effect,
                "min_rel": min_rel,
                "candidate": candidate if candidate > 0 else default_candidate(len(runs)),
            },
            "time": time_section,
            "alloc": alloc_section,
            "leaks": leaks,
            "series": {
                "time": sparkline_series(runs, time_section["findings"], column="excl_ns"),
                "alloc": sparkline_series(runs, alloc_section["findings"], column="alloc_bytes"),
                "process": {
                    "heap_slope_bytes_s": [r.heap_slope_bytes_s for r in runs],
                    "rss_peak_bytes": [float(r.rss_peak_bytes) for r in runs],
                },
            },
            "findings_total": regressions + leak_count,
            "verdict": verdict,
        }
    )
    return doc


def save_fleet_summary(doc: Dict[str, Any], path: str) -> str:
    """Write the summary to ``path`` (directories resolve to
    :data:`ARTIFACT` inside) byte-deterministically and return the path."""
    if os.path.isdir(path) or path.endswith(os.sep):
        os.makedirs(path, exist_ok=True)
        path = os.path.join(path, ARTIFACT)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def load_fleet_summary(path: str) -> Dict[str, Any]:
    """Read a fleet summary; ``path`` may be the JSON or a directory
    containing :data:`ARTIFACT`.  Raises :class:`MissingArtifact` (-> CLI
    exit 2) when absent or unreadable."""
    if os.path.isdir(path):
        path = os.path.join(path, ARTIFACT)
    if not os.path.exists(path):
        raise MissingArtifact(
            f"no fleet summary at {path or '.'} — run "
            f"`python -m repro.core.analysis fleet ROOT --out ...` first"
        )
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as exc:
        raise MissingArtifact(f"unreadable fleet summary {path}: {exc}") from exc


def _fmt_value(metric: str, value: float) -> str:
    if metric == "excl_ns":
        return f"{value / 1e6:.3f} ms"
    if metric == "alloc_bytes":
        return f"{value / 1e6:.2f} MB"
    return f"{value:,.4g}"


def _finding_lines(findings: List[Dict[str, Any]], top: int) -> List[str]:
    out = []
    for f in findings[:top]:
        rel = f.get("rel_change")
        p = f.get("p")
        name = f.get("region") or f.get("metric")
        out.append(
            f"  {f['verdict'].upper():11s} {name}: "
            f"{_fmt_value(f.get('metric', ''), f['baseline']['median'])} -> "
            f"{_fmt_value(f.get('metric', ''), f['candidate']['median'])} "
            + (f"({rel:+.1%}) " if rel is not None else "(new) ")
            + f"effect {f['effect_size']:+.2f} ({f['effect']})"
            + (f", p={p:.2g}" if p is not None else f", mad_z={f.get('mad_z', 0.0):+.1f}")
            + f", confidence {f['confidence']} [{f['method']}]"
        )
    if len(findings) > top:
        out.append(f"  (+{len(findings) - top} more)")
    return out


def render_fleet_summary(doc: Dict[str, Any], top: int = 10) -> str:
    """Human-readable fleet report for both modes (runs / gate)."""
    out: List[str] = []
    if doc.get("mode") == "gate":
        w = doc.get("windows", {})
        out.append(
            f"perf gate over {len(doc.get('snapshots', []))} snapshot(s) "
            f"({w.get('baseline_n', 0)} baseline / {w.get('candidate_n', 0)} candidate), "
            f"{doc.get('metrics_watched', 0)} watched metric(s), "
            f"{doc.get('metrics_unwatched', 0)} unwatched"
        )
        findings = doc.get("findings", [])
        if findings:
            out.append("findings:")
            out.extend(_finding_lines(findings, top))
        out.append(f"verdict: {doc.get('verdict', '?')}")
        return "\n".join(out)
    w = doc.get("windows", {})
    out.append(
        f"fleet of {len(doc.get('runs', []))} run(s) "
        f"({w.get('baseline_n', 0)} baseline / {w.get('candidate_n', 0)} candidate)"
        + (f", {len(doc['dropped_runs'])} duplicate(s) dropped" if doc.get("dropped_runs") else "")
    )
    for title, key in (("time (excl_ns)", "time"), ("alloc (bytes)", "alloc")):
        section = doc.get(key) or {}
        findings = section.get("findings", [])
        out.append(
            f"{title}: {len(findings)} finding(s) over "
            f"{section.get('checked_regions', 0)} region(s)"
        )
        out.extend(_finding_lines(findings, top))
    leaks = doc.get("leaks") or {}
    out.append(
        f"leaks: {leaks.get('region_leaks', 0)} region verdict(s) over "
        f"{leaks.get('checked_regions', 0)} region(s)"
    )
    for row in leaks.get("regions", []):
        if row["verdict"] != "leak":
            continue
        out.append(
            f"  LEAK        {row['region']}: "
            f"{row['alloc_velocity_bytes'] / 1e6:.2f} MB/run allocated, "
            f"reclaim rate {row['reclaim_rate']:.1%}, net "
            f"{row['net_median_bytes'] / 1e6:+.2f} MB/run "
            f"({row['net_positive_runs']}/{row['runs']} runs positive, "
            f"p={row['p']:.2g}), confidence {row['confidence']}"
        )
    for name, sig in sorted((leaks.get("process") or {}).items()):
        if sig["verdict"] == "leak":
            out.append(
                f"  LEAK        process {name}: median slope "
                f"{sig['median_slope_bytes_s'] / 1e3:.1f} kB/s "
                f"({sig['positive_runs']}/{sig['runs']} runs climbing, "
                f"p={sig['p']:.2g}), confidence {sig['confidence']}"
            )
    out.append(f"verdict: {doc.get('verdict', '?')}")
    return "\n".join(out)


def smoke() -> str:
    """End-to-end self-check used by ``analysis fleet --smoke`` and CI:
    generate the canonical synthetic populations, analyze each, and assert
    the contract — stable is clean, the step and drift regions are flagged
    with their names and effect sizes, the leak region and process leak
    verdicts fire, and the summary bytes are ingestion-order independent.
    Returns a one-line success message."""
    import shutil
    import tempfile

    from . import synth

    tmp = tempfile.mkdtemp(prefix="repro-fleet-smoke-")
    try:
        roots = synth.write_all(tmp)
        docs = {kind: build_fleet_summary([root]) for kind, root in roots.items()}
        assert docs["stable"]["verdict"] == "ok", docs["stable"]["verdict"]
        assert docs["stable"]["findings_total"] == 0

        step = [f for f in docs["step"]["time"]["findings"]
                if f["verdict"] == "regression"]
        assert step and step[0]["region"] == "app:transform", step
        assert abs(step[0]["effect_size"]) >= EFFECT_LARGE

        drift = [f for f in docs["drift"]["time"]["findings"]
                 if f["verdict"] == "regression"]
        assert drift and drift[0]["region"] == "app:decode", drift

        leak_doc = docs["leak"]["leaks"]
        leak_rows = [r for r in leak_doc["regions"] if r["verdict"] == "leak"]
        assert leak_rows and leak_rows[0]["region"] == "app:cache_fill", leak_rows
        assert leak_doc["process"]["heap"]["verdict"] == "leak"
        assert "leaking" in docs["leak"]["verdict"]

        # Ingestion-order independence: per-run-dir roots, shuffled.
        run_dirs = sorted(
            os.path.join(roots["step"], d) for d in os.listdir(roots["step"])
        )
        a = json.dumps(build_fleet_summary(run_dirs), sort_keys=True)
        b = json.dumps(build_fleet_summary(list(reversed(run_dirs))), sort_keys=True)
        assert a == b, "fleet summary must not depend on ingestion order"
        return (
            "fleet smoke OK: stable clean, step/drift flagged "
            f"(effect {step[0]['effect_size']:+.2f} / {drift[0]['effect_size']:+.2f}), "
            "leak region + process heap flagged, deterministic bytes"
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
