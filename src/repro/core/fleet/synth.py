"""Synthetic run populations — fixtures for the fleet analyzer.

Generates run directories carrying *real-schema* artifacts (meta.json,
profile.json, memory.json exactly as the measurement writes them) for four
canonical population shapes:

* ``stable``   — stationary noise; the analyzer must report zero findings.
* ``step``     — one region's exclusive time jumps +60% partway through
  (a merged regression); must be flagged with a large effect size.
* ``drift``    — one region grows a few percent per run (a slow
  degradation no pairwise diff would catch); must be flagged.
* ``leak``     — one region allocates heavily, reclaims almost nothing,
  and the process heap/RSS timelines climb within every run; must produce
  region and whole-process leak verdicts.

Everything is seeded and string-keyed (``random.Random(str)`` hashes with
SHA-512, stable across processes — never ``hash()``, which is randomized),
so the same spec always yields byte-identical artifacts: the determinism
tests and ``analysis fleet --smoke`` rely on that.

The checked-in entry point for tests lives at
``tests/fixtures/fleet/generate.py`` and simply drives
:func:`write_population` / :func:`write_all`.
"""

from __future__ import annotations

import json
import os
import random
from typing import Any, Dict, List, Optional

from ..schema import stamp

#: Canonical population specs.  ``regions`` maps region -> (base excl_ns,
#: kind); the special roles name which region carries the anomaly.
CANONICAL: Dict[str, Dict[str, Any]] = {
    "stable": {"runs": 18},
    "step": {"runs": 18, "step_region": "app:transform", "step_at": 12, "step_factor": 1.6},
    "drift": {"runs": 18, "drift_region": "app:decode", "drift_per_run": 1.035},
    "leak": {"runs": 14, "leak_region": "app:cache_fill", "leak_growth": 1.05},
}

REGIONS: Dict[str, Any] = {
    "user:step": (50_000_000, "user"),
    "app:transform": (20_000_000, "python"),
    "app:decode": (15_000_000, "python"),
    "app:load": (8_000_000, "python"),
    "builtins:sum": (5_000_000, "c"),
}

#: Heap-attribution bases: region -> (alloc bytes/run, reclaim fraction).
ALLOC: Dict[str, Any] = {
    "app:cache_fill": (8_000_000, 0.975),
    "app:transform": (2_000_000, 0.95),
    "app:decode": (1_000_000, 0.9),
}

BASE_EPOCH_NS = 1_700_000_000_000_000_000  # fixed, not wall clock
RUN_SPACING_NS = 3_600 * 10**9  # one run per hour
NOISE_SIGMA = 0.02


def _rng(*key: Any) -> random.Random:
    return random.Random(":".join(str(k) for k in key))


def _series(start: float, slope_per_s: float, rng: random.Random,
            points: int = 24, duration_s: float = 60.0) -> List[List[float]]:
    t0 = 10**12
    out = []
    for i in range(points):
        t_s = duration_s * i / (points - 1)
        value = start + slope_per_s * t_s + rng.gauss(0.0, 0.05)
        out.append([t0 + int(t_s * 1e9), round(value, 6)])
    return out


def write_run(out_dir: str, kind: str, index: int, spec: Dict[str, Any],
              seed: int = 0) -> str:
    """Write one synthetic run dir (meta/profile/memory.json) and return
    its path."""
    run_dir = os.path.join(out_dir, f"fleet-{kind}-{index:03d}")
    os.makedirs(run_dir, exist_ok=True)
    epoch = BASE_EPOCH_NS + index * RUN_SPACING_NS
    meta = stamp(
        {
            "rank": 0,
            "topology": {"rank": 0, "world_size": 1, "local_rank": 0, "mesh_shape": []},
            "pid": 10_000 + index,
            "experiment": f"fleet-{kind}",
            "instrumenter": "profile",
            "buffer_strategy": "numpy",
            "epoch_time_ns": epoch,
            "epoch_perf_ns": 10**12,
            "finalize_time_ns": epoch + 60 * 10**9,
            "n_regions": len(REGIONS),
            "events_flushed": 1000,
        }
    )
    pmeta = {
        "rank": 0,
        "topology": meta["topology"],
        "pid": meta["pid"],
        "experiment": meta["experiment"],
        "instrumenter": "profile",
        "substrates": ["profiling", "metrics", "memory"],
        "epoch_time_ns": epoch,
        "epoch_perf_ns": 10**12,
    }

    flat: Dict[str, Any] = {}
    for region, (base_ns, rkind) in REGIONS.items():
        rng = _rng(seed, kind, index, "time", region)
        scale = rng.gauss(1.0, NOISE_SIGMA)
        if region == spec.get("step_region") and index >= spec.get("step_at", 0):
            scale *= spec["step_factor"]
        if region == spec.get("drift_region"):
            scale *= spec["drift_per_run"] ** index
        excl = max(1, int(base_ns * scale))
        flat[region] = {
            "visits": 100,
            "incl_ns": int(excl * 1.1),
            "excl_ns": excl,
            "kind": rkind,
        }
    profile = stamp({"meta": pmeta, "metrics": {}, "threads": {}, "flat": flat})

    heap_regions: Dict[str, Any] = {}
    for region, (base_alloc, reclaim) in ALLOC.items():
        rng = _rng(seed, kind, index, "alloc", region)
        alloc = base_alloc * rng.gauss(1.0, NOISE_SIGMA)
        if region == spec.get("leak_region"):
            alloc *= spec["leak_growth"] ** index
            reclaim = 0.02  # almost nothing comes back
        alloc = max(1, int(alloc))
        freed = int(alloc * reclaim)
        # Non-leaking regions jitter around net zero (churn), so the sign
        # test sees an honest coin flip instead of a tiny constant bias.
        net = alloc - freed if region == spec.get("leak_region") else int(
            (alloc - freed) * rng.choice([-1.0, 1.0])
        )
        heap_regions[region] = {
            "alloc_bytes": alloc,
            "freed_bytes": freed,
            "net_bytes": net,
            "alloc_blocks": max(1, alloc // 512),
            "flushes": 4,
        }
    leaking = "leak_region" in spec
    slope_mb_s = 0.5 if leaking else 0.0  # ~524 kB/s, well over the floor
    rng = _rng(seed, kind, index, "series")
    rss0 = 30.0 + rng.gauss(0.0, 0.2)
    memory = stamp(
        {
            "meta": pmeta,
            "config": {"period_s": 0.01, "topn": 25},
            "heap": {
                "regions": heap_regions,
                "dropped_regions": 0,
                "start_bytes": 0,
                "end_bytes": int((2.0 + slope_mb_s * 60) * 1e6),
                "peak_bytes": int((2.5 + slope_mb_s * 60) * 1e6),
                "threads": {},
            },
            "rss": {
                "peak_bytes": int((rss0 + slope_mb_s * 60) * 1e6),
                "end_bytes": int((rss0 + slope_mb_s * 60) * 1e6),
                "samples": 24,
                "source": "statm",
            },
            "gc": {
                "collections": 12,
                "pause_ns_total": 1_500_000,
                "collected": 480,
                "uncollectable": 0,
                "per_generation": {},
            },
            "fds": {"peak": 8, "end": 8},
            "series": {
                "mem.rss_mb": _series(rss0, slope_mb_s, _rng(seed, kind, index, "rss")),
                "mem.heap_mb": _series(2.0, slope_mb_s, _rng(seed, kind, index, "heap")),
            },
        }
    )

    for name, doc in (("meta.json", meta), ("profile.json", profile), ("memory.json", memory)):
        with open(os.path.join(run_dir, name), "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
    return run_dir


def write_population(out_dir: str, kind: str, runs: Optional[int] = None,
                     seed: int = 0) -> str:
    """Materialize one canonical population under ``out_dir/<kind>/`` and
    return that root.  ``kind`` must be a :data:`CANONICAL` key."""
    spec = dict(CANONICAL[kind])
    n = runs if runs is not None else spec["runs"]
    root = os.path.join(out_dir, kind)
    os.makedirs(root, exist_ok=True)
    for i in range(n):
        write_run(root, kind, i, spec, seed=seed)
    return root


def write_all(out_dir: str, seed: int = 0) -> Dict[str, str]:
    """All four canonical populations; returns ``{kind: root}``."""
    return {kind: write_population(out_dir, kind, seed=seed) for kind in CANONICAL}
