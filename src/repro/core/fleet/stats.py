"""Statistics kernel for fleet-scale regression detection.

Run-population analytics cannot use raw thresholds: CI machines, schedulers
and allocator state add noise that a single pairwise ``--diff`` (or a fixed
"20% slower" rule) cannot distinguish from a real regression.  Everything
here is therefore *rank-based and effect-size driven*:

* :func:`cliffs_delta` — Cliff's delta, the ordinal effect size in
  ``[-1, 1]``: the probability a candidate sample exceeds a baseline sample
  minus the reverse.  Robust to outliers, scale-free, exactly antisymmetric
  under swapping the windows.
* :func:`mann_whitney` — the Mann-Whitney U rank-sum test (two-sided,
  tie-corrected normal approximation with continuity correction): "are
  these two windows draws from the same distribution?"
* :func:`compare_windows` — the decision procedure combining both (plus a
  robust MAD-outlier fallback when a window is too small for a rank test,
  which is the CI-gate case of one candidate snapshot vs N baselines).

Degenerate-input contract (property-tested): every function accepts empty,
single-element, constant, and duplicate-heavy inputs without raising, and
never returns NaN/inf — non-finite input values are dropped up front.
"""

from __future__ import annotations

import math
import sys
from bisect import bisect_left, bisect_right
from typing import Any, Dict, List, Optional, Sequence

#: |Cliff's delta| interpretation thresholds (Romano et al.): below small
#: is negligible; the default regression gate asks for at least MEDIUM.
EFFECT_SMALL = 0.147
EFFECT_MEDIUM = 0.33
EFFECT_LARGE = 0.474

#: Smallest window size the rank test is allowed on; below it the
#: MAD-outlier rule takes over (a U test on 1-2 samples is numerology).
MIN_RANK_WINDOW = 3

#: MAD z-score (robust sigmas) a small candidate window must exceed.
MAD_K = 3.0

#: Floor on the baseline's robust spread, as a fraction of |median| — a
#: near-constant baseline must not hair-trigger the outlier rule on
#: sub-percent wiggle.
MAD_FLOOR_FRAC = 0.05


def finite(values: Sequence[float]) -> List[float]:
    """``values`` with every non-finite (NaN/inf) entry dropped — the
    kernel's NaN-free input guarantee."""
    return [float(v) for v in values if isinstance(v, (int, float)) and math.isfinite(v)]


def median(values: Sequence[float]) -> float:
    """Median of ``values`` (0.0 for an empty sequence, never raises)."""
    vs = sorted(finite(values))
    if not vs:
        return 0.0
    n = len(vs)
    mid = n // 2
    # Halve before adding: (a + b) / 2 overflows to inf near float max.
    return vs[mid] if n % 2 else vs[mid - 1] / 2.0 + vs[mid] / 2.0


def mad(values: Sequence[float]) -> float:
    """Median absolute deviation (0.0 for fewer than 2 samples)."""
    vs = finite(values)
    if len(vs) < 2:
        return 0.0
    m = median(vs)
    # abs(v - m) can overflow for opposite-sign huge values; saturate so
    # the result honours the kernel's never-inf guarantee.
    big = sys.float_info.max
    return median([min(abs(v - m), big) for v in vs])


def cliffs_delta(candidate: Sequence[float], baseline: Sequence[float]) -> float:
    """Cliff's delta of ``candidate`` vs ``baseline``.

    ``+1`` means every candidate sample exceeds every baseline sample
    (candidate stochastically larger), ``-1`` the reverse, ``0`` perfect
    overlap.  Either window empty -> ``0.0`` (no evidence, not an error).
    Exactly antisymmetric: ``cliffs_delta(a, b) == -cliffs_delta(b, a)``.
    """
    a = finite(candidate)
    b = sorted(finite(baseline))
    if not a or not b:
        return 0.0
    m = len(b)
    gt = lt = 0
    for x in a:
        gt += bisect_left(b, x)        # baseline samples strictly below x
        lt += m - bisect_right(b, x)   # baseline samples strictly above x
    return (gt - lt) / (len(a) * m)


def mann_whitney(candidate: Sequence[float], baseline: Sequence[float]):
    """Two-sided Mann-Whitney U test of ``candidate`` vs ``baseline``.

    Returns ``(u, p)`` where ``u`` is the candidate-side U statistic and
    ``p`` the two-sided p-value from the tie-corrected normal approximation
    with continuity correction.  Degenerate inputs (either window empty,
    or every value tied) return ``p = 1.0`` — never NaN, never a raise.
    """
    a = finite(candidate)
    b = finite(baseline)
    n, m = len(a), len(b)
    if n == 0 or m == 0:
        return 0.0, 1.0
    pooled = sorted([(v, 0) for v in a] + [(v, 1) for v in b])
    ranks_a = 0.0
    tie_term = 0.0
    i = 0
    total = n + m
    while i < total:
        j = i
        while j + 1 < total and pooled[j + 1][0] == pooled[i][0]:
            j += 1
        t = j - i + 1
        avg_rank = (i + j) / 2.0 + 1.0  # ranks are 1-based
        if t > 1:
            tie_term += t * (t * t - 1.0)
        for k in range(i, j + 1):
            if pooled[k][1] == 0:
                ranks_a += avg_rank
        i = j + 1
    u = ranks_a - n * (n + 1) / 2.0
    mu = n * m / 2.0
    var = n * m / 12.0 * ((total + 1) - tie_term / (total * (total - 1.0))) if total > 1 else 0.0
    if var <= 0.0:  # all values tied: the windows are indistinguishable
        return u, 1.0
    z = (abs(u - mu) - 0.5) / math.sqrt(var)
    if z < 0.0:
        z = 0.0
    p = math.erfc(z / math.sqrt(2.0))
    # erfc underflow/rounding can nick just past 1.0; clamp to a valid p.
    return u, min(max(p, 0.0), 1.0)


def sign_test_p(positives: int, n: int) -> float:
    """One-sided exact sign test: probability of >= ``positives`` successes
    in ``n`` fair coin flips.  ``n == 0`` -> 1.0 (no evidence)."""
    if n <= 0:
        return 1.0
    k = max(0, min(positives, n))
    tail = sum(math.comb(n, i) for i in range(k, n + 1))
    return min(1.0, tail / (2.0 ** n))


def slope_per_second(series: Sequence[Sequence[float]]) -> float:
    """Least-squares slope of a ``[[t_ns, value], ...]`` timeline in
    value-units per second (0.0 for < 2 distinct timestamps)."""
    pts = [(float(t), float(v)) for t, v in series
           if math.isfinite(float(t)) and math.isfinite(float(v))]
    if len(pts) < 2:
        return 0.0
    ts = [t / 1e9 for t, _ in pts]
    vs = [v for _, v in pts]
    n = len(pts)
    mt = sum(ts) / n
    mv = sum(vs) / n
    den = sum((t - mt) ** 2 for t in ts)
    if den <= 0.0:
        return 0.0
    slope = sum((t - mt) * (v - mv) for t, v in zip(ts, vs)) / den
    return slope if math.isfinite(slope) else 0.0


def confidence_from_p(p: Optional[float]) -> str:
    """Map a p-value to the coarse confidence label carried in verdicts."""
    if p is None:
        return "heuristic"
    if p < 0.001:
        return "high"
    if p < 0.01:
        return "medium"
    return "low"


def effect_label(delta: float) -> str:
    """Romano et al. qualitative label for a Cliff's delta magnitude."""
    d = abs(delta)
    if d >= EFFECT_LARGE:
        return "large"
    if d >= EFFECT_MEDIUM:
        return "medium"
    if d >= EFFECT_SMALL:
        return "small"
    return "negligible"


def compare_windows(
    baseline: Sequence[float],
    candidate: Sequence[float],
    higher_is_worse: bool = True,
    alpha: float = 0.05,
    min_effect: float = EFFECT_MEDIUM,
    min_rel: float = 0.05,
) -> Dict[str, Any]:
    """Decide whether ``candidate`` regressed (or improved) vs ``baseline``.

    Both windows big enough (>= :data:`MIN_RANK_WINDOW`): Mann-Whitney p
    gated at ``alpha`` AND |Cliff's delta| gated at ``min_effect``.  A
    too-small window (the one-snapshot CI-gate case) falls back to the
    robust MAD-outlier rule: the candidate median must sit at least
    :data:`MAD_K` robust sigmas outside the baseline, with the spread
    floored at :data:`MAD_FLOOR_FRAC` of |median| so near-constant
    baselines don't hair-trigger.  Either way the median shift must also
    clear ``min_rel`` relative change — statistically-significant nothings
    are reported as ``stable``.

    Returns a JSON-ready dict: ``verdict`` (``regression`` / ``improvement``
    / ``stable`` / ``insufficient``), ``method``, ``effect_size`` (Cliff's
    delta, candidate vs baseline), ``effect``, ``p``, ``confidence``,
    ``rel_change``, and per-window ``n`` / ``median`` / ``mean``.
    """
    base = finite(baseline)
    cand = finite(candidate)
    med_b = median(base)
    med_c = median(cand)

    def _mean(vs: List[float], med: float) -> float:
        if not vs:
            return 0.0
        m = sum(vs) / len(vs)
        # Extreme finite inputs can overflow the sum; the median is the
        # robust stand-in and keeps the output NaN/inf-free.
        return m if math.isfinite(m) else med

    out: Dict[str, Any] = {
        "baseline": {
            "n": len(base),
            "median": med_b,
            "mean": _mean(base, med_b),
        },
        "candidate": {
            "n": len(cand),
            "median": med_c,
            "mean": _mean(cand, med_c),
        },
        "effect_size": 0.0,
        "effect": "negligible",
        "p": None,
        "method": None,
        "confidence": "none",
        "rel_change": None,
        "verdict": "insufficient",
    }
    if not base or not cand:
        return out
    delta = cliffs_delta(cand, base)
    out["effect_size"] = delta
    out["effect"] = effect_label(delta)
    if med_b != 0.0:
        rel = (med_c - med_b) / abs(med_b)
        if not math.isfinite(rel):
            # Opposite-sign medians near float max: the difference itself
            # overflowed — a shift that large is trivially past min_rel.
            rel = math.copysign(sys.float_info.max, med_c - med_b if med_c != med_b else 1.0)
        out["rel_change"] = rel
        rel_ok = abs(rel) >= min_rel
    else:
        # Baseline median exactly zero: any nonzero candidate is "new".
        out["rel_change"] = None
        rel_ok = med_c != 0.0
    if len(base) >= MIN_RANK_WINDOW and len(cand) >= MIN_RANK_WINDOW:
        _, p = mann_whitney(cand, base)
        out["p"] = p
        out["method"] = "mann-whitney"
        significant = p <= alpha and abs(delta) >= min_effect
        worse = delta > 0.0
    else:
        spread = mad(base)
        floor = MAD_FLOOR_FRAC * abs(med_b)
        sigma = 1.4826 * max(spread, floor / 1.4826)
        out["method"] = "mad-outlier"
        if sigma <= 0.0:
            # Constant-zero baseline: fall back on the rel_ok rule alone.
            significant = med_c != med_b
            z = 0.0
        else:
            z = (med_c - med_b) / sigma
            if math.isnan(z):  # inf/inf: both windows astronomically spread
                z = 0.0
            elif math.isinf(z):
                z = math.copysign(sys.float_info.max, z)
            significant = abs(z) >= MAD_K
        out["mad_z"] = z
        worse = med_c > med_b
    if not higher_is_worse:
        worse = not worse
    if significant and rel_ok:
        out["verdict"] = "regression" if worse else "improvement"
        out["confidence"] = confidence_from_p(out["p"])
    else:
        out["verdict"] = "stable"
        out["confidence"] = "none"
    return out
