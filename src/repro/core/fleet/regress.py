"""Per-region regression detection over a run population.

The population is split into a *baseline window* (the older runs) and a
*candidate window* (the newest runs); every region's per-run exclusive-time
and allocation distributions are compared window-vs-window with the
effect-size kernel (:func:`repro.core.fleet.stats.compare_windows`).  No
raw thresholds anywhere: a region regresses when the rank test says the
windows differ (p <= alpha), the effect is at least medium (|Cliff's
delta|), and the median moved by at least ``min_rel`` in the *worse*
direction (higher time / higher alloc).  Improvements are reported too —
a perf win showing up in the fleet view is signal, not noise.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from .ingest import RunStat
from .stats import EFFECT_MEDIUM, compare_windows

#: Regions seen in fewer than this fraction of the window's runs are not
#: compared — a region that only exists in two runs has no distribution.
MIN_PRESENCE = 0.5


def default_candidate(n_runs: int) -> int:
    """Default candidate-window size for an ``n_runs`` population: a third
    of the population, clamped to [1, 8]."""
    return max(1, min(n_runs // 3, 8))


def split_windows(runs: Sequence[RunStat], candidate: int = 0):
    """Split epoch-ordered ``runs`` into (baseline, candidate) windows.
    ``candidate <= 0`` picks :func:`default_candidate`."""
    n = len(runs)
    c = candidate if candidate > 0 else default_candidate(n)
    c = min(c, max(n - 1, 0))
    return list(runs[: n - c]), list(runs[n - c:])


def _series(
    runs: Sequence[RunStat], region: str, column: str
) -> List[float]:
    """The per-run series of one region column, absent runs skipped."""
    out = []
    for r in runs:
        table = getattr(r, column)
        if region in table:
            out.append(float(table[region]))
    return out


def region_findings(
    baseline: Sequence[RunStat],
    candidate: Sequence[RunStat],
    column: str = "excl_ns",
    metric: str = "excl_ns",
    alpha: float = 0.05,
    min_effect: float = EFFECT_MEDIUM,
    min_rel: float = 0.05,
    min_abs: float = 0.0,
) -> Dict[str, Any]:
    """Window-vs-window comparison of every region's ``column`` series.

    Returns ``{"findings": [...], "checked_regions": n, "skipped_regions":
    n}``; findings carry the full :func:`compare_windows` verdict dict plus
    the region name and metric, regressions first, sorted by effect size.
    ``min_abs`` drops regions whose candidate median is below it (noise
    floor: a 2x shift on a 3 µs region is not a fleet event).
    """
    regions = sorted(
        {name for r in list(baseline) + list(candidate) for name in getattr(r, column)}
    )
    findings: List[Dict[str, Any]] = []
    checked = skipped = 0
    for region in regions:
        base = _series(baseline, region, column)
        cand = _series(candidate, region, column)
        # Presence gate: the region must exist in enough of each window to
        # have a distribution at all (new/vanished regions are future work
        # for a dedicated churn section, not fake regressions).
        if (
            len(base) < max(1, MIN_PRESENCE * len(baseline))
            or len(cand) < max(1, MIN_PRESENCE * len(candidate))
        ):
            skipped += 1
            continue
        checked += 1
        verdict = compare_windows(
            base,
            cand,
            higher_is_worse=True,
            alpha=alpha,
            min_effect=min_effect,
            min_rel=min_rel,
        )
        if verdict["verdict"] in ("regression", "improvement"):
            if verdict["candidate"]["median"] < min_abs and verdict["baseline"]["median"] < min_abs:
                skipped += 1
                continue
            findings.append(dict(verdict, region=region, metric=metric))
    findings.sort(
        key=lambda f: (
            f["verdict"] != "regression",       # regressions first
            -abs(f["effect_size"]),
            -abs(f["rel_change"] or 0.0),
            f["region"],
        )
    )
    return {
        "findings": findings,
        "checked_regions": checked,
        "skipped_regions": skipped,
    }


def sparkline_series(
    runs: Sequence[RunStat],
    findings: Sequence[Dict[str, Any]],
    column: str = "excl_ns",
    top: int = 12,
) -> Dict[str, List[Optional[float]]]:
    """Per-run series for the report's fleet sparklines: every finding's
    region plus the biggest regions by candidate median, capped at ``top``.
    Absent runs yield ``None`` points (renderers skip them)."""
    chosen: List[str] = []
    for f in findings:
        if f["region"] not in chosen:
            chosen.append(f["region"])
    if len(chosen) < top:
        totals: Dict[str, float] = {}
        for r in runs:
            for name, v in getattr(r, column).items():
                totals[name] = totals.get(name, 0.0) + float(v)
        for name in sorted(totals, key=lambda n: (-totals[n], n)):
            if name not in chosen:
                chosen.append(name)
            if len(chosen) >= top:
                break
    out: Dict[str, List[Optional[float]]] = {}
    for name in chosen[:top]:
        out[name] = [
            float(getattr(r, column)[name]) if name in getattr(r, column) else None
            for r in runs
        ]
    return out
