"""Leak detection over memsys signals, fleet-wide.

The per-run memory report shows *curves*; this pass turns them into
*verdicts*, following the allocation-velocity vs reclaim-rate shape of
scalene's leak analysis:

* **Per region** (heap attribution columns across runs): a region leaks
  when it keeps allocating (``alloc_velocity`` = median attributed alloc
  bytes per run above a floor), reclaims little of it (``reclaim_rate`` =
  total freed / total alloc below the threshold), and its *net* bytes are
  consistently positive across runs (exact sign test at ``alpha``) — one
  noisy run cannot fake a leak, and a cache that frees on churn cannot
  either.
* **Whole process** (RSS / traced-heap timelines per run): each run's
  timeline is reduced to a least-squares slope in bytes/s; the process
  leaks when the runs' slopes are consistently positive (sign test) and
  the median slope clears a floor.  This catches leaks outside the
  attributed regions — C extensions, caches on unmeasured threads.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence

from .ingest import RunStat
from .stats import confidence_from_p, median, sign_test_p

#: Default reclaim-rate threshold: regions freeing at least this fraction
#: of what they allocate are churn, not leaks.
RECLAIM_THRESHOLD = 0.5

#: Default floor on the per-run attributed allocation median (bytes) — a
#: region must actually allocate to leak.
MIN_ALLOC_VELOCITY = 64 * 1024

#: Default floor on the whole-process timeline slope (bytes/s).
MIN_SLOPE_BYTES_S = 64 * 1024


def region_leaks(
    runs: Sequence[RunStat],
    alpha: float = 0.05,
    reclaim_threshold: float = RECLAIM_THRESHOLD,
    min_alloc_velocity: float = MIN_ALLOC_VELOCITY,
) -> List[Dict[str, Any]]:
    """Per-region leak verdicts across the population (leaks first, then
    by allocation velocity; regions without memsys data are absent)."""
    regions = sorted({name for r in runs for name in r.alloc_bytes})
    rows: List[Dict[str, Any]] = []
    for region in regions:
        alloc = [float(r.alloc_bytes[region]) for r in runs if region in r.alloc_bytes]
        freed = [float(r.freed_bytes.get(region, 0)) for r in runs if region in r.alloc_bytes]
        net = [float(r.net_bytes.get(region, 0)) for r in runs if region in r.alloc_bytes]
        total_alloc = sum(alloc)
        reclaim = (sum(freed) / total_alloc) if total_alloc > 0 else 1.0
        velocity = median(alloc)
        positive = sum(1 for v in net if v > 0)
        p = sign_test_p(positive, len(net))
        leaking = (
            velocity >= min_alloc_velocity
            and reclaim < reclaim_threshold
            and p <= alpha
        )
        rows.append(
            {
                "region": region,
                "runs": len(net),
                "alloc_velocity_bytes": velocity,
                "reclaim_rate": reclaim,
                "net_median_bytes": median(net),
                "net_positive_runs": positive,
                "p": p,
                "verdict": "leak" if leaking else "ok",
                "confidence": confidence_from_p(p) if leaking else "none",
            }
        )
    rows.sort(
        key=lambda r: (
            r["verdict"] != "leak",
            -r["alloc_velocity_bytes"],
            r["region"],
        )
    )
    return rows


def _process_signal(
    slopes: Sequence[float], alpha: float, min_slope: float
) -> Dict[str, Any]:
    vals = list(slopes)
    positive = sum(1 for s in vals if s > 0)
    p = sign_test_p(positive, len(vals))
    med = median(vals)
    leaking = bool(vals) and med >= min_slope and p <= alpha
    return {
        "runs": len(vals),
        "median_slope_bytes_s": med,
        "positive_runs": positive,
        "p": p,
        "verdict": "leak" if leaking else "ok",
        "confidence": confidence_from_p(p) if leaking else "none",
        "slopes_bytes_s": vals,
    }


def process_leaks(
    runs: Sequence[RunStat],
    alpha: float = 0.05,
    min_slope_bytes_s: float = MIN_SLOPE_BYTES_S,
) -> Dict[str, Any]:
    """Whole-process leak verdicts from the heap and RSS timeline slopes
    of every run that carried memsys data."""
    with_mem = [r for r in runs if r.has_memory]
    return {
        "heap": _process_signal(
            [r.heap_slope_bytes_s for r in with_mem], alpha, min_slope_bytes_s
        ),
        "rss": _process_signal(
            [r.rss_slope_bytes_s for r in with_mem], alpha, min_slope_bytes_s
        ),
    }


def leak_section(
    runs: Sequence[RunStat],
    alpha: float = 0.05,
    reclaim_threshold: float = RECLAIM_THRESHOLD,
    min_alloc_velocity: float = MIN_ALLOC_VELOCITY,
    min_slope_bytes_s: float = MIN_SLOPE_BYTES_S,
    top: int = 25,
) -> Dict[str, Any]:
    """The fleet summary's ``leaks`` section: per-region rows (capped at
    ``top``, leak verdicts always kept) + whole-process verdicts."""
    rows = region_leaks(
        runs,
        alpha=alpha,
        reclaim_threshold=reclaim_threshold,
        min_alloc_velocity=min_alloc_velocity,
    )
    leaks = [r for r in rows if r["verdict"] == "leak"]
    kept = rows[:top] if top > 0 else rows
    for row in leaks:  # never cut a leak verdict off the table
        if row not in kept:
            kept.append(row)
    return {
        "regions": kept,
        "region_leaks": len(leaks),
        "checked_regions": len(rows),
        "process": process_leaks(runs, alpha=alpha, min_slope_bytes_s=min_slope_bytes_s),
    }
