"""Two-phase bootstrap — ``python -m repro.scorep <opts> script.py <args>``.

Faithful port of the paper's Fig. 1 workflow:

  Phase 1 (*preparation*): parse measurement arguments, compose the
  measurement environment, and **restart the interpreter with os.execve**.
  Score-P restarts because ``LD_PRELOAD`` is evaluated by the dynamic linker
  at process start; we restart for the same structural reason — settings
  such as ``XLA_FLAGS`` / ``JAX_PLATFORMS`` are locked in when JAX first
  initializes, so they must be in the environment *before* the target
  application's imports run.

  Phase 2 (*execution*): detect the bootstrap marker in the environment,
  initialize measurement from env, install the instrumenter, and run the
  target script (``runpy``-style: read, compile, exec as ``__main__``,
  argv rewritten to the target's argv — paper §2.1).

CLI (compare paper Listing 1):

    python -m repro.scorep --instrumenter=profile --substrates=profiling,tracing \
        [--filter SPEC] [--out DIR] [--mpp=jax] [--xla-flags "..."] \
        ./run.py --app-arg
"""

from __future__ import annotations

import argparse
import os
import runpy
import sys
from typing import Dict, List, Optional, Tuple

from .measurement import ENV_PREFIX, MeasurementConfig, finalize, init
from .memsys.substrate import DEFAULT_PERIOD_S, DEFAULT_TOPN
from .topology import ProcessTopology

_BOOTSTRAP_MARKER = ENV_PREFIX + "BOOTSTRAPPED"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.scorep",
        description="Run a Python application under repro performance monitoring.",
        allow_abbrev=False,
    )
    p.add_argument("--instrumenter", default="profile",
                   choices=["none", "profile", "trace", "sampling", "monitoring",
                            "adaptive"],
                   help="event source (paper: sys.setprofile / sys.settrace; "
                        "monitoring/adaptive need Python 3.12+)")
    p.add_argument("--substrates", default="profiling,tracing,metrics",
                   help="comma-separated substrate list")
    p.add_argument("--out", default="repro-traces", help="output directory")
    p.add_argument("--run-dir", default=None, help="explicit run directory (overrides --out)")
    p.add_argument("--filter", dest="filter_spec", default="",
                   help="include/exclude rules, e.g. 'exclude:numpy.*;include:mypkg.*'")
    p.add_argument("--flush-events", type=int, default=1 << 16)
    p.add_argument("--sampling-period", type=int, default=97)
    p.add_argument("--adaptive-rate", type=float, default=4000.0,
                   help="target sampled call pairs per second for the "
                        "adaptive instrumenter (REPRO_MONITOR_ADAPTIVE_RATE)")
    p.add_argument("--buffer", default="list", choices=["list", "numpy"])
    p.add_argument("--memory", action="store_true",
                   help="enable the memory substrate (REPRO_MONITOR_MEMORY=1)")
    p.add_argument("--memory-period", type=float, default=DEFAULT_PERIOD_S,
                   help="memory poller period in seconds")
    p.add_argument("--memory-topn", type=int, default=DEFAULT_TOPN,
                   help="memory.json per-region table size")
    p.add_argument("--budget", type=float, default=0.0,
                   help="overhead budget as fractional dilation (0.05 = 5%%); "
                        "> 0 enables the runtime governor "
                        "(REPRO_MONITOR_BUDGET)")
    p.add_argument("--experiment", default="run")
    p.add_argument("--mpp", default=None, choices=[None, "jax"],
                   help="multi-process paradigm (jax: rank from JAX distributed env)")
    p.add_argument("--xla-flags", default=None,
                   help="extra XLA_FLAGS to install before restart (phase 1)")
    p.add_argument("--no-restart", action="store_true",
                   help="skip the execve restart (only safe if env is already correct)")
    p.add_argument("--no-chrome", action="store_true", help="skip Chrome trace export")
    p.add_argument("--report", action="store_true",
                   help="emit the unified HTML report (report.html) into the "
                        "run dir at finalize (REPRO_MONITOR_REPORT=1)")
    p.add_argument("--static-plan", dest="static_plan", default="",
                   help="static_plan.json from `analysis plan`: merges its "
                        "auto-excludes into the filter and warm-starts the "
                        "governor (REPRO_MONITOR_STATIC_PLAN)")
    p.add_argument("--agent", action="store_true",
                   help="run the live continuous-monitoring agent: publish "
                        "events to a shared-memory ring and serve /report, "
                        "/stats.json, /healthz on rank 0 "
                        "(REPRO_MONITOR_AGENT=1)")
    p.add_argument("--agent-port", type=int, default=0,
                   help="agent HTTP port (0 = ephemeral; "
                        "REPRO_MONITOR_AGENT_PORT)")
    p.add_argument("target", help="script path, or module name with -m style 'mod:pkg.mod'")
    p.add_argument("args", nargs=argparse.REMAINDER, help="target application arguments")
    return p


def compose_environment(ns: argparse.Namespace, environ) -> Dict[str, str]:
    """Phase 1: build the child environment (the LD_PRELOAD analogue).

    Topology (rank / world size / local rank / mesh) is detected from the
    launcher environment — our own bootstrap vars, JAX distributed, Open
    MPI, PMI — and re-serialized into the child env so phase 2 and any
    further forks see a consistent view."""
    env = dict(environ)
    topology = ProcessTopology.from_env(environ)
    substrates = tuple(s.strip() for s in ns.substrates.split(",") if s.strip())
    if ns.memory and "memory" not in substrates:
        substrates = substrates + ("memory",)
    config = MeasurementConfig(
        instrumenter=ns.instrumenter,
        substrates=substrates,
        out_dir=ns.out,
        run_dir=ns.run_dir,
        filter_spec=ns.filter_spec,
        flush_threshold=ns.flush_events,
        sampling_period=ns.sampling_period,
        adaptive_rate=ns.adaptive_rate,
        buffer_strategy=ns.buffer,
        memory_period=ns.memory_period,
        memory_topn=ns.memory_topn,
        budget=ns.budget,
        rank=topology.rank,
        topology=topology,
        experiment=ns.experiment,
        chrome_export=not ns.no_chrome,
        report=ns.report,
        static_plan=ns.static_plan,
        agent=ns.agent,
        agent_port=ns.agent_port,
    )
    env.update(config.to_env())
    env[ENV_PREFIX + "ENABLE"] = "1"
    env[_BOOTSTRAP_MARKER] = "1"
    if ns.xla_flags:
        existing = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (existing + " " + ns.xla_flags).strip()
    if ns.mpp == "jax":
        env[ENV_PREFIX + "MPP"] = "jax"
    return env


def run_target(target: str, args: List[str]) -> None:
    """Phase 2 tail: execute the target as ``__main__`` (paper §2.1)."""
    if target.startswith("mod:"):
        module = target[4:]
        sys.argv = [module] + args
        runpy.run_module(module, run_name="__main__", alter_sys=True)
    else:
        sys.argv = [target] + args
        script_dir = os.path.dirname(os.path.abspath(target))
        if script_dir not in sys.path:
            sys.path.insert(0, script_dir)
        runpy.run_path(target, run_name="__main__")


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    ns = build_parser().parse_args(argv)
    # argparse REMAINDER keeps a leading "--" separator if present.
    args = [a for i, a in enumerate(ns.args) if not (i == 0 and a == "--")]

    if os.environ.get(_BOOTSTRAP_MARKER) != "1" and not ns.no_restart:
        # ---- Phase 1: preparation. Compose env, restart interpreter. ----
        env = compose_environment(ns, os.environ)
        cmd = [sys.executable, "-m", "repro.scorep"] + argv
        os.execve(sys.executable, cmd, env)  # no return

    # ---- Phase 2: execution. ----
    if os.environ.get(_BOOTSTRAP_MARKER) == "1":
        config = MeasurementConfig.from_env()
    else:  # --no-restart path: build config directly from the namespace
        env = compose_environment(ns, {})
        config = MeasurementConfig.from_env(env)
    init(config)
    try:
        run_target(ns.target, args)
        return 0
    except SystemExit as exc:  # propagate the target's exit code
        code = exc.code
        return int(code) if isinstance(code, int) else (0 if code is None else 1)
    finally:
        finalize()
