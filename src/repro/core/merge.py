"""Multi-process trace merge — the MPI-style analysis step.

The paper's MPI mode produces one event stream per rank which Score-P unifies
into a single OTF2 archive.  Here every process writes its own run directory
(``<experiment>-...-r<rank>/``); :func:`merge_runs` aligns their clocks via
the (time_ns, perf_counter_ns) epoch pair recorded at measurement start and
produces a single merged Chrome trace + summary.

The heavy lifting lives in :mod:`repro.core.export`: per-rank streams are
encoded chunk-by-chunk with numpy and merged through a k-way heap.  Only the
compact raw npz columns stay resident; everything per-event and text-sized
(dicts, formatted records, JSON output) is bounded by the export chunk size
instead of the total event count.  Stale run directories from a previous
launch of the same experiment
(duplicate ranks) are detected and dropped — keeping only the newest by
clock epoch — instead of colliding on pid and interleaving B/E streams
into corrupt nesting.
"""

from __future__ import annotations

import glob
import json
import os
import warnings
from typing import Any, Dict, List, Optional

from .export import load_defs, merge_chrome_trace
from .filtering import Filter
from .governor import load_governor
from .memsys import load_memory
from .schema import stamp
from .topology import ProcessTopology


def memory_summary(entries: List[Dict[str, Any]], top: int = 5) -> Optional[Dict[str, Any]]:
    """Cross-rank memory section for the merge summary.

    Reads each selected rank's ``memory.json`` (best-effort: ranks without
    the memory substrate are simply absent) and reports per-rank peak
    RSS/heap, GC pause totals, and top allocating regions, plus the
    peak-RSS imbalance (max/min across ranks) — the load-balance signal the
    HPC-monitoring literature calls out for production jobs.
    """
    ranks = []
    for entry in entries:
        doc = load_memory(entry["run_dir"])
        if doc is None:
            continue
        heap = doc.get("heap", {})
        regions = heap.get("regions", {})
        top_regions = [
            {"region": name, "alloc_bytes": int(row.get("alloc_bytes", 0))}
            for name, row in sorted(
                regions.items(), key=lambda kv: -kv[1].get("alloc_bytes", 0)
            )[:top]
        ]
        ranks.append(
            {
                "rank": entry["pid"],
                "run_dir": entry["run_dir"],
                "peak_rss_bytes": int(doc.get("rss", {}).get("peak_bytes", 0)),
                "rss_source": doc.get("rss", {}).get("source", "?"),
                "peak_heap_bytes": int(heap.get("peak_bytes", 0)),
                "gc_pause_ns": int(doc.get("gc", {}).get("pause_ns_total", 0)),
                "gc_collections": int(doc.get("gc", {}).get("collections", 0)),
                "top_regions": top_regions,
            }
        )
    if not ranks:
        return None
    peaks = [r["peak_rss_bytes"] for r in ranks]
    hi = max(ranks, key=lambda r: r["peak_rss_bytes"])
    lo = min(ranks, key=lambda r: r["peak_rss_bytes"])
    return {
        "ranks": ranks,
        "peak_rss": {
            "max_bytes": hi["peak_rss_bytes"],
            "max_rank": hi["rank"],
            "min_bytes": lo["peak_rss_bytes"],
            "min_rank": lo["rank"],
            "imbalance": (
                hi["peak_rss_bytes"] / lo["peak_rss_bytes"]
                if lo["peak_rss_bytes"] > 0
                else None
            ),
        },
        "gc_pause_ns_total": sum(r["gc_pause_ns"] for r in ranks),
    }


def governor_summary(entries: List[Dict[str, Any]]) -> Optional[Dict[str, Any]]:
    """Cross-rank governor section for the merge summary.

    Reads each selected rank's ``governor.json`` (best-effort: ungoverned
    ranks are simply absent) and reports per-rank action counts, the final
    instrumenter each rank converged to, and the estimated distortion —
    plus the *union* of the per-rank suggested filter specs, which is the
    spec to feed the next multi-process launch (a region hot on any rank
    should be filtered on all of them).
    """
    ranks = []
    union = Filter()
    for entry in entries:
        doc = load_governor(entry["run_dir"])
        if doc is None:
            continue
        actions = doc.get("actions", [])
        kinds = sorted({s["kind"] for a in actions for s in a.get("steps", [])})
        final = doc.get("final_instrumenter") or {}
        est = doc.get("estimate", {})
        ranks.append(
            {
                "rank": entry["pid"],
                "run_dir": entry["run_dir"],
                "budget": doc.get("budget"),
                "actions": len(actions),
                "action_kinds": kinds,
                "final_instrumenter": final.get("name", "?")
                + (f"/p{final['period']}" if final.get("period") else ""),
                "overhead_fraction": float(est.get("overhead_fraction", 0.0)),
                "under_budget": bool(est.get("under_budget", True)),
                "suggested_filter": doc.get("suggested_filter", ""),
            }
        )
        rank_filter = Filter.from_spec(doc.get("suggested_filter", ""))
        # Union per clause kind: base include/exclude rules are the shared
        # launch config (identical across ranks in practice); the absolute
        # runtime excludes are where ranks genuinely differ.
        for ours, theirs in (
            (union.include, rank_filter.include),
            (union.exclude, rank_filter.exclude),
            (union.runtime_exclude, rank_filter.runtime_exclude),
        ):
            for pat in theirs:
                if pat not in ours:
                    ours.append(pat)
    if not ranks:
        return None
    return {
        "ranks": ranks,
        "actions_total": sum(r["actions"] for r in ranks),
        "ranks_over_budget": sum(1 for r in ranks if not r["under_budget"]),
        "suggested_filter": union.to_spec(),
    }


def profile_summary(
    entries: List[Dict[str, Any]], top: int = 12
) -> Optional[Dict[str, Any]]:
    """Cross-rank region-time section for the merge summary (heatmap data).

    Reads each selected rank's ``profile.json`` flat table (best-effort:
    ranks without the profiling substrate are simply absent) and builds a
    rank × region matrix of exclusive times over the union of each rank's
    top regions — the per-region load-imbalance view the HTML report renders
    as a heatmap.  Layout::

        {"ranks": [0, 1, ...],               # column order
         "regions": [name, ...],             # row order (total excl desc)
         "excl_ns": [[...], ...],            # excl_ns[row][col]
         "visits": [[...], ...],
         "imbalance": {region: max/mean}}    # rows with >1 rank present
    """
    per_rank: Dict[int, Dict[str, Dict[str, float]]] = {}
    for entry in entries:
        path = os.path.join(entry["run_dir"], "profile.json")
        if not os.path.exists(path):
            continue
        try:
            with open(path) as fh:
                per_rank[entry["pid"]] = json.load(fh).get("flat", {})
        except (OSError, ValueError):
            continue
    if not per_rank:
        return None
    chosen: List[str] = []
    for flat in per_rank.values():
        for name in sorted(flat, key=lambda n: -flat[n].get("excl_ns", 0))[:top]:
            if name not in chosen:
                chosen.append(name)
    totals = {
        name: sum(flat.get(name, {}).get("excl_ns", 0) for flat in per_rank.values())
        for name in chosen
    }
    regions = sorted(chosen, key=lambda n: -totals[n])
    ranks = sorted(per_rank)
    excl = [
        [int(per_rank[r].get(name, {}).get("excl_ns", 0)) for r in ranks]
        for name in regions
    ]
    visits = [
        [int(per_rank[r].get(name, {}).get("visits", 0)) for r in ranks]
        for name in regions
    ]
    imbalance = {}
    if len(ranks) > 1:
        for name, row in zip(regions, excl):
            mean = sum(row) / len(row)
            if mean > 0:
                imbalance[name] = round(max(row) / mean, 4)
    return {
        "ranks": ranks,
        "regions": regions,
        "excl_ns": excl,
        "visits": visits,
        "imbalance": imbalance,
    }


def find_runs(
    root: str, experiment: Optional[str] = None, marker: str = "defs.json"
) -> List[str]:
    """Locate run directories (those containing ``marker``) under ``root``.

    ``experiment`` matches on the ``<experiment>-`` run-dir boundary (or the
    exact name), so sibling experiments sharing a prefix (``run`` vs
    ``run2``) never bleed into each other's merge.

    The default marker is ``defs.json`` (merge needs event streams); the
    fleet analyzer passes ``meta.json`` so profile-only runs — which never
    write defs.json — join the population too.
    """
    runs = []
    for path in sorted(glob.glob(os.path.join(root, "*"))):
        if not os.path.isdir(path):
            continue
        if experiment is not None:
            base = os.path.basename(path)
            if base != experiment and not base.startswith(experiment + "-"):
                continue
        if os.path.exists(os.path.join(path, marker)):
            runs.append(path)
    return runs


def _rank_of(meta: Dict[str, Any]) -> int:
    topo = meta.get("topology") or {}
    return int(topo.get("rank", meta.get("rank", 0)) or 0)


def _dedupe_ranks(entries: List[Dict[str, Any]]):
    """Keep one run dir per rank (newest clock epoch wins); report the rest.

    Duplicate ranks prove that two launches of the experiment overlap in the
    merge root; when the surviving duplicates explicitly recorded the current
    launch's world size, leftover higher ranks from a previous *larger*
    launch (which collide with nothing) are stale too and are also dropped.
    """
    by_rank: Dict[int, Dict[str, Any]] = {}
    dropped: List[Dict[str, Any]] = []
    for entry in entries:
        cur = by_rank.get(entry["pid"])
        if cur is None:
            by_rank[entry["pid"]] = entry
        elif entry["epoch_time_ns"] >= cur["epoch_time_ns"]:
            dropped.append(cur)
            by_rank[entry["pid"]] = entry
        else:
            dropped.append(entry)
    if dropped:
        dup_ranks = {d["pid"] for d in dropped}
        worlds = [
            int(e["topology"].get("world_size", 0) or 0)
            for e in by_rank.values()
            if e["pid"] in dup_ranks and isinstance(e.get("topology"), dict)
            and "world_size" in e["topology"]
        ]
        current_world = max(worlds, default=0)
        if current_world >= 1:
            for rank in [r for r in by_rank if r >= current_world]:
                dropped.append(by_rank.pop(rank))
        warnings.warn(
            "merge_runs: duplicate rank run dirs (stale previous launch?); "
            "keeping newest by clock epoch and dropping: "
            + ", ".join(d["run_dir"] for d in dropped),
            RuntimeWarning,
            stacklevel=3,
        )
    return [by_rank[r] for r in sorted(by_rank)], dropped


def merge_runs(
    run_dirs: List[str], out_path: str, chunk: Optional[int] = None
) -> Dict[str, Any]:
    """Merge per-rank trace runs into one Chrome trace with aligned clocks.

    Per-rank timestamps are perf_counter_ns readings; alignment maps them to
    wall time: wall = epoch_time_ns + (t - epoch_perf_ns).

    Returns the merge summary (persisted as ``merged_trace_summary.json``
    by the CLI, rendered by ``analysis merge-summary`` and the HTML
    report): per-rank event counts (``ranks``), stale duplicates dropped
    (``dropped_runs``), export engine stats (``export``), and — when the
    per-rank artifacts exist — cross-rank ``memory``, ``governor``, and
    ``profile`` (rank × region exclusive-time heatmap) sections.  Stamped
    with ``report_schema_version``; field tables in docs/ARTIFACTS.md.
    """
    entries: List[Dict[str, Any]] = []
    summary: Dict[str, Any] = {
        "ranks": [], "dropped_runs": [], "total_events": 0, "world_size": 1,
    }
    for run_dir in run_dirs:
        defs = load_defs(run_dir)
        meta = defs.get("meta", {})
        topo = meta.get("topology") or {}
        rank = _rank_of(meta)
        epoch_time = int(meta.get("epoch_time_ns", 0) or 0)
        epoch_perf = int(meta.get("epoch_perf_ns", 0) or 0)
        try:
            tag = ProcessTopology.from_dict(topo).tag() if topo else f"r{rank}"
        except (TypeError, ValueError):
            tag = f"r{rank}"
        entries.append(
            {
                "run_dir": run_dir,
                "defs": defs,
                "pid": rank,
                "offset_ns": epoch_time - epoch_perf,
                "epoch_time_ns": epoch_time,
                "tag": tag,
                "topology": topo,
            }
        )
    selected, dropped = _dedupe_ranks(entries)
    for entry in selected:  # world size reflects the merged launch only
        summary["world_size"] = max(
            summary["world_size"],
            int(entry["topology"].get("world_size", entry["pid"] + 1) or 1),
        )
    summary["dropped_runs"] = [
        {"rank": d["pid"], "run_dir": d["run_dir"], "epoch_time_ns": d["epoch_time_ns"]}
        for d in dropped
    ]
    stats = merge_chrome_trace(selected, out_path, chunk=chunk)
    for entry in selected:
        n = stats["per_run_events"].get(entry["run_dir"], 0)
        summary["ranks"].append(
            {
                "rank": entry["pid"],
                "run_dir": entry["run_dir"],
                "events": n,
                "topology": entry["topology"],
            }
        )
        summary["total_events"] += n
    summary["out"] = out_path
    summary["export"] = {k: v for k, v in stats.items() if k != "per_run_events"}
    memory = memory_summary(selected)
    if memory is not None:
        summary["memory"] = memory
    governor = governor_summary(selected)
    if governor is not None:
        summary["governor"] = governor
    profile = profile_summary(selected)
    if profile is not None:
        summary["profile"] = profile
    return stamp(summary)


def build_parser():
    """The ``python -m repro.core.merge`` argument parser (also rendered into
    docs/CLI.md by :mod:`repro.core.clidoc`)."""
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.merge")
    p.add_argument("root", help="directory containing per-rank run dirs")
    p.add_argument("--experiment", default=None,
                   help="only merge run dirs of this experiment name")
    p.add_argument("--out", default=None,
                   help="merged trace path (default: <root>/merged_trace.json)")
    p.add_argument("--chunk", type=int, default=None,
                   help="export chunk size in events (REPRO_MONITOR_EXPORT_CHUNK)")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    from .analysis import render_merge_summary

    ns = build_parser().parse_args(argv)
    runs = find_runs(ns.root, ns.experiment)
    if not runs:
        print(f"no runs found under {ns.root}")
        return 1
    out = ns.out or os.path.join(ns.root, "merged_trace.json")
    summary = merge_runs(runs, out, chunk=ns.chunk)
    summary_path = os.path.splitext(out)[0] + "_summary.json"
    with open(summary_path, "w") as fh:
        json.dump(summary, fh, indent=1, allow_nan=False)
    print(render_merge_summary(summary))
    print(f"summary written to {summary_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
