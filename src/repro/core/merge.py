"""Multi-process trace merge — the MPI-style analysis step.

The paper's MPI mode produces one event stream per rank which Score-P unifies
into a single OTF2 archive.  Here every process writes its own run directory
(``<experiment>-...-r<rank>/``); :func:`merge_runs` aligns their clocks via
the (time_ns, perf_counter_ns) epoch pair recorded at measurement start and
produces a single merged Chrome trace + summary.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from .buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT
from .substrates.tracing import load_run


def find_runs(root: str, experiment: Optional[str] = None) -> List[str]:
    """Locate run directories (those containing defs.json) under ``root``."""
    runs = []
    for path in sorted(glob.glob(os.path.join(root, "*"))):
        if not os.path.isdir(path):
            continue
        if experiment and not os.path.basename(path).startswith(experiment):
            continue
        if os.path.exists(os.path.join(path, "defs.json")):
            runs.append(path)
    return runs


def merge_runs(run_dirs: List[str], out_path: str) -> Dict[str, Any]:
    """Merge per-rank trace runs into one Chrome trace with aligned clocks.

    Per-rank timestamps are perf_counter_ns readings; alignment maps them to
    wall time: wall = epoch_time_ns + (t - epoch_perf_ns).
    """
    events = []
    summary: Dict[str, Any] = {"ranks": [], "total_events": 0, "world_size": 1}
    for run_dir in run_dirs:
        defs, streams = load_run(run_dir)
        meta = defs["meta"]
        topo = meta.get("topology") or {}
        rank = topo.get("rank", meta.get("rank", 0))
        summary["world_size"] = max(summary["world_size"], topo.get("world_size", rank + 1))
        epoch_time = meta.get("epoch_time_ns", 0)
        epoch_perf = meta.get("epoch_perf_ns", 0)
        regions = defs["regions"]
        n_rank_events = 0
        for tid, cols in streams.items():
            kinds, rids, ts = cols["kind"], cols["region"], cols["t"]
            for i in range(len(kinds)):
                k = int(kinds[i])
                if k in (EV_ENTER, EV_C_ENTER):
                    ph = "B"
                elif k in (EV_EXIT, EV_C_EXIT):
                    ph = "E"
                else:
                    continue
                wall_ns = epoch_time + (int(ts[i]) - epoch_perf)
                r = regions[int(rids[i])]
                events.append(
                    {
                        "name": r["name"],
                        "cat": r["module"],
                        "ph": ph,
                        "ts": wall_ns / 1000.0,
                        "pid": rank,
                        "tid": tid,
                    }
                )
                n_rank_events += 1
        summary["ranks"].append(
            {"rank": rank, "run_dir": run_dir, "events": n_rank_events, "topology": topo}
        )
        summary["total_events"] += n_rank_events
    events.sort(key=lambda e: e["ts"])
    with open(out_path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
    summary["out"] = out_path
    return summary


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.merge")
    p.add_argument("root", help="directory containing per-rank run dirs")
    p.add_argument("--experiment", default=None)
    p.add_argument("--out", default=None)
    ns = p.parse_args(argv)
    runs = find_runs(ns.root, ns.experiment)
    if not runs:
        print(f"no runs found under {ns.root}")
        return 1
    out = ns.out or os.path.join(ns.root, "merged_trace.json")
    summary = merge_runs(runs, out)
    print(json.dumps(summary, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
