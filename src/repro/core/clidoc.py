"""Generated CLI documentation — docs/CLI.md is built from the live parsers.

Docs that describe flags by hand drift; this module renders every
user-facing argparse parser's ``--help`` output into one markdown file, and
``tests/test_docs.py`` diffs that file against a fresh render, so a flag
change that forgets the docs fails CI instead of shipping stale text.

    PYTHONPATH=src python -m repro.core.clidoc          # rewrite docs/CLI.md
    PYTHONPATH=src python -m repro.core.clidoc --check  # exit 1 on drift

Help text is rendered at a pinned ``COLUMNS`` width so the output is
byte-identical across terminals and CI.
"""

from __future__ import annotations

import os
import sys
from typing import List, Optional, Tuple

#: Pinned terminal width for deterministic argparse help rendering.
HELP_COLUMNS = 80

DOC_PATH = os.path.join("docs", "CLI.md")

HEADER = """# CLI reference

> **Generated file — do not edit.**  Rebuilt by
> `PYTHONPATH=src python -m repro.core.clidoc`; `tests/test_docs.py` fails
> when this file drifts from the live `--help` output of the parsers below.

All commands are run as `PYTHONPATH=src python -m <module> ...` (or install
the package and drop the `PYTHONPATH`).  Flag defaults shown here are the
single source of truth — they come straight from the argparse definitions.
"""

_SECTIONS: List[Tuple[str, str]] = [
    ("repro.scorep", "The measurement launcher (the paper's `python -m scorep` "
     "analogue): wraps any Python program in monitoring without source changes."),
    ("repro.core.analysis", "Offline artifact analysis: hotspots, run diffs, "
     "memory/governor views, merge summaries, and the unified HTML report."),
    ("repro.core.merge", "Cross-rank trace merge: unifies per-rank run dirs "
     "into one clock-aligned Chrome trace + summary."),
    ("repro.launch.train", "End-to-end training driver (config registry, "
     "sharded step, checkpointing) with monitoring built in."),
    ("repro.launch.serve", "Batched prefill + greedy-decode serving driver "
     "with monitoring built in."),
    ("repro.agent", "Live continuous-monitoring agent: spectate a running "
     "measured process over its shared-memory ring (`attach`), or run the "
     "end-to-end live-path smoke (`smoke`)."),
]


def _parser_for(module: str):
    if module == "repro.scorep":
        from .bootstrap import build_parser
    elif module == "repro.core.analysis":
        from .analysis import build_parser
    elif module == "repro.core.merge":
        from .merge import build_parser
    elif module == "repro.launch.train":
        from repro.launch.train import build_parser
    elif module == "repro.launch.serve":
        from repro.launch.serve import build_parser
    elif module == "repro.agent":
        from repro.agent.cli import build_parser
    else:  # pragma: no cover - guarded by _SECTIONS
        raise KeyError(module)
    return build_parser()


def _render_help(parser) -> str:
    """``parser.format_help()`` at the pinned width (argparse reads COLUMNS
    via shutil.get_terminal_size at format time)."""
    old = os.environ.get("COLUMNS")
    os.environ["COLUMNS"] = str(HELP_COLUMNS)
    try:
        return parser.format_help().rstrip("\n")
    finally:
        if old is None:
            del os.environ["COLUMNS"]
        else:
            os.environ["COLUMNS"] = old


def generate() -> str:
    """The full docs/CLI.md content as a string."""
    parts = [HEADER]
    for module, blurb in _SECTIONS:
        parts.append(f"## `python -m {module}`\n\n{blurb}\n")
        parts.append("```text\n" + _render_help(_parser_for(module)) + "\n```\n")
        if module == "repro.core.analysis":
            parts.append(_analysis_subcommands())
    return "\n".join(parts)


def _analysis_subcommands() -> str:
    """Per-subcommand help for the analysis tool (the top-level help only
    lists them)."""
    from .analysis import build_parser

    parser = build_parser()
    out = []
    # Walk the subparsers action to render each subcommand's own help,
    # recursing one level for nested modes (`analysis fleet analyze` ...).
    def walk(prefix: str, p) -> None:
        if p._subparsers is None:  # noqa: SLF001 (argparse has no public API for this)
            return
        for action in p._subparsers._group_actions:  # noqa: SLF001
            for name, sub in action.choices.items():
                out.append(f"### `{prefix} {name}`\n")
                out.append("```text\n" + _render_help(sub) + "\n```\n")
                walk(f"{prefix} {name}", sub)

    walk("analysis", parser)
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.clidoc")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if docs/CLI.md is stale instead of rewriting it")
    p.add_argument("--out", default=DOC_PATH)
    ns = p.parse_args(argv)
    content = generate()
    if ns.check:
        try:
            with open(ns.out) as fh:
                on_disk = fh.read()
        except OSError:
            on_disk = ""
        if on_disk != content:
            print(
                f"{ns.out} is stale — regenerate with "
                "`PYTHONPATH=src python -m repro.core.clidoc`. "
                f"(This interpreter is Python "
                f"{sys.version_info.major}.{sys.version_info.minor}; argparse "
                "help formatting varies across Python versions, so regenerate "
                "with the same minor version CI pins or the check will flap.)",
                file=sys.stderr,
            )
            return 1
        print(f"{ns.out} is up to date")
        return 0
    os.makedirs(os.path.dirname(ns.out) or ".", exist_ok=True)
    with open(ns.out, "w") as fh:
        fh.write(content)
    print(f"wrote {ns.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
