"""Runtime overhead governor — closing the paper's open problem (§5).

The paper measures instrumentation overhead as ``t = α + β·N`` and names
"ways to control the runtime overhead" as future work; Score-P's manual
workflow (run, inspect the profile, hand-write a filter file, re-run) is
what this module automates *online*:

1. **Calibrate** — before the instrumenter installs, a micro-probe times a
   known call kernel bare vs. instrumented and derives the per-call-pair
   cost of the configured event source, of the filtered-verdict fast path
   (hook fires, region lookup returns ``FILTERED``, nothing appended), and
   of the counting sampler's unsampled/sampled paths (the downgrade
   target), so escalation decisions are model-driven rather than blind.
2. **Account** — at every buffer flush the governor bins the batch per
   region (numpy ``bincount``; no per-event Python) and estimates the
   instrumentation cost of the window: represented call pairs × calibrated
   pair cost, plus the residual hook cost of regions it has already
   excluded (their events no longer reach the buffer, but the hook still
   fires and pays the filtered fast path).
3. **Enforce** — when the windowed overhead estimate exceeds the budget
   (``REPRO_MONITOR_BUDGET``, e.g. ``0.05`` = 5% dilation), it escalates
   along a ladder, projecting each rung's effect with the calibration
   model and walking until the projection fits the budget:
   a. exclude high-frequency / short-duration regions (runtime filter
      tightening + cached-verdict invalidation via
      ``RegionRegistry.refilter``),
   b. raise the counting sampler's period (``Instrumenter.set_period``),
   c. downgrade the instrumenter along ``Instrumenter.downgrade_to``
      (trace → profile → sampling → none; on 3.12+ the sampler downgrades
      to the PEP 669 ``adaptive`` sampler first, which self-limits its
      sample rate and so keeps *some* signal where the ladder previously
      went dark).

Cost tiers: instrumenters with ``zero_cost_filtered`` (the PEP 669 family)
retire filtered locations via ``sys.monitoring.DISABLE``, so their
filtered-verdict cost is a one-time hit, not a per-call rate — the
projection prices excluded regions at zero for them, which makes rung (a)
a true fix instead of a shuffle from the full path to the filtered path.
The adaptive sampler's projected cost is likewise capped at its configured
target sample rate rather than scaling with the application call rate.
4. **Report** — ``governor.json`` records the calibration, every action
   taken, the per-region cost table, the estimated distortion, and a
   Score-P-style suggested filter spec that round-trips through
   ``Filter.from_spec`` for the next run (``--filter`` /
   ``REPRO_MONITOR_FILTER``).

Known approximations (documented, deliberate): exclusive time is estimated
from *leaf* enter/exit pairs only (vectorizable; the high-frequency
short-duration offenders the governor hunts are exactly leaf pairs);
``settrace`` line events are amortized into the calibrated pair cost; and
after an instrumenter swap, pre-existing worker threads lose their hook
(their stale callbacks self-remove) — the swap installs on the flushing
thread and on threads started afterwards.  User regions (explicit
``rmon.region`` annotations) are never auto-excluded.
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time
from dataclasses import asdict, dataclass
from typing import TYPE_CHECKING, Any, Dict, List, Optional

import numpy as np

from .buffer import EV_C_ENTER, EV_ENTER, ListEventBuffer
from .filtering import Filter
from .instrumenters import INSTRUMENTERS, make_instrumenter
from .regions import KIND_USER, RegionRegistry
from .schema import stamp

if TYPE_CHECKING:  # pragma: no cover
    from .measurement import Measurement

ARTIFACT = "governor.json"

#: Period ceiling for the sampler rung; past this the ladder downgrades.
DEFAULT_MAX_PERIOD = 1 << 13


def _fnmatch_escape(name: str) -> str:
    """Escape fnmatch metacharacters so a region name matches literally."""
    return "".join(f"[{ch}]" if ch in "*?[" else ch for ch in name)


# ----------------------------------------------------------------------------
# Calibration — micro-probe of the installed instrumenter
# ----------------------------------------------------------------------------


def _probe_fn(x):
    return x + 1


def _probe_loop(n):
    f = _probe_fn
    x = 0
    for _ in range(n):
        x = f(x)
    return x


class _ProbeHost:
    """Minimal Measurement surface an instrumenter binds against."""

    def __init__(self, record: bool = True):
        decide = None if record else (lambda module, name, file: False)
        self.regions = RegionRegistry(decide=decide)
        self._buf = ListEventBuffer(thread_id=0, flush_threshold=1 << 30)

    def thread_buffer(self):
        return self._buf


@dataclass
class Calibration:
    """Per-call-pair instrumentation costs (ns), from the startup probe.

    A *call pair* is one enter+exit hook invocation pair; costs are the
    measured per-pair slowdown of the probe kernel vs. the bare loop.
    """

    instrumenter: str
    sampling_period: int
    cost_full_ns: float  # configured instrumenter, region recorded
    cost_filtered_ns: float  # configured instrumenter, verdict FILTERED
    sampling_base_ns: float  # counting sampler, unsampled path
    sampling_sampled_ns: float  # counting sampler, period=1 (every call)
    # Adaptive (PEP 669) sampler, cost per *recorded* pair: unsampled calls
    # are DISABLEd away entirely, so the per-call unit is meaningless — the
    # projection multiplies this by the (self-limited) sample rate instead.
    # 0.0 when the probe did not run (no sys.monitoring, or instrumenter
    # "none").
    adaptive_sample_ns: float = 0.0
    probe_calls: int = 0
    probe_s: float = 0.0


def _time_probe(n: int, repeats: int, instrumenter=None, record: bool = True) -> float:
    best = float("inf")
    for _ in range(repeats):
        if instrumenter is not None:
            host = _ProbeHost(record=record)
            instrumenter.install(host)
        try:
            t0 = time.perf_counter()
            _probe_loop(n)
            best = min(best, time.perf_counter() - t0)
        finally:
            if instrumenter is not None:
                instrumenter.uninstall()
    return best


#: Process-wide probe cache: the per-event cost of an event source is a
#: property of the interpreter/machine, not of one measurement, and
#: re-probing per run would both waste α and inject probe jitter into
#: β fits over repeated measurements (benchmarks/governed_overhead.py).
_CALIBRATION_CACHE: Dict[Any, Calibration] = {}


def calibrate(
    instrumenter_name: str,
    sampling_period: int = 97,
    calls: int = 2000,
    repeats: int = 3,
    use_cache: bool = True,
) -> Calibration:
    """Micro-probe the per-event cost of ``instrumenter_name``.

    Uses throwaway instrumenter instances on a stub host (never the live
    measurement), so calibration leaves no trace in the run's artifacts.
    The sampler is probed twice — at a period far beyond the probe size
    (pure unsampled fast path) and at period 1 (every call sampled) — which
    decomposes its cost so period raises can be projected analytically.
    """
    key = (instrumenter_name, sampling_period, calls)
    if use_cache and key in _CALIBRATION_CACHE:
        return _CALIBRATION_CACHE[key]
    t_start = time.perf_counter()
    bare = _time_probe(calls, repeats)

    def pair_cost(name: str, record: bool = True, period: Optional[int] = None) -> float:
        if name == "none":
            return 0.0
        kwargs = {"period": period} if period is not None else {}
        inst = make_instrumenter(name, **kwargs)
        t = _time_probe(calls, repeats, instrumenter=inst, record=record)
        return max(t - bare, 0.0) / calls * 1e9

    def adaptive_sample_cost() -> float:
        # Per *recorded pair* cost of the adaptive sampler.  Unsampled calls
        # never reach a callback (DISABLE retires their location), so the
        # probe's slowdown is divided by the pairs it actually buffered, not
        # by the loop's call count.
        inst = make_instrumenter("adaptive")
        best = float("inf")
        for _ in range(repeats):
            host = _ProbeHost()
            inst.install(host)
            try:
                t0 = time.perf_counter()
                _probe_loop(calls)
                dt = time.perf_counter() - t0
            finally:
                inst.uninstall()
            pairs = max(len(host._buf.events) / 2.0, 1.0)
            best = min(best, max(dt - bare, 0.0) / pairs * 1e9)
        return best

    if instrumenter_name == "sampling":
        cost_full = pair_cost("sampling", period=sampling_period)
        cost_filtered = pair_cost("sampling", record=False, period=sampling_period)
    elif instrumenter_name == "adaptive":
        # Priced per recorded pair (see adaptive_sample_ns); filtered
        # locations retire after one DISABLE hit, so their rate cost is 0.
        cost_full = 0.0
        cost_filtered = 0.0
    else:
        cost_full = pair_cost(instrumenter_name)
        cost_filtered = pair_cost(instrumenter_name, record=False)
    sampling_base = (
        0.0 if instrumenter_name == "none" else pair_cost("sampling", period=1 << 30)
    )
    sampling_sampled = (
        0.0 if instrumenter_name == "none" else pair_cost("sampling", period=1)
    )
    adaptive_sample = (
        adaptive_sample_cost()
        if instrumenter_name != "none" and hasattr(sys, "monitoring")
        else 0.0
    )
    if instrumenter_name == "adaptive":
        cost_full = adaptive_sample
    result = _CALIBRATION_CACHE[key] = Calibration(
        instrumenter=instrumenter_name,
        sampling_period=sampling_period,
        cost_full_ns=cost_full,
        cost_filtered_ns=cost_filtered,
        sampling_base_ns=sampling_base,
        sampling_sampled_ns=max(sampling_sampled, sampling_base),
        adaptive_sample_ns=adaptive_sample,
        probe_calls=calls,
        probe_s=time.perf_counter() - t_start,
    )
    return result


# ----------------------------------------------------------------------------
# Projection model — cost of a (instrumenter, period) state
# ----------------------------------------------------------------------------


@dataclass
class _LadderState:
    name: str
    period: int


class Governor:
    """Online overhead controller for one :class:`Measurement`.

    Hooked by the measurement at three points: :meth:`calibrate_startup`
    (before instrumenter install), :meth:`on_flush` (under the flush lock,
    after substrates), and :meth:`close` (at finalize, instrumenter already
    uninstalled); plus its own watchdog tick between flushes.  All mutation
    of shared measurement state (filter, registry, instrumenter) happens
    under the measurement flush lock, in ``on_flush`` or ``_tick``.
    """

    def __init__(
        self,
        measurement: "Measurement",
        budget: float,
        *,
        max_period: int = DEFAULT_MAX_PERIOD,
        min_window_s: float = 0.005,
        min_window_pairs: int = 32,
        max_excludes_per_action: int = 8,
        # Regions whose *fastest* observed leaf execution is longer than
        # this are never auto-excluded (instrumentation distorts them
        # little).  The minimum — not the mean — is the robust
        # short-duration signal: a single GC pause or descheduling spike
        # landing inside one leaf span inflates the mean past any cap,
        # while the minimum converges on the true body time.
        offender_max_leaf_ns: float = 50_000.0,
        probe_calls: int = 2000,
        projection_safety: float = 2.0,
        watchdog_s: float = 0.01,
    ):
        if budget <= 0:
            raise ValueError("governor budget must be > 0 (fractional dilation)")
        self.measurement = measurement
        self.budget = float(budget)
        self.max_period = int(max_period)
        self.min_window_ns = int(min_window_s * 1e9)
        self.min_window_pairs = int(min_window_pairs)
        self.max_excludes_per_action = int(max_excludes_per_action)
        self.offender_max_leaf_ns = float(offender_max_leaf_ns)
        self.probe_calls = int(probe_calls)
        self.projection_safety = float(projection_safety)
        self.watchdog_s = float(watchdog_s)
        self._watchdog: Optional[threading.Thread] = None
        self._watchdog_stop = threading.Event()
        self._tick_events = 0
        self._tick_filtered = 0
        self._tick_inst: Any = None
        self._tick_t = 0

        self.calibration: Optional[Calibration] = None
        self.actions: List[Dict[str, Any]] = []
        self.frozen = False  # finalize in progress: account, never act

        # Cumulative per-region accounting (index == region id).
        self._visits = np.zeros(0, dtype=np.int64)  # recorded enters
        self._visits_rep = np.zeros(0, dtype=np.float64)  # × cost multiplier
        self._leaf_ns = np.zeros(0, dtype=np.float64)  # leaf-pair exclusive
        self._leaf_min = np.zeros(0, dtype=np.float64)  # fastest leaf span
        self._est_cost = np.zeros(0, dtype=np.float64)
        self._excluded_rids: set = set()
        # Residual model: represented pair rate of excluded regions, frozen
        # at exclusion time (their events stop reaching the buffer).
        self._excluded_rate = 0.0  # pairs/s

        self._t_open = 0
        self._window_start = 0
        self._window_cost = 0.0
        self._window_pairs = 0.0
        self._cum_pairs = 0.0
        # Observed buffered-events-per-pair ratio (2.0 for enter/exit-only
        # streams; line-dominated settrace streams run far higher) — the
        # watchdog needs it to turn raw buffer growth into a pair rate.
        self._ev_total = 0.0
        self._ev_enters = 0.0
        # State history for batch costing: perf_counter_ns at which each
        # (instrumenter, period) state became active, with its multiplier
        # and pair cost.  A buffer that fills under one state can flush
        # after an escalation (another thread's flush triggered it), so
        # batches are costed by *event timestamp*, not by the current state.
        self._state_t: List[int] = []
        self._state_mult: List[float] = []
        self._state_cost: List[float] = []
        # Initial entry so a batch flushed before open() (global
        # sys.monitoring hooks + a busy worker can fire in the window
        # between instrumenter install and governor open) indexes a valid
        # state; costs are 0 until calibration, and open() pushes the
        # calibrated state on top.
        self._push_state(0)
        self._total_cost = 0.0
        self._total_residual = 0.0
        self._residual_mark = 0  # last time residual was folded into totals

        # Static-plan warm start (repro.core.staticpass): predicted offender
        # region names (both module forms) pre-qualified for the exclude
        # rung, plus a provenance summary for the governor document.
        self._plan_offenders: set = set()
        # Wait-point regions from the plan's concurrency section (lock
        # acquires, joins, blocking calls — both module forms).  These are
        # sampler-friendly: mostly blocked, so their instrumentation cost is
        # negligible and their enter/exit pairs *are* the wait-state signal.
        # They must never be excluded — see ``_offenders``.
        self._plan_wait_points: set = set()
        self._plan_meta: Optional[Dict[str, Any]] = None

    # -- lifecycle ----------------------------------------------------------

    def seed_static_plan(self, plan: Dict[str, Any]) -> None:
        """Warm-start from a static plan (``staticpass.apply_plan`` calls
        this).  Predicted offenders become exclude-rung candidates without
        waiting for observed leaf-duration evidence — the short-duration
        verdict was reached statically, so the first over-budget window can
        act on them instead of burning a ladder rung on a downgrade."""
        from .staticpass import offender_names, plan_exclude_patterns

        self._plan_offenders = offender_names(plan)
        conc = plan.get("concurrency") or {}
        self._plan_wait_points = {
            row[key]
            for row in conc.get("wait_points", [])
            for key in ("region", "frameless_region")
            if row.get(key)
        }
        self._plan_meta = {
            "generator": plan.get("generator", "?"),
            "functions": plan.get("functions", 0),
            "verdicts": dict(plan.get("verdicts", {})),
            "predicted_offenders": len(plan.get("predicted_offenders", [])),
            "patterns": len(plan_exclude_patterns(plan)),
            "wait_points": len(conc.get("wait_points", [])),
        }

    def calibrate_startup(self) -> Calibration:
        cfg = self.measurement.config
        self.calibration = calibrate(
            cfg.instrumenter, cfg.sampling_period, calls=self.probe_calls
        )
        return self.calibration

    def open(self) -> None:
        self._t_open = time.perf_counter_ns()
        self._window_start = self._t_open
        self._residual_mark = self._t_open
        self._tick_t = self._t_open
        self._push_state(0)  # events may predate open by an install race
        if self.watchdog_s > 0:
            self._watchdog_stop.clear()
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, name="repro-governor", daemon=True
            )
            self._watchdog.start()

    def stop_watchdog(self) -> None:
        self._watchdog_stop.set()
        if self._watchdog is not None:
            self._watchdog.join(timeout=1.0)
            self._watchdog = None

    # -- cost model ---------------------------------------------------------

    def _pair_cost(self, state: _LadderState) -> float:
        cal = self.calibration
        if cal is None or state.name == "none":
            return 0.0
        if state.name == "sampling":
            return cal.sampling_base_ns + (
                cal.sampling_sampled_ns - cal.sampling_base_ns
            ) / max(state.period, 1)
        if state.name == "adaptive":
            # Cost per *recorded* pair; unsampled calls never fire a
            # callback, so this only ever multiplies a sample rate (the
            # observed buffer rate in accounting, the capped target rate in
            # projection — see _projected).
            return cal.adaptive_sample_ns
        return cal.cost_full_ns

    def _filtered_pair_cost(self, state: _LadderState) -> float:
        cal = self.calibration
        if cal is None or state.name == "none":
            return 0.0
        if state.name == "sampling":
            return cal.sampling_base_ns
        inst_cls = INSTRUMENTERS.get(state.name)
        if inst_cls is not None and inst_cls.zero_cost_filtered:
            # DISABLE retires filtered locations after one hit: excluding a
            # region removes its cost entirely instead of moving it to a
            # per-call filtered fast path.
            return 0.0
        return cal.cost_filtered_ns

    def _current_state(self) -> _LadderState:
        inst = self.measurement.instrumenter
        return _LadderState(inst.name, int(getattr(inst, "period", 0) or 0))

    def _push_state(self, t_ns: int) -> None:
        """Record that the current (instrumenter, period) took effect at
        ``t_ns`` — called at open and after every applied escalation."""
        state = self._current_state()
        self._state_t.append(t_ns)
        self._state_mult.append(
            max(self.measurement.instrumenter.cost_multiplier(), 1.0)
        )
        self._state_cost.append(self._pair_cost(state))

    @staticmethod
    def _overhead_fraction(cost_ns: float, elapsed_ns: float) -> float:
        """Estimated dilation: instrumentation time over useful time."""
        useful = max(elapsed_ns - cost_ns, elapsed_ns * 0.01, 1.0)
        return cost_ns / useful

    def _projected(self, state: _LadderState, kept_rate: float, excl_rate: float) -> float:
        if state.name == "adaptive":
            # The adaptive sampler is self-limiting: its controller holds
            # the recorded-pair rate near the configured target no matter
            # how fast the application calls, so projected cost is bounded
            # by the target rate, not the call rate.
            kept_rate = min(kept_rate, self.measurement.config.adaptive_rate)
        cost_per_s = kept_rate * self._pair_cost(state) + excl_rate * self._filtered_pair_cost(
            state
        )
        return self._overhead_fraction(cost_per_s, 1e9)

    # -- accounting (called under the measurement flush lock) ---------------

    def _ensure(self, n: int) -> None:
        if n > self._visits.size:
            grow = max(n, 2 * self._visits.size, 64)
            for attr in ("_visits", "_visits_rep", "_leaf_ns", "_leaf_min", "_est_cost"):
                arr = getattr(self, attr)
                fill = np.inf if attr == "_leaf_min" else 0
                new = np.full(grow, fill, dtype=arr.dtype)
                new[: arr.size] = arr
                setattr(self, attr, new)

    def on_flush(self, thread_id: int, columns: Dict[str, np.ndarray]) -> None:
        kind = columns["kind"]
        if kind.size:
            reg = columns["region"]
            t = columns["t"]
            enter_mask = (kind == EV_ENTER) | (kind == EV_C_ENTER)
            enters = reg[enter_mask]
            # Cost each enter by the state active at its *timestamp* (a
            # batch can flush after an escalation changed the state it was
            # recorded under — another thread's flush pulls the trigger).
            seg = np.searchsorted(
                np.asarray(self._state_t, dtype=np.uint64), t[enter_mask], side="right"
            ) - 1
            np.clip(seg, 0, len(self._state_t) - 1, out=seg)
            mults = np.asarray(self._state_mult)[seg]
            pair_costs = mults * np.asarray(self._state_cost)[seg]
            if enters.size:
                self._ensure(int(enters.max()) + 1)
                size = self._visits.size
                counts = np.bincount(enters, minlength=size)
                self._visits[: counts.size] += counts
                rep = np.bincount(enters, weights=mults, minlength=size)
                self._visits_rep[: rep.size] += rep
                cost = np.bincount(enters, weights=pair_costs, minlength=size)
                self._est_cost[: cost.size] += cost
            # Leaf pairs: enter immediately followed by the matching exit —
            # their duration is pure exclusive time, vectorizable without a
            # shadow-stack replay.
            if kind.size > 1:
                leaf = (
                    enter_mask[:-1]
                    & (kind[1:] == kind[:-1] + 1)  # EV_EXIT/EV_C_EXIT = enter+1
                    & (reg[1:] == reg[:-1])
                )
                if leaf.any():
                    dur = (t[1:][leaf] - t[:-1][leaf]).astype(np.float64)
                    leaf_regs = reg[:-1][leaf]
                    leaf_sum = np.bincount(
                        leaf_regs, weights=dur, minlength=self._visits.size
                    )
                    self._leaf_ns[: leaf_sum.size] += leaf_sum
                    np.minimum.at(self._leaf_min, leaf_regs, dur)
            self._window_pairs += float(mults.sum())
            self._window_cost += float(pair_costs.sum())
            self._ev_total += float(kind.size)
            self._ev_enters += float(enters.size)

        # Live-agent publish cost counts against the same budget as the
        # instrumentation itself: pull the nanoseconds accrued since the
        # last flush into this window (the publisher degrades its stride
        # when its share of the budget is exceeded; this makes the residual
        # visible to the escalation ladder too).
        agent = getattr(self.measurement, "agent", None)
        if agent is not None:
            self._window_cost += float(agent.take_publish_cost_ns())

        now = time.perf_counter_ns()
        elapsed = now - self._window_start
        if elapsed < self.min_window_ns or self._window_pairs < self.min_window_pairs:
            return
        residual = self._excluded_rate * self._filtered_pair_cost(
            self._current_state()
        ) * (elapsed / 1e9)
        overhead = self._overhead_fraction(self._window_cost + residual, elapsed)
        acted = False
        if overhead > self.budget and not self.frozen:
            window_s = elapsed / 1e9
            total_s = max((now - self._t_open) / 1e9, window_s)
            cum_rate = (self._cum_pairs + self._window_pairs) / total_s
            kept_rate = max(self._window_pairs / window_s, cum_rate)
            acted = self._escalate(overhead, kept_rate, now)
        if acted or overhead <= self.budget:
            self._close_window(now)

    # -- watchdog (stall safety net) ----------------------------------------

    def _watchdog_loop(self) -> None:
        # The watchdog is measurement infrastructure: clear any per-thread
        # hooks the instrumenter's thread-entry installed (Score-P's runtime
        # never records itself).  Left hooked, the watchdog's own
        # threading.* calls would fill a buffer and could drive the *first*
        # escalation off the governor's self-inflicted cost — excluding
        # threading regions and downgrading before the application's first
        # flush ever arrives.  (Under ``sys.monitoring`` hooks are global,
        # not per-thread; the tick's few calls per period are noise there.)
        sys.setprofile(None)
        sys.settrace(None)
        while not self._watchdog_stop.wait(self.watchdog_s):
            if self.frozen:
                return
            try:
                self._tick()
            except Exception:  # pragma: no cover - never kill the app
                return

    def _tick(self) -> None:
        """Between-flush evaluation from live buffer growth.

        The control loop is flush-driven, but an escalation can collapse the
        event rate so far that the next flush never comes (everything
        excluded, or the sampler's period raised) while residual hook cost
        still exceeds the budget — the model that justified stopping there
        was built from one noisy window.  The watchdog reads ``len()`` of
        the live buffers (no flushing, no per-event cost) to measure the
        *actual* post-action event rate and re-escalates if it proves the
        projection wrong.  It only ever runs after the first flush-driven
        action, so region accounting stays flush-granular; and a swap it
        performs installs the new hook only on threads started afterwards
        (pre-existing threads' stale callbacks self-remove — losing coverage
        there errs on the cheap side, which is the governor's mandate).
        """
        if not self.actions:
            return
        measurement = self.measurement
        with measurement._flush_lock:
            if self.frozen:
                return
            now = time.perf_counter_ns()
            dt_ns = now - self._tick_t
            if dt_ns < self.min_window_ns:
                return
            inst = measurement.instrumenter
            with measurement._buffers_lock:
                buffers = list(measurement._buffers)
            total = sum(len(b) for b in buffers) + sum(
                getattr(b, "n_flushed", 0) for b in buffers
            )
            nfiltered = inst.filtered_calls()
            if inst is not self._tick_inst:
                # Swapped instrumenter: its filtered counter restarted at 0.
                self._tick_inst = inst
                self._tick_filtered = 0
            delta = max(total - self._tick_events, 0)
            delta_f = max(nfiltered - self._tick_filtered, 0)
            self._tick_events = total
            self._tick_filtered = nfiltered
            self._tick_t = now
            state = self._current_state()
            mult = max(inst.cost_multiplier(), 1.0)
            dt_s = dt_ns / 1e9
            # Buffered events per call pair, as observed in real flushes:
            # dividing by a flat 2 would overestimate the pair rate of a
            # line-dominated settrace stream by the lines-per-call factor.
            ev_per_pair = (
                self._ev_total / self._ev_enters if self._ev_enters else 2.0
            )
            recorded_rate = (delta / max(ev_per_pair, 2.0)) * mult / dt_s
            filtered_rate = delta_f * mult / dt_s
            cost_rate = recorded_rate * self._pair_cost(state) + (
                filtered_rate * self._filtered_pair_cost(state)
            )
            overhead = self._overhead_fraction(cost_rate, 1e9)
            if overhead > self.budget:
                # The measured filtered rate supersedes the frozen
                # exclusion-time estimate for this decision.
                self._excluded_rate = max(self._excluded_rate, filtered_rate)
                if self._escalate(overhead, recorded_rate, now):
                    self._close_window(now)
                    # An escalation that swapped the instrumenter ran
                    # install() on *this* thread — re-assert the watchdog's
                    # never-instrumented invariant, or its own tick calls
                    # would feed back into the very rates it measures.
                    sys.setprofile(None)
                    sys.settrace(None)

    def _close_window(self, now: int) -> None:
        self._total_cost += self._window_cost
        self._cum_pairs += self._window_pairs
        self._fold_residual(now)
        self._window_cost = 0.0
        self._window_pairs = 0.0
        self._window_start = now

    def _fold_residual(self, now: int) -> None:
        dt = max(now - self._residual_mark, 0)
        self._total_residual += self._excluded_rate * self._filtered_pair_cost(
            self._current_state()
        ) * (dt / 1e9)
        self._residual_mark = now

    # -- escalation ---------------------------------------------------------

    def _offenders(self, exclude_ids: set) -> List[int]:
        """Candidate regions, most expensive first: high-frequency,
        short-duration, not user-annotated, not already excluded.

        Short-duration means the fastest observed leaf span is under the
        cap; regions never seen as a leaf are skipped — once their callees
        are excluded they become leaves in later batches and turn eligible
        (the ladder's downgrade rungs cover the meantime).  Exception: a
        region the static plan predicted as an offender is pre-qualified
        (``seed_static_plan``) — the short-duration verdict was reached
        statically, so no observed-leaf evidence is required.

        The inverse static hint also applies: a region the concurrency
        analyzer marked as a wait point (lock acquire, join, blocking call)
        is never offered for exclusion.  Wait points spend their time
        blocked, so keeping them costs almost nothing, and dropping them
        would erase exactly the wait-state signal the concurrency report
        exists to surface."""
        n = self._visits.size
        regions = self.measurement.regions
        order = np.argsort(-self._est_cost[:n])
        out = []
        for rid in order:
            rid = int(rid)
            if self._visits[rid] <= 0 or rid in exclude_ids:
                continue
            try:
                region = regions.get(rid)
            except KeyError:
                continue
            if region.kind == KIND_USER:
                continue
            rname = f"{region.module}:{region.name}"
            if rname in self._plan_wait_points:
                continue
            if not self._leaf_min[rid] <= self.offender_max_leaf_ns:
                if rname not in self._plan_offenders:
                    continue
            out.append(rid)
        return out

    def _escalate(self, overhead: float, kept_rate_raw: float, now: int) -> bool:
        """Walk the ladder until the projected overhead fits the budget.

        ``kept_rate_raw`` is the caller's wall-clock estimate of recorded
        call pairs per second.  Rates must be per second of *useful* time,
        not wall time: once a rung removes instrumentation cost the
        application speeds up and the hook rate rises by the same factor, so
        projecting with the wall rate would under-estimate every cheaper
        rung and strand the ladder short of the budget (with too few events
        left to flush, there may be no later evaluation to correct it).
        Both the dilation correction and the calibrated cost are themselves
        estimates (and a window may straddle call-free phases that depress
        the apparent rate), so ladder-stop decisions additionally apply
        ``projection_safety``: erring toward one rung too many keeps the
        budget a guarantee rather than a coin flip — and the watchdog's
        measured rates catch any remaining under-shoot afterwards.
        """
        measurement = self.measurement
        state = self._current_state()
        if state.name == "none" and not self._excluded_rate:
            return False
        total_s = max((now - self._t_open) / 1e9, 1e-9)
        dilation = (1.0 + overhead) * self.projection_safety
        kept_rate = kept_rate_raw * dilation
        excl_rate = self._excluded_rate
        applied: List[Dict[str, Any]] = []

        # Rung a — exclude offenders (projection moves their rate to the
        # filtered fast path).
        new_excluded: List[int] = []
        for rid in self._offenders(self._excluded_rids):
            if self._projected(state, kept_rate, excl_rate) <= self.budget:
                break
            if len(new_excluded) >= self.max_excludes_per_action:
                break
            rate = float(self._visits_rep[rid]) / total_s * dilation
            rate = min(rate, kept_rate)
            kept_rate -= rate
            excl_rate += rate
            new_excluded.append(rid)
        if new_excluded:
            regions = measurement.regions
            patterns = []
            names = []
            for rid in new_excluded:
                region = regions.get(rid)
                patterns.append(
                    f"{_fnmatch_escape(region.module)}.{_fnmatch_escape(region.name)}"
                )
                names.append(f"{region.module}:{region.name}")
            measurement.filter.add_runtime_excludes(patterns)
            invalidated = regions.refilter()
            self._excluded_rids.update(new_excluded)
            self._fold_residual(now)
            self._excluded_rate = excl_rate
            applied.append(
                {
                    "kind": "exclude_regions",
                    "regions": names,
                    "patterns": patterns,
                    "invalidated_handles": len(invalidated),
                }
            )

        # Rungs b/c — raise the sampling period, then downgrade, projecting
        # each step; a downgrade to the sampler re-enters the period rung.
        target = _LadderState(state.name, state.period)
        for _ in range(32):
            if self._projected(target, kept_rate, excl_rate) <= self.budget:
                break
            if target.name == "sampling" and 0 < target.period < self.max_period:
                target.period = min(target.period * 2, self.max_period)
                continue
            down = INSTRUMENTERS[target.name].downgrade_to if target.name else None
            if down is None:
                break
            target = _LadderState(
                down,
                measurement.config.sampling_period if down == "sampling" else 0,
            )
        if not new_excluded and target == state:
            # The projection model claims the current state fits, yet the
            # *measured* overhead is over budget — the model's rate estimate
            # is wrong (noisy window, call-free phase).  Trust the
            # measurement and force one rung of progress; the next window
            # (or the watchdog) re-evaluates from there.
            if state.name == "sampling" and 0 < state.period < self.max_period:
                target = _LadderState(state.name, min(state.period * 2, self.max_period))
            else:
                down = INSTRUMENTERS[state.name].downgrade_to
                if down is not None:
                    target = _LadderState(
                        down,
                        measurement.config.sampling_period if down == "sampling" else 0,
                    )
        if target.name != state.name:
            measurement.swap_instrumenter(
                target.name,
                **({"period": target.period} if target.name == "sampling" else {}),
            )
            applied.append(
                {
                    "kind": "downgrade_instrumenter",
                    "from": state.name,
                    "to": target.name,
                    "period": target.period or None,
                }
            )
        elif target.period != state.period and target.period:
            if measurement.instrumenter.set_period(target.period):
                applied.append(
                    {
                        "kind": "raise_period",
                        "from": state.period,
                        "to": target.period,
                    }
                )

        if not applied:
            return False
        self._push_state(now)  # batches recorded before `now` keep old costs
        projected = self._projected(target, kept_rate, excl_rate)
        self.actions.append(
            {
                "t_ns": now - self._t_open,
                "window_overhead": round(overhead, 6),
                "projected_overhead": round(projected, 6),
                "budget": self.budget,
                "steps": applied,
            }
        )
        return True

    # -- report -------------------------------------------------------------

    def document(self) -> Dict[str, Any]:
        now = time.perf_counter_ns()
        self._close_window(now)
        elapsed = max(now - self._t_open, 1)
        est_cost = self._total_cost + self._total_residual
        regions = self.measurement.regions
        n = self._visits.size
        rows = []
        for rid in np.argsort(-self._est_cost[:n]):
            rid = int(rid)
            if self._visits[rid] <= 0:
                continue
            try:
                region = regions.get(rid)
            except KeyError:
                continue
            rows.append(
                {
                    "region": f"{region.module}:{region.name}",
                    "kind": region.kind,
                    "visits": int(self._visits[rid]),
                    "visits_represented": float(self._visits_rep[rid]),
                    "leaf_excl_ns": float(self._leaf_ns[rid]),
                    "leaf_min_ns": (
                        float(self._leaf_min[rid])
                        if np.isfinite(self._leaf_min[rid])
                        else None
                    ),
                    "est_cost_ns": float(self._est_cost[rid]),
                    "excluded": rid in self._excluded_rids,
                }
            )
            if len(rows) >= 50:
                break
        state = self._current_state()
        return stamp({
            "budget": self.budget,
            "calibration": asdict(self.calibration) if self.calibration else None,
            "final_instrumenter": {"name": state.name, "period": state.period or None},
            "actions": self.actions,
            "regions": rows,
            "estimate": {
                "elapsed_ns": int(elapsed),
                "recorded_cost_ns": round(self._total_cost, 1),
                "residual_cost_ns": round(self._total_residual, 1),
                "overhead_fraction": round(
                    float(self._overhead_fraction(est_cost, elapsed)), 6
                ),
                "under_budget": bool(
                    self._overhead_fraction(est_cost, elapsed) <= self.budget
                ),
            },
            "suggested_filter": self.suggest_filter(),
            # None when no plan seeded this run — report renders the
            # plan-vs-observed section only for plan-seeded runs.
            "static_plan": self._plan_meta,
        })

    def suggest_filter(self) -> str:
        """Filter spec for the next run: the base filter's own rules, plus —
        as absolute ``exclude!`` rules — everything excluded at runtime and
        any remaining offender whose estimated cost alone eats >=10% of the
        budget.  Round-trips through ``Filter.from_spec`` with the base
        semantics intact (an include-only allow-list stays one), so a single
        ``--filter`` replaces both."""
        flt = self.measurement.filter
        patterns = list(dict.fromkeys(flt.runtime_exclude))
        elapsed = max(time.perf_counter_ns() - self._t_open, 1)
        threshold = 0.1 * self.budget * elapsed
        regions = self.measurement.regions
        extra = []
        for rid in np.argsort(-self._est_cost[: self._visits.size]):
            rid = int(rid)
            if rid in self._excluded_rids or self._visits[rid] <= 0:
                continue
            if self._est_cost[rid] < threshold:
                break
            if not self._leaf_min[rid] <= self.offender_max_leaf_ns:
                continue
            try:
                region = regions.get(rid)
            except KeyError:
                continue
            if region.kind == KIND_USER:
                continue
            extra.append(
                f"{_fnmatch_escape(region.module)}.{_fnmatch_escape(region.name)}"
            )
        for pat in extra:
            if pat not in patterns:
                patterns.append(pat)
        return Filter(
            include=list(flt.include),
            exclude=list(flt.exclude),
            runtime_exclude=patterns,
        ).to_spec()

    def close(self, run_dir: str) -> Dict[str, Any]:
        self.frozen = True
        self.stop_watchdog()
        doc = self.document()
        with open(os.path.join(run_dir, ARTIFACT), "w") as fh:
            json.dump(doc, fh, indent=1, allow_nan=False)
        return doc


def load_governor(run_dir: str) -> Optional[Dict[str, Any]]:
    """Read a run's governor.json (``None`` when no governor ran)."""
    path = os.path.join(run_dir, ARTIFACT)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


# -- stable document accessors ------------------------------------------------
#
# Consumers of governor.json (the analysis renderer, the HTML report, merge's
# cross-rank section) read through these rather than walking the raw action
# dicts, so the serialized step layout can evolve behind one seam.


def describe_step(step: Dict[str, Any]) -> str:
    """One-line human description of a single escalation step."""
    kind = step.get("kind", "?")
    if kind == "exclude_regions":
        regions = step.get("regions", [])
        head = ", ".join(regions[:3]) + ("…" if len(regions) > 3 else "")
        return f"excluded {len(regions)} regions ({head})"
    if kind == "raise_period":
        return f"period {step.get('from')} -> {step.get('to')}"
    if kind == "downgrade_instrumenter":
        return f"{step.get('from')} -> {step.get('to')}"
    return kind


def action_rows(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Flattened escalation timeline of a governor.json document.  Each row:
    ``{"t_ns", "window_overhead", "projected_overhead", "steps": [str, ...]}``
    with steps already rendered through :func:`describe_step`."""
    rows = []
    for action in doc.get("actions", []):
        rows.append(
            {
                "t_ns": int(action.get("t_ns", 0)),
                "window_overhead": float(action.get("window_overhead", 0.0)),
                "projected_overhead": float(action.get("projected_overhead", 0.0)),
                "steps": [describe_step(s) for s in action.get("steps", [])],
            }
        )
    return rows


def region_rows(doc: Dict[str, Any], top: int = 0) -> List[Dict[str, Any]]:
    """Per-region cost rows of a governor.json document (already sorted by
    estimated instrumentation cost by the writer).  ``top`` > 0 truncates."""
    rows = [
        {
            "region": r.get("region", "?"),
            "kind": r.get("kind", "?"),
            "visits": int(r.get("visits", 0)),
            "leaf_excl_ns": float(r.get("leaf_excl_ns", 0.0)),
            "est_cost_ns": float(r.get("est_cost_ns", 0.0)),
            "excluded": bool(r.get("excluded", False)),
        }
        for r in doc.get("regions", [])
    ]
    return rows[:top] if top > 0 else rows


def estimate_overview(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Headline numbers of a governor.json document: budget, calibration,
    final instrumenter, distortion estimate, suggested filter spec."""
    cal = doc.get("calibration") or {}
    final = doc.get("final_instrumenter") or {}
    est = doc.get("estimate") or {}
    return {
        "budget": float(doc.get("budget", 0.0)),
        "cost_full_ns": float(cal.get("cost_full_ns", 0.0)),
        "cost_filtered_ns": float(cal.get("cost_filtered_ns", 0.0)),
        "calibrated_instrumenter": cal.get("instrumenter", "?"),
        "final_instrumenter": final.get("name", "?"),
        "final_period": final.get("period"),
        "actions": len(doc.get("actions", [])),
        "overhead_fraction": float(est.get("overhead_fraction", 0.0)),
        "under_budget": bool(est.get("under_budget", True)),
        "elapsed_ns": int(est.get("elapsed_ns", 0)),
        "suggested_filter": doc.get("suggested_filter", ""),
    }
