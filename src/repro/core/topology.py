"""Process topology — who am I in the parallel job?

The paper's measurement system must "cope with highly parallel programs"
across core, node, and inter-node levels; the Python-side equivalent of
Score-P's location/location-group model is one :class:`ProcessTopology` per
process: (rank, world size, local rank, mesh shape).  Everything that used
to take a bare ``rank: int`` — measurement config, run-dir naming, trace
merge, the dist modules' event annotations — threads this object instead,
so no layer reaches into globals or re-parses launcher env vars.

This module is deliberately jax-free: the monitoring core must import
without a device runtime (paper §2: the bootstrap runs before the target
application's imports).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Mapping, Optional, Tuple

ENV_PREFIX = "REPRO_MONITOR_"

#: Launcher variables consulted (first hit wins), mirroring Score-P's MPP
#: detection order: our own bootstrap env, JAX distributed, Open MPI, PMI,
#: then the generic torchrun-style names.
_RANK_VARS = (ENV_PREFIX + "RANK", "JAX_PROCESS_INDEX", "OMPI_COMM_WORLD_RANK",
              "PMI_RANK", "RANK")
_WORLD_VARS = (ENV_PREFIX + "WORLD_SIZE", "JAX_NUM_PROCESSES", "OMPI_COMM_WORLD_SIZE",
               "PMI_SIZE", "WORLD_SIZE")
_LOCAL_VARS = (ENV_PREFIX + "LOCAL_RANK", "OMPI_COMM_WORLD_LOCAL_RANK", "LOCAL_RANK")
_MESH_VAR = ENV_PREFIX + "MESH"


def _first_int(environ: Mapping[str, str], names, default: int) -> int:
    for name in names:
        value = environ.get(name)
        if value in (None, ""):
            continue
        try:
            return int(value)
        except ValueError:
            continue
    return default


@dataclasses.dataclass(frozen=True)
class ProcessTopology:
    """Immutable description of this process's place in the job."""

    rank: int = 0
    world_size: int = 1
    local_rank: int = 0
    mesh_shape: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.rank < 0 or self.local_rank < 0 or self.world_size < 1:
            raise ValueError(f"invalid topology {self}")

    # -- identity ------------------------------------------------------------

    @property
    def is_primary(self) -> bool:
        return self.rank == 0

    @property
    def n_devices_expected(self) -> int:
        n = 1
        for d in self.mesh_shape:
            n *= d
        return n

    def tag(self) -> str:
        """Run-dir / display tag: ``r3of8`` (``r0`` for single-process)."""
        if self.world_size <= 1:
            return f"r{self.rank}"
        return f"r{self.rank}of{self.world_size}"

    def as_dict(self) -> Dict[str, object]:
        return {
            "rank": self.rank,
            "world_size": self.world_size,
            "local_rank": self.local_rank,
            "mesh_shape": list(self.mesh_shape),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "ProcessTopology":
        """Inverse of :meth:`as_dict` (artifact round-trip: run ``meta.json``
        / ``defs.json`` embed the dict form; the export engine reads it back)."""
        rank = int(d.get("rank", 0) or 0)
        world = int(d.get("world_size", 1) or 1)
        local = int(d.get("local_rank", rank) or 0)
        mesh = tuple(int(x) for x in (d.get("mesh_shape") or ()))
        return cls(rank=rank, world_size=max(world, rank + 1),
                   local_rank=local, mesh_shape=mesh)

    # -- env round-trip (two-phase bootstrap, fork-based launchers) ----------

    @classmethod
    def from_env(cls, environ: Optional[Mapping[str, str]] = None) -> "ProcessTopology":
        e = os.environ if environ is None else environ
        rank = _first_int(e, _RANK_VARS, 0)
        world = _first_int(e, _WORLD_VARS, 1)
        local = _first_int(e, _LOCAL_VARS, rank)
        mesh = parse_mesh_shape(e.get(_MESH_VAR, ""))
        return cls(rank=rank, world_size=max(world, rank + 1), local_rank=local, mesh_shape=mesh)

    def to_env(self) -> Dict[str, str]:
        env = {
            ENV_PREFIX + "RANK": str(self.rank),
            ENV_PREFIX + "WORLD_SIZE": str(self.world_size),
            ENV_PREFIX + "LOCAL_RANK": str(self.local_rank),
        }
        if self.mesh_shape:
            env[_MESH_VAR] = format_mesh_shape(self.mesh_shape)
        return env

    # -- mesh binding (duck-typed: anything with .shape mapping works) -------

    def with_mesh(self, mesh) -> "ProcessTopology":
        """Topology annotated with the device-mesh shape this process drives."""
        shape = getattr(mesh, "shape", mesh)
        if hasattr(shape, "values"):
            shape = tuple(shape.values())
        return dataclasses.replace(self, mesh_shape=tuple(int(d) for d in shape))

    def with_rank(self, rank: int) -> "ProcessTopology":
        return dataclasses.replace(
            self, rank=rank, world_size=max(self.world_size, rank + 1)
        )


def parse_mesh_shape(spec: str) -> Tuple[int, ...]:
    """Parse ``"2x16x16"`` (or ``"2,16,16"``) into ``(2, 16, 16)``."""
    spec = spec.strip()
    if not spec:
        return ()
    parts = spec.replace(",", "x").split("x")
    try:
        shape = tuple(int(p) for p in parts if p)
    except ValueError:
        return ()
    return shape if all(d > 0 for d in shape) else ()


def format_mesh_shape(shape: Tuple[int, ...]) -> str:
    return "x".join(str(d) for d in shape)
