"""Profile analysis / diffing — the offline-analysis step of the paper's
workflow (Score-P profiles are compared across runs in Cube/Vampir; here the
comparison is programmatic and drives the §Perf loop).

    PYTHONPATH=src python -m repro.core.analysis diff RUN_A RUN_B
    PYTHONPATH=src python -m repro.core.analysis top RUN_DIR
    PYTHONPATH=src python -m repro.core.analysis merge-summary SUMMARY_JSON
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple


def load_profile(run_dir: str) -> Dict[str, Any]:
    with open(os.path.join(run_dir, "profile.json")) as fh:
        return json.load(fh)


def flat_metrics(profile: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    return profile.get("flat", {})


def hotspots(run_dir: str, top: int = 20) -> List[Tuple[str, Dict[str, float]]]:
    flat = flat_metrics(load_profile(run_dir))
    return sorted(flat.items(), key=lambda kv: -kv[1]["excl_ns"])[:top]


def diff_profiles(run_a: str, run_b: str, min_ns: int = 0) -> List[Dict[str, Any]]:
    """Per-region exclusive-time deltas between two runs (B - A).

    Regions present in only one run are reported with the other side at 0 —
    exactly what a before/after optimization comparison needs."""
    a = flat_metrics(load_profile(run_a))
    b = flat_metrics(load_profile(run_b))
    rows = []
    for name in sorted(set(a) | set(b)):
        ea = a.get(name, {}).get("excl_ns", 0)
        eb = b.get(name, {}).get("excl_ns", 0)
        va = a.get(name, {}).get("visits", 0)
        vb = b.get(name, {}).get("visits", 0)
        if max(ea, eb) < min_ns:
            continue
        rows.append(
            {
                "region": name,
                "excl_ns_a": ea,
                "excl_ns_b": eb,
                "delta_ns": eb - ea,
                # Regions new in B have no meaningful ratio; ``None`` keeps
                # the row strictly JSON-serializable (float("inf") is not).
                "ratio": (eb / ea) if ea else None if eb else 1.0,
                "visits_a": va,
                "visits_b": vb,
            }
        )
    rows.sort(key=lambda r: -abs(r["delta_ns"]))
    return rows


def render_diff(rows: List[Dict[str, Any]], top: int = 25) -> str:
    out = [f"{'delta_ms':>10s} {'a_ms':>10s} {'b_ms':>10s} {'ratio':>7s}  region"]
    for r in rows[:top]:
        ratio = "new" if r["ratio"] is None else f"{r['ratio']:.2f}"
        out.append(
            f"{r['delta_ns'] / 1e6:10.3f} {r['excl_ns_a'] / 1e6:10.3f} "
            f"{r['excl_ns_b'] / 1e6:10.3f} {ratio:>7s}  {r['region']}"
        )
    return "\n".join(out)


def render_merge_summary(summary: Dict[str, Any]) -> str:
    """Human-readable view of a ``merge_runs`` summary, including the
    streaming export engine's writer stats (events/bytes/chunks)."""
    out = [f"{'rank':>5s} {'events':>10s}  run_dir"]
    for r in summary.get("ranks", []):
        out.append(f"{r['rank']:5d} {r['events']:10d}  {r['run_dir']}")
    for d in summary.get("dropped_runs", []):
        out.append(f"{d['rank']:5d} {'DROPPED':>10s}  {d['run_dir']} (stale duplicate)")
    out.append(
        f"total {summary.get('total_events', 0)} span events, "
        f"world_size {summary.get('world_size', 1)}"
    )
    export = summary.get("export") or {}
    if export:
        mb = export.get("bytes", 0) / 1e6
        out.append(
            f"export: {export.get('events', 0)} events "
            f"({export.get('meta_events', 0)} metadata, "
            f"{export.get('counter_events', 0)} counters) in "
            f"{export.get('chunks', 0)} chunks "
            f"(max {export.get('max_chunk_events', 0)} events/chunk), "
            f"{mb:.1f} MB, {export.get('events_per_s', 0.0):,.0f} events/s"
        )
    if summary.get("out"):
        out.append(f"merged trace: {summary['out']}")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.analysis")
    sub = p.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="per-region exclusive-time delta (B - A)")
    d.add_argument("run_a")
    d.add_argument("run_b")
    d.add_argument("--top", type=int, default=25)
    t = sub.add_parser("top", help="hotspot table for one run")
    t.add_argument("run_dir")
    t.add_argument("--top", type=int, default=20)
    m = sub.add_parser("merge-summary", help="render a merge summary JSON")
    m.add_argument("summary", help="merged_trace_summary.json written by repro.core.merge")
    ns = p.parse_args(argv)
    if ns.cmd == "diff":
        print(render_diff(diff_profiles(ns.run_a, ns.run_b), ns.top))
    elif ns.cmd == "merge-summary":
        with open(ns.summary) as fh:
            print(render_merge_summary(json.load(fh)))
    else:
        for name, vals in hotspots(ns.run_dir, ns.top):
            print(f"{vals['excl_ns'] / 1e6:12.3f} ms excl {vals['visits']:10d}x  {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
