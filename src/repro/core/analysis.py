"""Profile analysis / diffing — the offline-analysis step of the paper's
workflow (Score-P profiles are compared across runs in Cube/Vampir; here the
comparison is programmatic and drives the §Perf loop).

    PYTHONPATH=src python -m repro.core.analysis diff RUN_A RUN_B [--min-ns N]
    PYTHONPATH=src python -m repro.core.analysis top RUN_DIR
    PYTHONPATH=src python -m repro.core.analysis memory RUN_DIR
    PYTHONPATH=src python -m repro.core.analysis memory-diff RUN_A RUN_B
    PYTHONPATH=src python -m repro.core.analysis merge-summary SUMMARY_JSON
    PYTHONPATH=src python -m repro.core.analysis governor RUN_DIR
    PYTHONPATH=src python -m repro.core.analysis suggest-filter RUN_DIR
    PYTHONPATH=src python -m repro.core.analysis report RUN_DIR [--diff BASE]
    PYTHONPATH=src python -m repro.core.analysis plan PATHS... [--out FILE]
    PYTHONPATH=src python -m repro.core.analysis lint PATHS...
    PYTHONPATH=src python -m repro.core.analysis concurrency PATHS... [--out FILE]
    PYTHONPATH=src python -m repro.core.analysis fleet ROOT... [--out FILE]
    PYTHONPATH=src python -m repro.core.analysis fleet gate TRAJ [--append DIR]

Every subcommand follows one error convention: a missing/unreadable artifact
(or a bad path handed to ``plan``/``lint``/``concurrency``/``fleet``) raises
:class:`MissingArtifact`, which the CLI renders as a one-line ``error: ...``
on stderr and **exit code 2** (so scripts can tell "wrong substrate set" from
real failures, which keep their tracebacks).  ``lint``, ``concurrency`` and
``fleet`` additionally exit **1** when violations/findings/confirmed
regressions remain and **0** when clean — the same contract as every
mainstream linter, so they drop into CI gates unchanged.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple


# Canonical home is repro.core.schema (single class identity even when this
# module runs as __main__ under `python -m`); re-exported here because the
# exit-2 convention is this CLI's contract and callers import it from here.
from .schema import MissingArtifact  # noqa: F401  (re-export)


def _load_artifact(run_dir: str, artifact: str, substrate: str) -> Dict[str, Any]:
    path = os.path.join(run_dir, artifact)
    if not os.path.exists(path):
        raise MissingArtifact(
            f"no {artifact} in {run_dir or '.'} — was the {substrate!r} substrate "
            f"enabled for this run? (REPRO_MONITOR_SUBSTRATES / rmon.init(substrates=...))"
        )
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as exc:
        # Unreadable == missing for the exit-code contract: a truncated
        # artifact (crashed writer) should produce the one-line error, not
        # a traceback.
        raise MissingArtifact(f"unreadable {artifact} in {run_dir or '.'}: {exc}") from exc


def load_profile(run_dir: str) -> Dict[str, Any]:
    return _load_artifact(run_dir, "profile.json", "profiling")


def load_memory_doc(run_dir: str) -> Dict[str, Any]:
    return _load_artifact(run_dir, "memory.json", "memory")


def flat_metrics(profile: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    return profile.get("flat", {})


def hotspots(run_dir: str, top: int = 20) -> List[Tuple[str, Dict[str, float]]]:
    flat = flat_metrics(load_profile(run_dir))
    return sorted(flat.items(), key=lambda kv: -kv[1]["excl_ns"])[:top]


def diff_profiles(run_a: str, run_b: str, min_ns: int = 0) -> List[Dict[str, Any]]:
    """Per-region exclusive-time deltas between two runs (B - A).

    Regions present in only one run are reported with the other side at 0 —
    exactly what a before/after optimization comparison needs."""
    a = flat_metrics(load_profile(run_a))
    b = flat_metrics(load_profile(run_b))
    rows = []
    for name in sorted(set(a) | set(b)):
        ea = a.get(name, {}).get("excl_ns", 0)
        eb = b.get(name, {}).get("excl_ns", 0)
        va = a.get(name, {}).get("visits", 0)
        vb = b.get(name, {}).get("visits", 0)
        if max(ea, eb) < min_ns:
            continue
        rows.append(
            {
                "region": name,
                "excl_ns_a": ea,
                "excl_ns_b": eb,
                "delta_ns": eb - ea,
                # Regions new in B have no meaningful ratio; ``None`` keeps
                # the row strictly JSON-serializable (float("inf") is not).
                "ratio": (eb / ea) if ea else None if eb else 1.0,
                "visits_a": va,
                "visits_b": vb,
            }
        )
    rows.sort(key=lambda r: -abs(r["delta_ns"]))
    return rows


def render_diff(rows: List[Dict[str, Any]], top: int = 25) -> str:
    out = [f"{'delta_ms':>10s} {'a_ms':>10s} {'b_ms':>10s} {'ratio':>7s}  region"]
    for r in rows[:top]:
        ratio = "new" if r["ratio"] is None else f"{r['ratio']:.2f}"
        out.append(
            f"{r['delta_ns'] / 1e6:10.3f} {r['excl_ns_a'] / 1e6:10.3f} "
            f"{r['excl_ns_b'] / 1e6:10.3f} {ratio:>7s}  {r['region']}"
        )
    return "\n".join(out)


def memory_hotspots(run_dir: str, top: int = 20) -> List[Tuple[str, Dict[str, Any]]]:
    """Top allocating regions of one run, by attributed alloc bytes."""
    regions = load_memory_doc(run_dir).get("heap", {}).get("regions", {})
    return sorted(regions.items(), key=lambda kv: -kv[1].get("alloc_bytes", 0))[:top]


def render_memory(doc: Dict[str, Any], top: int = 20) -> str:
    """Human-readable memory report: top-allocators table + system summary.

    Reads through the stable :mod:`repro.core.memsys` document accessors —
    the same seam the HTML report uses — so renderer and report cannot
    disagree about the memory.json layout."""
    from .memsys import overview, region_rows

    out = [f"{'alloc_mb':>10s} {'net_mb':>10s} {'blocks':>10s} {'flushes':>8s}  region"]
    for row in region_rows(doc, top=top):
        out.append(
            f"{row['alloc_bytes'] / 1e6:10.2f} {row['net_bytes'] / 1e6:10.2f} "
            f"{row['alloc_blocks']:10d} {row['flushes']:8d}  {row['region']}"
        )
    ov = overview(doc)
    if ov["dropped_regions"]:
        out.append(f"(+{ov['dropped_regions']} regions beyond the top-N cut)")
    out.append(
        f"heap: start {ov['heap_start_bytes'] / 1e6:.1f} MB, "
        f"end {ov['heap_end_bytes'] / 1e6:.1f} MB, "
        f"peak {ov['heap_peak_bytes'] / 1e6:.1f} MB (tracemalloc)"
    )
    out.append(
        f"rss:  peak {ov['rss_peak_bytes'] / 1e6:.1f} MB, "
        f"end {ov['rss_end_bytes'] / 1e6:.1f} MB "
        f"({ov['rss_samples']} samples via {ov['rss_source']})"
    )
    out.append(
        f"gc:   {ov['gc_collections']} collections, "
        f"{ov['gc_pause_ns_total'] / 1e6:.2f} ms total pause, "
        f"{ov['gc_collected']} objects collected"
    )
    return "\n".join(out)


def diff_memory(run_a: str, run_b: str, min_bytes: int = 0) -> List[Dict[str, Any]]:
    """Per-region attributed-allocation deltas between two runs (B - A)."""
    a = load_memory_doc(run_a).get("heap", {}).get("regions", {})
    b = load_memory_doc(run_b).get("heap", {}).get("regions", {})
    rows = []
    for name in sorted(set(a) | set(b)):
        aa = a.get(name, {}).get("alloc_bytes", 0)
        ab = b.get(name, {}).get("alloc_bytes", 0)
        if max(aa, ab) < min_bytes:
            continue
        rows.append(
            {
                "region": name,
                "alloc_bytes_a": aa,
                "alloc_bytes_b": ab,
                "delta_bytes": ab - aa,
                "ratio": (ab / aa) if aa else None if ab else 1.0,
                "net_bytes_a": a.get(name, {}).get("net_bytes", 0),
                "net_bytes_b": b.get(name, {}).get("net_bytes", 0),
            }
        )
    rows.sort(key=lambda r: -abs(r["delta_bytes"]))
    return rows


def render_memory_diff(rows: List[Dict[str, Any]], top: int = 25) -> str:
    out = [f"{'delta_mb':>10s} {'a_mb':>10s} {'b_mb':>10s} {'ratio':>7s}  region"]
    for r in rows[:top]:
        ratio = "new" if r["ratio"] is None else f"{r['ratio']:.2f}"
        out.append(
            f"{r['delta_bytes'] / 1e6:10.2f} {r['alloc_bytes_a'] / 1e6:10.2f} "
            f"{r['alloc_bytes_b'] / 1e6:10.2f} {ratio:>7s}  {r['region']}"
        )
    return "\n".join(out)


def load_governor_doc(run_dir: str) -> Dict[str, Any]:
    from .governor import load_governor

    doc = load_governor(run_dir)
    if doc is None:
        raise MissingArtifact(
            f"no readable governor.json in {run_dir or '.'} — was the overhead "
            f"governor enabled for this run? (--budget / REPRO_MONITOR_BUDGET > 0)"
        )
    return doc


def render_governor(doc: Dict[str, Any], top: int = 15) -> str:
    """Human-readable governor report: calibration, actions, cost table.

    Reads through the stable :mod:`repro.core.governor` document accessors
    (``action_rows`` / ``region_rows``) shared with the HTML report."""
    from .governor import action_rows
    from .governor import region_rows as governor_region_rows

    out: List[str] = []
    cal = doc.get("calibration") or {}
    final = doc.get("final_instrumenter") or {}
    out.append(
        f"budget {doc.get('budget', 0.0):.1%} dilation; calibrated "
        f"{cal.get('instrumenter', '?')} at {cal.get('cost_full_ns', 0.0):.0f} ns/pair "
        f"(filtered {cal.get('cost_filtered_ns', 0.0):.0f}, sampler base "
        f"{cal.get('sampling_base_ns', 0.0):.0f}) in {cal.get('probe_s', 0.0) * 1e3:.0f} ms"
    )
    period = f" (period {final['period']})" if final.get("period") else ""
    out.append(f"final instrumenter: {final.get('name', '?')}{period}")
    actions = action_rows(doc)
    out.append(f"actions: {len(actions)}")
    for a in actions:
        out.append(
            f"  @{a['t_ns'] / 1e6:9.1f} ms  overhead {a['window_overhead']:.1%} "
            f"-> projected {a['projected_overhead']:.1%}: {'; '.join(a['steps'])}"
        )
    out.append(f"{'est_cost_ms':>12s} {'leaf_ms':>10s} {'visits':>10s} {'x':>4s}  region")
    for row in governor_region_rows(doc, top=top):
        out.append(
            f"{row['est_cost_ns'] / 1e6:12.3f} {row['leaf_excl_ns'] / 1e6:10.3f} "
            f"{row['visits']:10d} {'EXCL' if row['excluded'] else '':>4s}  {row['region']}"
        )
    est = doc.get("estimate", {})
    out.append(
        f"estimated distortion: {est.get('overhead_fraction', 0.0):.2%} of useful time "
        f"({est.get('recorded_cost_ns', 0) / 1e6:.1f} ms recorded + "
        f"{est.get('residual_cost_ns', 0) / 1e6:.1f} ms filtered residual over "
        f"{est.get('elapsed_ns', 0) / 1e6:.0f} ms) — "
        + ("under budget" if est.get("under_budget") else "OVER budget")
    )
    if doc.get("suggested_filter"):
        out.append(f"suggested filter: {doc['suggested_filter']}")
    return "\n".join(out)


def suggest_filter_from_profile(
    profile: Dict[str, Any],
    cost_ns: float = 1500.0,
    max_mean_ns: float = 20_000.0,
    min_visits: int = 100,
) -> str:
    """Score-P-style filter suggestion from a profile alone (no governor).

    The scorep-score workflow, automated: regions that are high-frequency
    (``visits >= min_visits``) and short (mean exclusive time at most
    ``max_mean_ns``) are filter candidates, ranked by estimated
    instrumentation cost ``visits * cost_ns``.  ``cost_ns`` defaults to a
    conservative per-visit pair cost; a governed run's governor.json
    carries the calibrated value instead.
    """
    from .governor import _fnmatch_escape

    candidates = []
    for name, vals in flat_metrics(profile).items():
        module, _, func = name.partition(":")
        if not func:
            continue
        # User-annotated regions are never suggested for exclusion.  The
        # flat table carries the region kind (newer profiles); older
        # profiles fall back on the default user-region module name.
        if vals.get("kind", "user" if module == "user" else "python") == "user":
            continue
        visits = vals.get("visits", 0)
        if visits < min_visits:
            continue
        if vals.get("excl_ns", 0) / visits > max_mean_ns:
            continue
        candidates.append((visits * cost_ns, f"{_fnmatch_escape(module)}.{_fnmatch_escape(func)}"))
    candidates.sort(key=lambda c: -c[0])
    from .filtering import Filter

    return Filter(exclude=[pat for _, pat in candidates]).to_spec()


def render_merge_summary(summary: Dict[str, Any]) -> str:
    """Human-readable view of a ``merge_runs`` summary, including the
    streaming export engine's writer stats (events/bytes/chunks)."""
    out = [f"{'rank':>5s} {'events':>10s}  run_dir"]
    for r in summary.get("ranks", []):
        out.append(f"{r['rank']:5d} {r['events']:10d}  {r['run_dir']}")
    for d in summary.get("dropped_runs", []):
        out.append(f"{d['rank']:5d} {'DROPPED':>10s}  {d['run_dir']} (stale duplicate)")
    out.append(
        f"total {summary.get('total_events', 0)} span events, "
        f"world_size {summary.get('world_size', 1)}"
    )
    export = summary.get("export") or {}
    if export:
        mb = export.get("bytes", 0) / 1e6
        out.append(
            f"export: {export.get('events', 0)} events "
            f"({export.get('meta_events', 0)} metadata, "
            f"{export.get('counter_events', 0)} counters) in "
            f"{export.get('chunks', 0)} chunks "
            f"(max {export.get('max_chunk_events', 0)} events/chunk), "
            f"{mb:.1f} MB, {export.get('events_per_s', 0.0):,.0f} events/s"
        )
    memory = summary.get("memory") or {}
    if memory:
        peak = memory.get("peak_rss", {})
        imb = peak.get("imbalance")
        out.append(
            f"memory: peak RSS max {peak.get('max_bytes', 0) / 1e6:.1f} MB "
            f"(rank {peak.get('max_rank')}) / min {peak.get('min_bytes', 0) / 1e6:.1f} MB "
            f"(rank {peak.get('min_rank')}), imbalance "
            + (f"{imb:.2f}x" if imb else "n/a")
            + f", gc pause {memory.get('gc_pause_ns_total', 0) / 1e6:.2f} ms total"
        )
        for r in memory.get("ranks", []):
            tops = ", ".join(
                f"{t['region']} ({t['alloc_bytes'] / 1e6:.1f} MB)"
                for t in r.get("top_regions", [])[:3]
            )
            out.append(
                f"  rank {r['rank']}: peak RSS {r['peak_rss_bytes'] / 1e6:.1f} MB, "
                f"heap {r['peak_heap_bytes'] / 1e6:.1f} MB, "
                f"gc {r['gc_pause_ns'] / 1e6:.2f} ms"
                + (f"; top: {tops}" if tops else "")
            )
    profile = summary.get("profile") or {}
    if profile.get("regions"):
        imb = profile.get("imbalance") or {}
        worst = sorted(imb.items(), key=lambda kv: -kv[1])[:3]
        out.append(
            f"profile: {len(profile['regions'])} regions across "
            f"{len(profile.get('ranks', []))} ranks"
            + (
                "; worst imbalance (max/mean): "
                + ", ".join(f"{name} {v:.2f}x" for name, v in worst)
                if worst
                else ""
            )
        )
    governor = summary.get("governor") or {}
    if governor:
        out.append(
            f"governor: {governor.get('actions_total', 0)} actions across "
            f"{len(governor.get('ranks', []))} ranks, "
            f"{governor.get('ranks_over_budget', 0)} rank(s) over budget"
        )
        for r in governor.get("ranks", []):
            out.append(
                f"  rank {r['rank']}: {r['actions']} actions "
                f"({', '.join(r['action_kinds']) or 'none'}), final "
                f"{r['final_instrumenter']}, est overhead "
                f"{r['overhead_fraction']:.2%}"
            )
        if governor.get("suggested_filter"):
            out.append(f"  suggested filter (union): {governor['suggested_filter']}")
    if summary.get("out"):
        out.append(f"merged trace: {summary['out']}")
    return "\n".join(out)


def load_merge_summary(path: str) -> Dict[str, Any]:
    """Read a merge summary; ``path`` may be the JSON itself or the merge
    root directory containing ``merged_trace_summary.json``.  Raises
    :class:`MissingArtifact` (-> CLI exit 2) when absent or unreadable."""
    if os.path.isdir(path):
        path = os.path.join(path, "merged_trace_summary.json")
    if not os.path.exists(path):
        raise MissingArtifact(
            f"no merge summary at {path or '.'} — run "
            f"`python -m repro.core.merge <root>` first"
        )
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as exc:
        raise MissingArtifact(f"unreadable merge summary {path}: {exc}") from exc


def smoke_report(out_path: Optional[str] = None) -> str:
    """Self-contained report smoke: record a tiny instrumented run into a
    temp dir, generate report.html from it, and round-trip the embedded
    payload.  Used by ``analysis report --smoke`` in CI so the documented
    flow is *executed* on every push, not just described.  Returns the
    report path."""
    import shutil
    import tempfile

    from .measurement import MeasurementConfig, Measurement
    from .report import build_report, extract_payload, render_report

    tmp = tempfile.mkdtemp(prefix="repro-report-smoke-")
    # The throwaway run dir is removed on the way out; the report itself
    # lands outside it (default: one stable file in the temp root, so
    # repeated smoke runs overwrite rather than accumulate).
    out_path = out_path or os.path.join(
        tempfile.gettempdir(), "repro-report-smoke.html"
    )
    run_dir = os.path.join(tmp, "smoke-run")
    m = Measurement(
        MeasurementConfig(
            instrumenter="profile",
            substrates=("profiling", "tracing", "metrics", "memory"),
            run_dir=run_dir,
            experiment="report-smoke",
            memory_period=0.01,
        )
    )
    try:
        m.start()
        # The workload must not live in repro.core.* — the filter always
        # drops the measurement core's own regions — so compile it under a
        # synthetic module name.
        workload: Dict[str, Any] = {"__name__": "report_smoke"}
        exec(
            compile(
                "def smoke_leaf(n):\n"
                "    return sum(range(n))\n"
                "def smoke_work():\n"
                "    return [smoke_leaf(500) for _ in range(50)]\n",
                "report_smoke.py",
                "exec",
            ),
            workload,
        )
        for step in range(3):
            with m.region("step"):
                workload["smoke_work"]()
            m.metric("smoke.step", float(step))
        m.finalize()

        doc = build_report(run_dir)
        page = render_report(doc)
        with open(out_path, "w", encoding="utf-8") as fh:
            fh.write(page)
        payload = extract_payload(page)
        assert payload == json.loads(json.dumps(doc)), "embedded payload round-trip"
        assert payload["regions"], "report has region rows"
        assert any("smoke_leaf" in r["region"] for r in payload["regions"])
        assert "smoke.step" in (payload["metrics"] or {})
        for needle in ("https://", "http://", "cdn.", "@import", "src=\"//"):
            assert needle not in page, f"report must be self-contained (found {needle})"
        return out_path
    finally:
        m.finalize()  # no-op when already finalized; uninstalls on failure
        shutil.rmtree(tmp, ignore_errors=True)


def build_parser():
    """The ``python -m repro.core.analysis`` argument parser (also rendered
    into docs/CLI.md by :mod:`repro.core.clidoc`)."""
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.analysis")
    sub = p.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="per-region exclusive-time delta (B - A)")
    d.add_argument("run_a")
    d.add_argument("run_b")
    d.add_argument("--top", type=int, default=25)
    d.add_argument("--min-ns", type=int, default=0,
                   help="drop regions below this exclusive time in both runs")
    t = sub.add_parser("top", help="hotspot table for one run")
    t.add_argument("run_dir")
    t.add_argument("--top", type=int, default=20)
    mem = sub.add_parser("memory", help="top-allocators table for one run")
    mem.add_argument("run_dir")
    mem.add_argument("--top", type=int, default=20)
    md = sub.add_parser("memory-diff", help="per-region allocation delta (B - A)")
    md.add_argument("run_a")
    md.add_argument("run_b")
    md.add_argument("--top", type=int, default=25)
    md.add_argument("--min-bytes", type=int, default=0,
                    help="drop regions below this alloc size in both runs")
    m = sub.add_parser("merge-summary", help="render a merge summary JSON")
    m.add_argument("summary",
                   help="merged_trace_summary.json written by repro.core.merge, "
                        "or the merge root directory containing it")
    g = sub.add_parser("governor", help="overhead-governor report for one run")
    g.add_argument("run_dir")
    g.add_argument("--top", type=int, default=15)
    sf = sub.add_parser(
        "suggest-filter",
        help="print a filter spec for the next run (governor.json when "
             "present, else a scorep-score-style heuristic over profile.json)",
    )
    sf.add_argument("run_dir")
    sf.add_argument("--cost-ns", type=float, default=1500.0,
                    help="assumed per-visit cost for the profile heuristic")
    sf.add_argument("--max-mean-ns", type=float, default=20_000.0,
                    help="regions with longer mean exclusive time are kept")
    sf.add_argument("--min-visits", type=int, default=100,
                    help="regions with fewer visits are kept")
    rp = sub.add_parser(
        "report",
        help="self-contained HTML report fusing all artifacts of a run "
             "(or merge root) into one page",
    )
    rp.add_argument("run_dir", nargs="?", default=None,
                    help="run directory or merge root (omit with --smoke)")
    rp.add_argument("--diff", metavar="BASE", default=None,
                    help="baseline run dir: adds a run-vs-run regression section "
                         "(this run is B, BASE is A)")
    rp.add_argument("--out", default=None,
                    help="output path (default: <run_dir>/report.html)")
    rp.add_argument("--open", action="store_true", dest="open_browser",
                    help="open the generated report in the default browser")
    rp.add_argument("--smoke", action="store_true",
                    help="record a tiny throwaway run, report it, and verify "
                         "the embedded payload round-trips (CI gate)")
    pl = sub.add_parser(
        "plan",
        help="static instrumentation plan: scan sources (no execution), "
             "classify every function, emit static_plan.json",
    )
    pl.add_argument("paths", nargs="+",
                    help="package directories and/or .py files to scan")
    pl.add_argument("--out", default=None,
                    help="plan output path (default: ./static_plan.json; "
                         "directories resolve to static_plan.json inside)")
    pl.add_argument("--top", type=int, default=15,
                    help="predicted-offender rows to print")
    pl.add_argument("--smoke", action="store_true",
                    help="build + verify the plan round-trip without writing "
                         "it (CI gate); --out still writes when given")
    ln = sub.add_parser(
        "lint",
        help="measurement-API lint: report misuse (never-entered regions, "
             "foreign hooks, threads before install, ...) with stable rule "
             "ids; exit 1 on violations",
    )
    ln.add_argument("paths", nargs="+",
                    help="package directories and/or .py files to lint")
    cc = sub.add_parser(
        "concurrency",
        help="static concurrency analysis: discover threads/locks/coroutines "
             "(no execution), run the SP4xx passes (deadlock order, races, "
             "event-loop blocking, fork-after-threads, unjoined work), emit "
             "concurrency_plan.json; exit 1 on findings",
    )
    cc.add_argument("paths", nargs="+",
                    help="package directories and/or .py files to analyze")
    cc.add_argument("--out", default=None,
                    help="write concurrency_plan.json here (directories "
                         "resolve to concurrency_plan.json inside); omitted "
                         "= report only, nothing written")
    cc.add_argument("--top", type=int, default=10,
                    help="entrypoint/finding rows to print")
    cc.add_argument("--smoke", action="store_true",
                    help="verify the artifact contract (stamped doc "
                         "round-trips load) and exit 0 even with findings "
                         "(CI gate)")
    fl = sub.add_parser(
        "fleet",
        help="fleet-scale run-population analytics: effect-size regression "
             "detection, memsys leak analysis, and the CI perf gate; exit 1 "
             "on confirmed regressions/leaks",
    )
    flsub = fl.add_subparsers(dest="fleet_cmd", required=True)
    fa = flsub.add_parser(
        "analyze",
        help="analyze a run population (N run dirs): baseline-vs-candidate "
             "effect-size regressions + leak verdicts -> fleet_summary.json "
             "(`analysis fleet ROOT...` is shorthand for this)",
    )
    fa.add_argument("roots", nargs="*",
                    help="run directories and/or directories containing them "
                         "(optional with --smoke)")
    fa.add_argument("--experiment", default=None,
                    help="only ingest runs of this experiment (run-dir "
                         "boundary match, as in repro.core.merge)")
    fa.add_argument("--candidate", type=int, default=0,
                    help="candidate-window size in runs, newest first "
                         "(0 = a third of the population, clamped to [1, 8])")
    fa.add_argument("--alpha", type=float, default=0.05,
                    help="Mann-Whitney significance level")
    fa.add_argument("--min-effect", type=float, default=0.33,
                    help="minimum |Cliff's delta| for a verdict")
    fa.add_argument("--min-rel", type=float, default=0.05,
                    help="minimum relative median change for a verdict")
    fa.add_argument("--out", default=None,
                    help="write fleet_summary.json here (directories resolve "
                         "to fleet_summary.json inside); omitted = report only")
    fa.add_argument("--top", type=int, default=10,
                    help="finding rows to print")
    fa.add_argument("--smoke", action="store_true",
                    help="generate the canonical synthetic populations, "
                         "verify the stable/step/drift/leak contract and "
                         "byte-determinism, exit 0 (CI gate)")
    fg = flsub.add_parser(
        "gate",
        help="CI perf gate over a benchmark-artifact trajectory directory: "
             "exit 1 on a confirmed regression, 0 otherwise (first runs seed "
             "the baseline and pass), 2 on missing/corrupt inputs",
    )
    fg.add_argument("trajectory",
                    help="trajectory directory of snapshot subdirs "
                         "(NNNNN[-label]/*.json)")
    fg.add_argument("--append", metavar="DIR", default=None,
                    help="first copy DIR's *.json benchmark artifacts in as "
                         "the newest snapshot (e.g. benchmarks/artifacts)")
    fg.add_argument("--label", default=None,
                    help="snapshot label appended to the index (e.g. a "
                         "commit SHA)")
    fg.add_argument("--candidate", type=int, default=1,
                    help="candidate-window size in snapshots")
    fg.add_argument("--min-baseline", type=int, default=4,
                    help="baseline snapshots required before the gate "
                         "judges; fewer = seeding pass")
    fg.add_argument("--min-rel", type=float, default=0.10,
                    help="minimum relative median change for a verdict")
    fg.add_argument("--out", default=None,
                    help="write the gate summary here (default: "
                         "fleet_summary.json inside the trajectory dir)")
    fg.add_argument("--top", type=int, default=10,
                    help="finding rows to print")
    fs = flsub.add_parser(
        "show",
        help="render an existing fleet_summary.json (runs or gate mode)",
    )
    fs.add_argument("summary",
                    help="fleet_summary.json, or a directory containing it")
    fs.add_argument("--top", type=int, default=10,
                    help="finding rows to print")
    return p


#: ``analysis fleet X`` where X is not one of these gets ``analyze``
#: inserted — so ``analysis fleet RUNS_ROOT`` / ``analysis fleet --smoke``
#: work as the natural shorthand while ``fleet gate`` stays a real mode.
_FLEET_MODES = ("analyze", "gate", "show")


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv[:1] == ["fleet"] and (len(argv) == 1 or argv[1] not in _FLEET_MODES):
        argv.insert(1, "analyze")
    ns = build_parser().parse_args(argv)
    try:
        if ns.cmd == "diff":
            print(render_diff(diff_profiles(ns.run_a, ns.run_b, min_ns=ns.min_ns), ns.top))
        elif ns.cmd == "memory":
            print(render_memory(load_memory_doc(ns.run_dir), ns.top))
        elif ns.cmd == "memory-diff":
            print(render_memory_diff(
                diff_memory(ns.run_a, ns.run_b, min_bytes=ns.min_bytes), ns.top))
        elif ns.cmd == "merge-summary":
            print(render_merge_summary(load_merge_summary(ns.summary)))
        elif ns.cmd == "governor":
            print(render_governor(load_governor_doc(ns.run_dir), ns.top))
        elif ns.cmd == "suggest-filter":
            # Spec goes to stdout alone, so it can be command-substituted
            # straight into --filter / REPRO_MONITOR_FILTER.
            try:
                spec = load_governor_doc(ns.run_dir).get("suggested_filter", "")
            except MissingArtifact:
                spec = suggest_filter_from_profile(
                    load_profile(ns.run_dir),
                    cost_ns=ns.cost_ns,
                    max_mean_ns=ns.max_mean_ns,
                    min_visits=ns.min_visits,
                )
            print(spec)
        elif ns.cmd == "report":
            from .report import write_report

            if ns.smoke:
                path = smoke_report(out_path=ns.out)
                print(f"report smoke OK: {path}")
            elif ns.run_dir is None:
                print("error: report needs a run dir (or --smoke)", file=sys.stderr)
                return 2
            else:
                path = write_report(ns.run_dir, out_path=ns.out, diff_base=ns.diff)
                print(f"report written to {path}")
            if ns.open_browser:
                import webbrowser

                webbrowser.open(f"file://{os.path.abspath(path)}")
        elif ns.cmd == "plan":
            from .staticpass import build_plan, render_plan, save_plan, verify_plan

            plan = build_plan(ns.paths)
            verify_plan(plan)
            print(render_plan(plan, top=ns.top))
            if ns.smoke and ns.out is None:
                print("plan smoke OK (round-trip verified, nothing written)")
            else:
                out = ns.out or os.path.join(os.curdir, "static_plan.json")
                if os.path.isdir(out):
                    from .staticpass import ARTIFACT

                    out = os.path.join(out, ARTIFACT)
                print(f"plan written to {save_plan(plan, out)}")
        elif ns.cmd == "lint":
            from .staticpass import lint_paths

            violations = lint_paths(ns.paths)
            for v in violations:
                print(v.format())
            if violations:
                print(f"{len(violations)} violation(s)", file=sys.stderr)
                return 1
            print("clean: no measurement-API violations")
        elif ns.cmd == "concurrency":
            import json as _json
            import tempfile

            from .staticpass import (
                load_concurrency_plan,
                render_concurrency_plan,
                save_concurrency_plan,
            )
            from .staticpass.concurrency import analyze_paths, assemble_plan

            model, findings = analyze_paths(ns.paths)
            doc = assemble_plan(ns.paths, model, findings)
            print(render_concurrency_plan(doc, top=ns.top))
            if ns.out is not None:
                print(
                    f"concurrency plan written to "
                    f"{save_concurrency_plan(doc, ns.out)}"
                )
            if ns.smoke:
                # Artifact contract: stamped, serializable, loads back.
                assert doc.get("report_schema_version", 0) >= 1
                with tempfile.TemporaryDirectory() as td:
                    path = save_concurrency_plan(doc, td + os.sep)
                    loaded = load_concurrency_plan(path)
                assert loaded["rule_counts"] == doc["rule_counts"]
                assert _json.dumps(loaded["findings"]) == _json.dumps(
                    doc["findings"]
                )
                print("concurrency smoke OK (artifact round-trip verified)")
            elif findings:
                print(f"{len(findings)} finding(s)", file=sys.stderr)
                return 1
            else:
                print("clean: no concurrency findings")
        elif ns.cmd == "fleet":
            from .fleet import (
                append_snapshot,
                build_fleet_summary,
                gate_summary,
                load_fleet_summary,
                render_fleet_summary,
                save_fleet_summary,
            )
            from .fleet import smoke as fleet_smoke

            if ns.fleet_cmd == "analyze":
                if ns.smoke:
                    print(fleet_smoke())
                    return 0
                if not ns.roots:
                    print("error: fleet analyze needs run population roots "
                          "(or --smoke)", file=sys.stderr)
                    return 2
                doc = build_fleet_summary(
                    ns.roots,
                    experiment=ns.experiment,
                    candidate=ns.candidate,
                    alpha=ns.alpha,
                    min_effect=ns.min_effect,
                    min_rel=ns.min_rel,
                )
                print(render_fleet_summary(doc, top=ns.top))
                if ns.out is not None:
                    print(f"fleet summary written to "
                          f"{save_fleet_summary(doc, ns.out)}")
                if doc["findings_total"]:
                    print(f"{doc['findings_total']} confirmed finding(s)",
                          file=sys.stderr)
                    return 1
            elif ns.fleet_cmd == "gate":
                if ns.append is not None:
                    name = append_snapshot(ns.trajectory, ns.append,
                                           label=ns.label)
                    print(f"appended snapshot {name} from {ns.append}")
                doc = gate_summary(
                    ns.trajectory,
                    candidate=ns.candidate,
                    min_baseline=ns.min_baseline,
                    min_rel=ns.min_rel,
                )
                print(render_fleet_summary(doc, top=ns.top))
                out = ns.out if ns.out is not None else ns.trajectory + os.sep
                print(f"gate summary written to {save_fleet_summary(doc, out)}")
                if doc["verdict"] == "regressed":
                    print(f"{doc['findings_total']} confirmed regression(s)",
                          file=sys.stderr)
                    return 1
            else:
                print(render_fleet_summary(load_fleet_summary(ns.summary),
                                           top=ns.top))
        else:
            for name, vals in hotspots(ns.run_dir, ns.top):
                print(f"{vals['excl_ns'] / 1e6:12.3f} ms excl {vals['visits']:10d}x  {name}")
    except MissingArtifact as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
