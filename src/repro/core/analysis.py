"""Profile analysis / diffing — the offline-analysis step of the paper's
workflow (Score-P profiles are compared across runs in Cube/Vampir; here the
comparison is programmatic and drives the §Perf loop).

    PYTHONPATH=src python -m repro.core.analysis diff RUN_A RUN_B
    PYTHONPATH=src python -m repro.core.analysis top RUN_DIR
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple


def load_profile(run_dir: str) -> Dict[str, Any]:
    with open(os.path.join(run_dir, "profile.json")) as fh:
        return json.load(fh)


def flat_metrics(profile: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    return profile.get("flat", {})


def hotspots(run_dir: str, top: int = 20) -> List[Tuple[str, Dict[str, float]]]:
    flat = flat_metrics(load_profile(run_dir))
    return sorted(flat.items(), key=lambda kv: -kv[1]["excl_ns"])[:top]


def diff_profiles(run_a: str, run_b: str, min_ns: int = 0) -> List[Dict[str, Any]]:
    """Per-region exclusive-time deltas between two runs (B - A).

    Regions present in only one run are reported with the other side at 0 —
    exactly what a before/after optimization comparison needs."""
    a = flat_metrics(load_profile(run_a))
    b = flat_metrics(load_profile(run_b))
    rows = []
    for name in sorted(set(a) | set(b)):
        ea = a.get(name, {}).get("excl_ns", 0)
        eb = b.get(name, {}).get("excl_ns", 0)
        va = a.get(name, {}).get("visits", 0)
        vb = b.get(name, {}).get("visits", 0)
        if max(ea, eb) < min_ns:
            continue
        rows.append(
            {
                "region": name,
                "excl_ns_a": ea,
                "excl_ns_b": eb,
                "delta_ns": eb - ea,
                "ratio": (eb / ea) if ea else float("inf") if eb else 1.0,
                "visits_a": va,
                "visits_b": vb,
            }
        )
    rows.sort(key=lambda r: -abs(r["delta_ns"]))
    return rows


def render_diff(rows: List[Dict[str, Any]], top: int = 25) -> str:
    out = [f"{'delta_ms':>10s} {'a_ms':>10s} {'b_ms':>10s} {'ratio':>7s}  region"]
    for r in rows[:top]:
        ratio = f"{r['ratio']:.2f}" if r["ratio"] != float("inf") else "new"
        out.append(
            f"{r['delta_ns'] / 1e6:10.3f} {r['excl_ns_a'] / 1e6:10.3f} "
            f"{r['excl_ns_b'] / 1e6:10.3f} {ratio:>7s}  {r['region']}"
        )
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(prog="python -m repro.core.analysis")
    sub = p.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="per-region exclusive-time delta (B - A)")
    d.add_argument("run_a")
    d.add_argument("run_b")
    d.add_argument("--top", type=int, default=25)
    t = sub.add_parser("top", help="hotspot table for one run")
    t.add_argument("run_dir")
    t.add_argument("--top", type=int, default=20)
    ns = p.parse_args(argv)
    if ns.cmd == "diff":
        print(render_diff(diff_profiles(ns.run_a, ns.run_b), ns.top))
    else:
        for name, vals in hotspots(ns.run_dir, ns.top):
            print(f"{vals['excl_ns'] / 1e6:12.3f} ms excl {vals['visits']:10d}x  {name}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
