"""Artifact schema versioning — one number, stamped into every JSON artifact.

Every JSON document a run produces (profile.json, memory.json, metrics.json,
governor.json, meta.json, merged_trace_summary.json) and the report data
model embedded in report.html carries a top-level ``report_schema_version``
key.  The version covers the *union* of the artifact schemas — it is bumped
whenever any field documented in docs/ARTIFACTS.md changes meaning, moves,
or disappears, not when purely additive fields appear.  Offline tools
(``repro.core.analysis``, ``repro.core.report``) accept documents whose
version is at most ``REPORT_SCHEMA_VERSION`` and treat missing keys as
"older writer, additive field absent"; a *newer* version than the reader
knows is reported, not guessed at.

The policy in one line: **readers are backwards-compatible, writers stamp
the current version, breaking changes bump it.**  See docs/ARTIFACTS.md for
the per-artifact field tables this version number protects.
"""

from __future__ import annotations

from typing import Any, Dict

class MissingArtifact(RuntimeError):
    """A run dir lacks the artifact a tool needs (wrong substrate set, not
    a run dir at all, ...).  CLIs render this as a one-line ``error:`` and
    exit code 2.  Defined here — not in the CLI module — so the class has
    exactly one identity even when a CLI module runs as ``__main__`` under
    ``python -m`` (a duplicate class in ``__main__`` would not be caught
    when library code raises the imported one)."""


#: Current artifact-schema generation.  History:
#:   1 — first stamped generation (PR 5): the PR 0-4 artifact fields as
#:       documented in docs/ARTIFACTS.md, plus the report data model.
REPORT_SCHEMA_VERSION = 1

SCHEMA_KEY = "report_schema_version"


def stamp(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Stamp ``doc`` (in place) with the current schema version and return it."""
    doc[SCHEMA_KEY] = REPORT_SCHEMA_VERSION
    return doc


def schema_version(doc: Dict[str, Any]) -> int:
    """The schema generation ``doc`` was written under.

    Documents from before versioning (PR 0-4 writers) carry no key and are
    generation 0 — readers treat them exactly like generation 1 with every
    post-PR-4 additive field absent.
    """
    return int(doc.get(SCHEMA_KEY, 0))
