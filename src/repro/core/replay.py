"""Shared shadow-stack replay over flushed event batches.

Substrates that need call-context (the profiling substrate's call tree, the
memory substrate's per-region heap attribution) replay flushed event columns
through a per-thread shadow stack.  The stack discipline — push on enter,
pop on exit, implicit close of an inner frame that lost its exit, orphan /
mismatch bookkeeping — used to live inline in the profiling substrate; it is
factored out here so every consumer interprets malformed streams (a C exit
interleaved with a Python exit, an exit with no enter after a mid-run
attach) identically.

A frame is ``[region, enter_t, child_ns]``; ``child_ns`` accumulates the
inclusive time of closed children so consumers can derive exclusive time.
Consumers observe transitions through three optional callbacks:

    on_enter(region, t)                        after the frame is pushed
    on_close(region, enter_t, exit_t, child_ns) when a frame closes
    on_other(kind, region, t, aux)             LINE / EXCEPTION / ... events

Callbacks run once per event at flush granularity — never on the per-event
instrumentation fast path.
"""

from __future__ import annotations

from typing import Callable, List, Optional

from .buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT

OnEnter = Optional[Callable[[int, int], None]]
OnClose = Optional[Callable[[int, int, int, int], None]]
OnOther = Optional[Callable[[int, int, int, int], None]]


class ReplayState:
    """Per-thread shadow stack + malformed-stream counters."""

    __slots__ = ("stack", "last_t", "orphan_exits", "mismatched_exits")

    def __init__(self):
        self.stack: List[List[int]] = []  # frames: [region, enter_t, child_ns]
        self.last_t = 0
        self.orphan_exits = 0
        self.mismatched_exits = 0

    @property
    def depth(self) -> int:
        return len(self.stack)

    def live_region(self) -> int:
        """Region open at the top of the stack (-1 at top level)."""
        return self.stack[-1][0] if self.stack else -1

    def live_stack(self) -> List[int]:
        """The open region ids, outermost first."""
        return [frame[0] for frame in self.stack]


def replay(
    state: ReplayState,
    kinds,
    regions,
    ts,
    auxs=None,
    on_enter: OnEnter = None,
    on_close: OnClose = None,
    on_other: OnOther = None,
) -> None:
    """Replay one flushed batch of event columns through ``state``.

    ``kinds`` / ``regions`` / ``ts`` / ``auxs`` may be numpy columns or
    plain sequences; they are converted with ``tolist()`` once (element
    access on numpy arrays is far slower than on lists).
    """
    kinds = kinds.tolist() if hasattr(kinds, "tolist") else kinds
    regions = regions.tolist() if hasattr(regions, "tolist") else regions
    ts = ts.tolist() if hasattr(ts, "tolist") else ts
    if auxs is not None and hasattr(auxs, "tolist"):
        auxs = auxs.tolist()
    stack = state.stack
    for i, kind in enumerate(kinds):
        t = ts[i]
        if kind == EV_ENTER or kind == EV_C_ENTER:
            rid = regions[i]
            if on_enter is not None:
                on_enter(rid, t)
            stack.append([rid, t, 0])
        elif kind == EV_EXIT or kind == EV_C_EXIT:
            rid = regions[i]
            if not stack:
                state.orphan_exits += 1
                state.last_t = t
                continue
            if stack[-1][0] != rid:
                # An exit that doesn't match the open region.  If the parent
                # matches, the inner frame lost its exit — close it
                # implicitly; otherwise count and pop anyway.
                if len(stack) >= 2 and stack[-2][0] == rid:
                    region, enter_t, child_ns = stack.pop()
                    if on_close is not None:
                        on_close(region, enter_t, t, child_ns)
                    stack[-1][2] += t - enter_t
                else:
                    state.mismatched_exits += 1
            region, enter_t, child_ns = stack.pop()
            if on_close is not None:
                on_close(region, enter_t, t, child_ns)
            if stack:
                stack[-1][2] += t - enter_t
        elif on_other is not None:
            on_other(kind, regions[i], t, auxs[i] if auxs is not None else 0)
        state.last_t = t


def unwind(state: ReplayState, on_close: OnClose = None) -> None:
    """Close frames still open at finalize (the program is always inside
    ``__main__`` etc. when measurement stops) using the last seen timestamp."""
    t = state.last_t
    while state.stack:
        region, enter_t, child_ns = state.stack.pop()
        if on_close is not None:
            on_close(region, enter_t, t, child_ns)
        if state.stack:
            state.stack[-1][2] += t - enter_t
