"""repro.core.memsys — memory monitoring subsystem.

Memory is a first-class measurement signal next to time (the paper hosts
arbitrary metric sources — plugins, rusage, PAPI — alongside region
instrumentation; the HPC-monitoring literature treats memory behaviour as a
production-critical signal).  This package provides:

* :mod:`sysinfo` — cheap process-level probes: RSS (``/proc/self/statm``
  with a ``resource.getrusage`` fallback), open file descriptors.
* :mod:`poller` — a background sampling thread (RSS / traced heap / fd
  timelines) and a GC-pause watcher built on ``gc.callbacks``.
* :mod:`heap` — a tracemalloc-based heap collector that attributes
  allocation deltas to the live region shadow stack at buffer-flush
  granularity (sharing the replay machinery in :mod:`repro.core.replay`
  with the profiling substrate).
* :mod:`substrate` — the ``memory`` measurement substrate writing
  ``memory.json`` (per-region allocation attribution, per-thread peaks,
  RSS/GC/fd timelines) into the run directory.

Enable with ``REPRO_MONITOR_MEMORY=1`` (period / table size via
``REPRO_MONITOR_MEMORY_PERIOD`` / ``REPRO_MONITOR_MEMORY_TOPN``) or by
adding ``"memory"`` to the substrate list.
"""

from .heap import HeapCollector  # noqa: F401
from .poller import GcWatcher, SystemPoller  # noqa: F401
from .substrate import (  # noqa: F401
    DEFAULT_PERIOD_S,
    DEFAULT_TOPN,
    MemorySubstrate,
    load_memory,
    overview,
    reclaim_rows,
    region_rows,
    timelines,
)
from .sysinfo import open_fd_count, rss_bytes  # noqa: F401

__all__ = [
    "DEFAULT_PERIOD_S",
    "DEFAULT_TOPN",
    "GcWatcher",
    "HeapCollector",
    "MemorySubstrate",
    "SystemPoller",
    "load_memory",
    "open_fd_count",
    "overview",
    "reclaim_rows",
    "region_rows",
    "rss_bytes",
    "timelines",
]
