"""Background system poller + GC-pause watcher.

The poller is the rusage/plugin-style *asynchronous* metric source of the
paper's measurement model: a daemon thread samples RSS, the traced Python
heap, and the open-fd count on a configurable period, producing timelines
on the same ``perf_counter_ns`` timebase as region events (so the export
engine can clock-align them as Perfetto counter tracks).

Timelines are bounded: when a series reaches ``max_samples`` the poller
halves the series (keeping every other point) and doubles its period, so a
week-long run costs the same memory as a minute-long one.

GC pauses come from ``gc.callbacks`` — the interpreter invokes the
callback synchronously around each collection, so the delta between the
"start" and "stop" phases is the actual stop-the-world pause.
"""

from __future__ import annotations

import gc
import threading
import time
import tracemalloc
from typing import Dict, List, Optional

from .sysinfo import open_fd_count, rss_bytes, rss_source


class SystemPoller:
    """Daemon sampling thread for RSS / traced-heap / fd timelines."""

    def __init__(self, period_s: float = 0.1, max_samples: int = 1 << 14):
        self.period_s = max(float(period_s), 1e-3)
        self.max_samples = max(int(max_samples), 16)
        self.rss: List[List[int]] = []  # [t_perf_ns, bytes]
        self.heap: List[List[int]] = []  # [t_perf_ns, traced bytes]
        self.fds: List[List[int]] = []  # [t_perf_ns, open fds]
        self.peak_rss = 0
        self.peak_fds = 0
        self.n_samples = 0
        self.rss_source = "none"
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def sample(self) -> None:
        """Take one sample (also called directly at open/close so even a
        run shorter than the period gets endpoints)."""
        t = time.perf_counter_ns()
        rss = rss_bytes()
        self.rss_source = rss_source()
        self.rss.append([t, rss])
        self.peak_rss = max(self.peak_rss, rss)
        if tracemalloc.is_tracing():
            self.heap.append([t, tracemalloc.get_traced_memory()[0]])
        fds = open_fd_count()
        if fds is not None:
            self.fds.append([t, fds])
            self.peak_fds = max(self.peak_fds, fds)
        self.n_samples += 1
        if len(self.rss) >= self.max_samples:
            self._decimate()

    def _decimate(self) -> None:
        """Halve the timelines and double the period (bounded memory)."""
        self.rss = self.rss[::2]
        self.heap = self.heap[::2]
        self.fds = self.fds[::2]
        self.period_s *= 2

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="repro-memsys-poller", daemon=True
        )
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.period_s):
            self.sample()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        self._thread = None
        self.sample()  # closing endpoint


class GcWatcher:
    """Accumulates GC pause time / counts via ``gc.callbacks``."""

    def __init__(self, max_samples: int = 1 << 12):
        self.max_samples = max(int(max_samples), 16)
        self.pauses: List[List[int]] = []  # [t_perf_ns (at stop), pause_ns]
        self.collections = 0
        self.collected = 0
        self.uncollectable = 0
        self.pause_ns_total = 0
        self.per_generation: Dict[int, Dict[str, int]] = {}
        self._t0 = 0
        self._installed = False

    def _callback(self, phase: str, info: Dict[str, int]) -> None:
        if phase == "start":
            self._t0 = time.perf_counter_ns()
            return
        now = time.perf_counter_ns()
        pause = now - self._t0 if self._t0 else 0
        self._t0 = 0
        self.collections += 1
        self.pause_ns_total += pause
        self.collected += int(info.get("collected", 0))
        self.uncollectable += int(info.get("uncollectable", 0))
        gen = int(info.get("generation", 0))
        agg = self.per_generation.setdefault(
            gen, {"collections": 0, "pause_ns": 0, "collected": 0}
        )
        agg["collections"] += 1
        agg["pause_ns"] += pause
        agg["collected"] += int(info.get("collected", 0))
        if len(self.pauses) < self.max_samples:
            self.pauses.append([now, pause])

    def install(self) -> None:
        if not self._installed:
            gc.callbacks.append(self._callback)
            self._installed = True

    def uninstall(self) -> None:
        if self._installed:
            try:
                gc.callbacks.remove(self._callback)
            except ValueError:
                pass
            self._installed = False
