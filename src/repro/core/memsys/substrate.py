"""The ``memory`` measurement substrate — memory.json writer.

Composes the heap collector (per-region allocation attribution), the
system poller (RSS / heap / fd timelines), and the GC watcher into one
substrate.  Artifact:

    memory.json
      heap      per-region alloc/net bytes + blocks, per-thread peaks
      rss       peak/end + probe source
      gc        collections, pause totals, per-generation breakdown
      fds       peak/end open file descriptors
      series    counter timelines on the perf_counter_ns timebase
                (``mem.rss_mb``, ``mem.heap_mb``, ``mem.fds``,
                ``mem.gc_pause_ms``) — the export engine renders these as
                Perfetto counter tracks next to the metrics.json series.

Disabled by default; enabled via ``REPRO_MONITOR_MEMORY=1`` or by listing
``memory`` in the substrates.  When disabled no collector, poller, or GC
callback is installed and tracemalloc stays off, so the event fast path
and the flush path are untouched.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from ..schema import stamp
from ..substrates.base import Substrate
from .heap import HeapCollector
from .poller import GcWatcher, SystemPoller

DEFAULT_PERIOD_S = 0.1
DEFAULT_TOPN = 25

ARTIFACT = "memory.json"


class MemorySubstrate(Substrate):
    name = "memory"

    def __init__(
        self,
        period: float = DEFAULT_PERIOD_S,
        topn: int = DEFAULT_TOPN,
        trace_python: bool = True,
    ):
        self.period = float(period)
        self.topn = int(topn)
        self.heap = HeapCollector(trace_python=trace_python)
        self.poller = SystemPoller(period_s=self.period)
        self.gc = GcWatcher()
        self._run_dir = ""
        self._meta: Dict[str, Any] = {}

    def open(self, run_dir: str, meta: Dict[str, Any]) -> None:
        self._run_dir = run_dir
        self._meta = meta
        self.heap.open()
        self.gc.install()
        self.poller.sample()  # opening endpoint even for sub-period runs
        self.poller.start()

    def on_flush(self, thread_id: int, columns) -> None:
        self.heap.on_flush(thread_id, columns)

    def close(self, region_table: List[Dict[str, Any]]) -> None:
        self.poller.stop()
        self.gc.uninstall()
        self.heap.close()
        doc = self.document(region_table)
        with open(os.path.join(self._run_dir, ARTIFACT), "w") as fh:
            json.dump(doc, fh, indent=1, allow_nan=False)

    # -- document assembly (separate so tests/tools can introspect) ---------

    def document(self, region_table: List[Dict[str, Any]]) -> Dict[str, Any]:
        heap_doc = self.heap.region_table(region_table, topn=self.topn)
        heap_doc.update(
            start_bytes=self.heap.start_bytes,
            end_bytes=self.heap.end_bytes,
            peak_bytes=self.heap.peak_bytes,
            threads=self.heap.thread_table(),
        )
        rss_series = self.poller.rss
        fd_series = self.poller.fds
        series = {
            "mem.rss_mb": [[t, v / 1e6] for t, v in rss_series],
            "mem.heap_mb": [[t, v / 1e6] for t, v in self.poller.heap],
            "mem.fds": [[t, float(v)] for t, v in fd_series],
            "mem.gc_pause_ms": [[t, p / 1e6] for t, p in self.gc.pauses],
        }
        return stamp({
            "meta": self._meta,
            "config": {"period_s": self.period, "topn": self.topn},
            "heap": heap_doc,
            "rss": {
                "peak_bytes": self.poller.peak_rss,
                "end_bytes": rss_series[-1][1] if rss_series else 0,
                "samples": self.poller.n_samples,
                "source": self.poller.rss_source,
            },
            "gc": {
                "collections": self.gc.collections,
                "pause_ns_total": self.gc.pause_ns_total,
                "collected": self.gc.collected,
                "uncollectable": self.gc.uncollectable,
                "per_generation": {
                    str(g): agg for g, agg in sorted(self.gc.per_generation.items())
                },
            },
            "fds": {
                "peak": self.poller.peak_fds,
                "end": fd_series[-1][1] if fd_series else None,
            },
            "series": {k: v for k, v in series.items() if v},
        })


def load_memory(run_dir: str) -> Optional[Dict[str, Any]]:
    """Read a run's memory.json (``None`` when the substrate was off or the
    artifact is unreadable — callers treat memory data as best-effort)."""
    path = os.path.join(run_dir, ARTIFACT)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


# -- stable document accessors ------------------------------------------------
#
# Every consumer of memory.json (analysis renderers, the HTML report, merge's
# cross-rank section) goes through these instead of indexing the raw dict, so
# the JSON layout can evolve behind one compatibility seam.  All of them
# tolerate missing sections (older writers, partial documents).


def region_rows(doc: Dict[str, Any], top: int = 0) -> List[Dict[str, Any]]:
    """Per-region allocation rows from a memory.json document, sorted by
    attributed alloc bytes descending.  ``top`` > 0 truncates.  Each row:
    ``{"region", "alloc_bytes", "net_bytes", "alloc_blocks", "flushes"}``."""
    regions = doc.get("heap", {}).get("regions", {})
    rows = [
        {
            "region": name,
            "alloc_bytes": int(row.get("alloc_bytes", 0)),
            "net_bytes": int(row.get("net_bytes", 0)),
            "alloc_blocks": int(row.get("alloc_blocks", 0)),
            "flushes": int(row.get("flushes", 0)),
        }
        for name, row in regions.items()
    ]
    rows.sort(key=lambda r: -r["alloc_bytes"])
    return rows[:top] if top > 0 else rows


def overview(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Scalar summary of a memory.json document (heap/rss/gc/fds headline
    numbers) with every field present regardless of writer age."""
    heap = doc.get("heap", {})
    rss = doc.get("rss", {})
    gc = doc.get("gc", {})
    fds = doc.get("fds", {})
    return {
        "heap_start_bytes": int(heap.get("start_bytes", 0)),
        "heap_end_bytes": int(heap.get("end_bytes", 0)),
        "heap_peak_bytes": int(heap.get("peak_bytes", 0)),
        "dropped_regions": int(heap.get("dropped_regions", 0) or 0),
        "rss_peak_bytes": int(rss.get("peak_bytes", 0)),
        "rss_end_bytes": int(rss.get("end_bytes", 0)),
        "rss_samples": int(rss.get("samples", 0)),
        "rss_source": rss.get("source", "?"),
        "gc_collections": int(gc.get("collections", 0)),
        "gc_pause_ns_total": int(gc.get("pause_ns_total", 0)),
        "gc_collected": int(gc.get("collected", 0)),
        "fds_peak": fds.get("peak"),
    }


def timelines(doc: Dict[str, Any]) -> Dict[str, List[List[float]]]:
    """The ``mem.*`` counter series of a memory.json document as
    ``{name: [[t_ns, value], ...]}`` (empty when series were not kept)."""
    return {k: v for k, v in doc.get("series", {}).items() if v}


def reclaim_rows(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-region allocation/reclaim columns for leak analysis, sorted by
    alloc bytes descending.  Each row: ``{"region", "alloc_bytes",
    "freed_bytes", "net_bytes", "reclaim_rate"}`` where ``reclaim_rate`` is
    ``freed / alloc`` (1.0 when the region allocated nothing — nothing to
    reclaim is fully reclaimed).  The fleet leak detector's seam into
    memory.json; keep it in sync with :func:`region_rows`."""
    regions = doc.get("heap", {}).get("regions", {})
    rows = []
    for name, row in regions.items():
        alloc = int(row.get("alloc_bytes", 0))
        freed = int(row.get("freed_bytes", 0))
        rows.append(
            {
                "region": name,
                "alloc_bytes": alloc,
                "freed_bytes": freed,
                "net_bytes": int(row.get("net_bytes", alloc - freed)),
                "reclaim_rate": (freed / alloc) if alloc > 0 else 1.0,
            }
        )
    rows.sort(key=lambda r: (-r["alloc_bytes"], r["region"]))
    return rows
