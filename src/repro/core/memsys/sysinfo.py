"""Process-level memory/system probes.

Everything here must be cheap enough to call from the poller thread at a
sub-second period and from per-step driver annotations: one small file read
or one syscall, no allocation-heavy parsing.  Like the rest of the
monitoring core this module is jax-free and degrades gracefully off-Linux:
``/proc/self/statm`` first, ``resource.getrusage`` (peak RSS) as the
documented fallback, ``None``/0 when neither source exists.
"""

from __future__ import annotations

import os
import sys
from typing import Optional

_STATM_PATH = "/proc/self/statm"
_PAGE_SIZE = os.sysconf("SC_PAGE_SIZE") if hasattr(os, "sysconf") else 4096

#: Which probe produced the last successful ``rss_bytes`` reading
#: ("statm" | "getrusage" | "none"); recorded in memory.json so readers
#: know whether the timeline is current RSS or the rusage high-water mark.
_rss_source = "none"


def _rss_from_statm() -> Optional[int]:
    try:
        with open(_STATM_PATH, "rb") as fh:
            fields = fh.read().split()
        return int(fields[1]) * _PAGE_SIZE
    except (OSError, IndexError, ValueError):
        return None


def _rss_from_getrusage() -> Optional[int]:
    try:
        import resource
    except ImportError:
        return None
    ru_maxrss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is kilobytes on Linux, bytes on macOS.
    return ru_maxrss if sys.platform == "darwin" else ru_maxrss * 1024


def rss_bytes() -> int:
    """Resident set size in bytes (0 when no probe is available).

    Prefers the live reading from ``/proc/self/statm``; falls back to the
    ``getrusage`` peak-RSS high-water mark on platforms without procfs.
    """
    global _rss_source
    rss = _rss_from_statm()
    if rss is not None:
        # Reviewed race: every caller (main or poller thread) writes the
        # same platform-determined tag, so the lost update is harmless.
        _rss_source = "statm"  # repro-lint: allow=SP402
        return rss
    rss = _rss_from_getrusage()
    if rss is not None:
        _rss_source = "getrusage"
        return rss
    _rss_source = "none"
    return 0


def rss_source() -> str:
    """Probe that served the most recent :func:`rss_bytes` call."""
    return _rss_source


def open_fd_count() -> Optional[int]:
    """Number of open file descriptors (``None`` when undeterminable)."""
    try:
        return len(os.listdir("/proc/self/fd"))
    except OSError:
        return None
