"""Heap collector — tracemalloc deltas attributed to the region shadow stack.

Score-P attributes metric values to the call path active when the metric is
read; scalene showed the same idea pays off for Python heap traffic.  Our
measurement substrates only see events at *flush* granularity (the per-event
fast path stays a single buffer append), so the collector works at the same
granularity: at every buffer flush it reads the process-wide traced heap
(``tracemalloc.get_traced_memory``) and allocated-block count
(``sys.getallocatedblocks``), computes the delta since the previous flush,
and distributes it over the regions of the flushed batch proportionally to
their *exclusive time* within the batch — derived by replaying the batch
through the same shadow-stack machinery the profiling substrate uses
(:mod:`repro.core.replay`), so both substrates agree on what "the live
region" is for malformed streams.  Time not covered by a frame closed in
the batch (regions still open at the flush boundary) is charged to the
region at the top of the live stack.

This is an attribution *approximation* (allocation rate is assumed uniform
over the flush interval's wall time), the standard trade of sampling
profilers: exact per-allocation attribution costs a tracemalloc snapshot
diff per flush — orders of magnitude more than the entire measurement
fast path.
"""

from __future__ import annotations

import sys
import tracemalloc
from typing import Any, Dict, List

from ..replay import ReplayState, replay, unwind

#: Region id used for deltas observed with an empty shadow stack.
TOPLEVEL = -1


class _ThreadHeap:
    __slots__ = ("replay", "peak_heap_bytes", "flushes")

    def __init__(self):
        self.replay = ReplayState()
        self.peak_heap_bytes = 0
        self.flushes = 0


class HeapCollector:
    """Per-region net/alloc byte and block accounting at flush granularity."""

    def __init__(self, trace_python: bool = True):
        self.trace_python = trace_python
        self._started_tracing = False
        self._threads: Dict[int, _ThreadHeap] = {}
        # rid -> [alloc_bytes, freed_bytes, net_bytes, alloc_blocks, flushes];
        # byte/block fields are floats (time-weighted shares), rounded at
        # report time.
        self._regions: Dict[int, List[float]] = {}
        self._last_heap = 0
        self._last_blocks = 0
        self.start_bytes = 0
        self.end_bytes = 0
        self.peak_bytes = 0

    # -- lifecycle ----------------------------------------------------------

    def open(self) -> None:
        if self.trace_python and not tracemalloc.is_tracing():
            tracemalloc.start()
            self._started_tracing = True
        if tracemalloc.is_tracing():
            self._last_heap, _ = tracemalloc.get_traced_memory()
        self.start_bytes = self._last_heap
        self._last_blocks = sys.getallocatedblocks()

    def close(self) -> None:
        if tracemalloc.is_tracing():
            self.end_bytes, self.peak_bytes = tracemalloc.get_traced_memory()
        if self._started_tracing:
            tracemalloc.stop()
            self._started_tracing = False
        for state in self._threads.values():
            unwind(state.replay)

    # -- flush path ---------------------------------------------------------

    def on_flush(self, thread_id: int, columns: Dict[str, Any]) -> None:
        state = self._threads.get(thread_id)
        if state is None:
            state = self._threads[thread_id] = _ThreadHeap()
        span_start = state.replay.last_t

        # Replay the batch, accumulating per-region exclusive time *within
        # this batch* as the attribution weights.  Frames that opened in an
        # earlier batch are clipped to the batch span and only the child
        # time they accumulated during this batch is subtracted (snapshot
        # below) — otherwise a long-lived frame closing here would absorb
        # the whole delta with its lifetime duration.
        excl: Dict[int, int] = {}
        replay_state = state.replay
        child_base = [frame[2] for frame in replay_state.stack]

        def on_close(rid: int, enter_t: int, exit_t: int, child_ns: int) -> None:
            depth = len(replay_state.stack)  # the closed frame's position
            if depth < len(child_base):
                base = child_base[depth]
                # Once an inherited frame closes, its depth can be reoccupied
                # by frames pushed during this batch — those must start from
                # a zero baseline, so drop the stale snapshot entries.
                del child_base[depth:]
            else:
                base = 0
            weight = (exit_t - max(enter_t, span_start)) - (child_ns - base)
            if weight > 0:
                excl[rid] = excl.get(rid, 0) + weight

        replay(
            state.replay, columns["kind"], columns["region"], columns["t"],
            on_close=on_close,
        )
        state.flushes += 1

        if not tracemalloc.is_tracing():
            return
        heap, _ = tracemalloc.get_traced_memory()
        blocks = sys.getallocatedblocks()
        d_heap = heap - self._last_heap
        d_blocks = blocks - self._last_blocks
        self._last_heap = heap
        self._last_blocks = blocks
        state.peak_heap_bytes = max(state.peak_heap_bytes, heap)

        # Time inside frames still open at the flush boundary is not covered
        # by any closed frame; charge it to the live stack top.
        span = state.replay.last_t - span_start if span_start else 0
        covered = sum(excl.values())
        remainder = span - covered
        if remainder > 0 or not excl:
            live = state.replay.live_region()
            excl[live] = excl.get(live, 0) + max(remainder, 0)
        total = sum(excl.values())
        if total <= 0:  # zero-width batch: all weight on the live region
            excl = {state.replay.live_region(): 1}
            total = 1
        for rid, weight in excl.items():
            share = weight / total
            agg = self._regions.get(rid)
            if agg is None:
                agg = self._regions[rid] = [0.0, 0.0, 0.0, 0.0, 0]
            part = d_heap * share
            if part >= 0:
                agg[0] += part
            else:
                agg[1] += -part
            agg[2] += part
            if d_blocks > 0:
                agg[3] += d_blocks * share
            agg[4] += 1

    # -- reporting ----------------------------------------------------------

    def region_table(
        self, region_table: List[Dict[str, Any]], topn: int = 0
    ) -> Dict[str, Any]:
        """Named per-region attribution, top-N by alloc bytes.

        Returns ``{"regions": {...}, "dropped_regions": n}`` where dropped
        counts entries beyond the top-N cut (their bytes stay visible in the
        heap totals, only the per-region rows are elided).
        """

        def name_of(rid: int) -> str:
            if rid < 0:
                return "<toplevel>"
            r = region_table[rid]
            return f"{r['module']}:{r['name']}"

        rows = sorted(self._regions.items(), key=lambda kv: -kv[1][0])
        dropped = 0
        if topn and len(rows) > topn:
            dropped = len(rows) - topn
            rows = rows[:topn]
        regions = {
            name_of(rid): {
                "alloc_bytes": int(agg[0]),
                "freed_bytes": int(agg[1]),
                "net_bytes": int(agg[2]),
                "alloc_blocks": int(agg[3]),
                "flushes": agg[4],
            }
            for rid, agg in rows
        }
        return {"regions": regions, "dropped_regions": dropped}

    def thread_table(self) -> Dict[str, Dict[str, int]]:
        return {
            str(tid): {
                "peak_heap_bytes": state.peak_heap_bytes,
                "flushes": state.flushes,
                "orphan_exits": state.replay.orphan_exits,
                "mismatched_exits": state.replay.mismatched_exits,
            }
            for tid, state in sorted(self._threads.items())
        }
