"""Streaming, numpy-vectorized Chrome trace export engine.

Both trace consumers — the per-run ``to_chrome`` export in the tracing
substrate and the multi-rank ``merge_runs`` — used to build one Python dict
per event and hold the whole trace in memory before a single ``json.dump``.
That per-event interpreted path is exactly what the paper's Score-P C
bindings exist to avoid; this module is the Python-side equivalent: events
move from the raw npz columns to JSON text through numpy bulk operations
only, in chunks.  The raw columns themselves stay resident (~21 bytes per
event, the npz working set), but every per-event expansion — dicts,
formatted records, JSON text — is O(chunk) instead of O(total events).

Encoding scheme
---------------
A Chrome span event is ``{"name":N,"cat":C,"ph":P,"pid":p,"tid":t,"ts":T}``.
For a given stream, everything but the timestamp is one of ``2 * n_regions``
fixed strings, so events are encoded as fixed-width byte records:

    [ template(region, ph)  padded to W | ts digits | '.' | 3 frac | '}' ',' ]

JSON permits whitespace between tokens, so templates are space-padded to a
common width and timestamp digits are left-padded with spaces (never zeros:
leading zeros are not valid JSON numbers).  The whole chunk is then a
``(n, rowlen)`` uint8 matrix assembled by a handful of C-level numpy ops —
a template-row gather plus vectorized divmod digit extraction — and written
with one ``write``.  Timestamps are emitted as exact decimal microseconds
(``ns // 1000 . ns % 1000``), which parses to the same float as the naive
exporter's ``ns / 1000.0`` for any ns below 2**53.

Multi-rank merge uses the same chunk encoder per stream and a k-way
``heapq.merge`` over (wall_ns, record) items, so the merged trace is
written in clock-aligned order while only O(chunk) formatted records per
stream are alive at any time.

The chunk size is controlled by ``REPRO_MONITOR_EXPORT_CHUNK`` (events per
encoded chunk, default 262144).
"""

from __future__ import annotations

import heapq
import json
import math
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from .buffer import EV_C_ENTER, EV_C_EXIT, EV_ENTER, EV_EXIT
from .topology import ProcessTopology

ENV_CHUNK = "REPRO_MONITOR_EXPORT_CHUNK"
DEFAULT_CHUNK = 1 << 18


def export_chunk_size(chunk: Optional[int] = None) -> int:
    """Resolve the export chunk size (argument > env knob > default)."""
    if chunk is None:
        try:
            chunk = int(os.environ.get(ENV_CHUNK, DEFAULT_CHUNK))
        except ValueError:
            chunk = DEFAULT_CHUNK
    return max(int(chunk), 1)


# ----------------------------------------------------------------------------
# Span templates
# ----------------------------------------------------------------------------

class SpanTemplates:
    """Per-(stream) table of fixed-width event prefixes.

    Row ``2 * rid + 0`` holds the "B" prefix for region ``rid``, row
    ``2 * rid + 1`` the "E" prefix; all rows are space-padded to the width
    of the longest prefix so a chunk gather is a contiguous row copy.
    """

    __slots__ = ("table", "width", "strings")

    def __init__(self, region_table: List[Dict[str, Any]], pid: int, tid: int):
        strings: List[str] = []
        for r in region_table:
            name = json.dumps(str(r.get("name", "?")))
            cat = json.dumps(str(r.get("module", "")))
            for ph in ("B", "E"):
                strings.append(
                    f'{{"name":{name},"cat":{cat},"ph":"{ph}",'
                    f'"pid":{int(pid)},"tid":{int(tid)},"ts":'
                )
        self.strings = strings
        self.width = max((len(s.encode("ascii")) for s in strings), default=0)
        table = np.full((len(strings), self.width), 0x20, dtype=np.uint8)
        for i, s in enumerate(strings):
            b = s.encode("ascii")
            table[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
        self.table = table


def _ascii_digits(values: np.ndarray, width: int, pad_space: bool) -> np.ndarray:
    """``(width, n)`` uint8 ASCII digits of non-negative ``values``.

    With ``pad_space`` the leading zeros (all but the last digit) become
    spaces, keeping the emitted JSON number free of leading zeros.
    """
    out = np.empty((width, len(values)), dtype=np.uint8)
    rem = values
    for i in range(width - 1, 0, -1):
        rem, digit = np.divmod(rem, 10)
        out[i] = digit.astype(np.uint8)
    out[0] = rem.astype(np.uint8)
    if pad_space and width > 1:
        lead = np.logical_and.accumulate(out[:-1] == 0, axis=0)
        out += 0x30
        out[:-1][lead] = 0x20
    else:
        out += 0x30
    return out


def encode_spans(
    kinds: np.ndarray,
    rids: np.ndarray,
    ts_ns: np.ndarray,
    templates: SpanTemplates,
    offset_ns: int = 0,
):
    """Encode one chunk of raw event columns into JSON byte records.

    Returns ``(records, wall_ns)`` where ``records`` is a ``(m, rowlen)``
    uint8 matrix (each row one event ending ``},``) and ``wall_ns`` the
    int64 clock-aligned timestamps of the kept (B/E) events; ``(None,
    None)`` when the chunk holds no span events.
    """
    kinds = np.asarray(kinds)
    is_e = (kinds == EV_EXIT) | (kinds == EV_C_EXIT)
    keep = is_e | (kinds == EV_ENTER) | (kinds == EV_C_ENTER)
    if not keep.any():
        return None, None
    if not keep.all():
        rids = np.asarray(rids)[keep]
        ts_ns = np.asarray(ts_ns)[keep]
        is_e = is_e[keep]
    m = len(ts_ns)
    wall = ts_ns.astype(np.int64) + int(offset_ns)
    if int(wall.min()) < 0:
        return _encode_spans_python(is_e, rids, wall, templates), wall
    q, frac = np.divmod(wall, 1000)
    digits = max(len(str(int(q.max()))), 1)
    width = templates.width
    rowlen = width + digits + 6  # digits + '.' + 3 frac digits + '}' + ','
    rec = np.empty((m, rowlen), dtype=np.uint8)
    idx = np.asarray(rids).astype(np.int64) * 2 + is_e
    rec[:, :width] = templates.table[idx]
    rec[:, width : width + digits] = _ascii_digits(q, digits, pad_space=True).T
    rec[:, width + digits] = 0x2E  # '.'
    rec[:, width + digits + 1 : width + digits + 4] = _ascii_digits(
        frac, 3, pad_space=False
    ).T
    rec[:, -2] = 0x7D  # '}'
    rec[:, -1] = 0x2C  # ','
    return rec, wall


def _encode_spans_python(is_e, rids, wall, templates: SpanTemplates):
    """Fallback for negative clock-aligned timestamps (pathological epochs):
    per-event formatting, same record content, returned as list of bytes."""
    strings = templates.strings
    out = []
    for exit_, rid, w in zip(is_e.tolist(), np.asarray(rids).tolist(), wall.tolist()):
        sign = "-" if w < 0 else ""
        q, frac = divmod(abs(int(w)), 1000)
        out.append(f"{strings[rid * 2 + exit_]}{sign}{q}.{frac:03d}}},".encode("ascii"))
    return out


def records_to_blobs(records) -> List[bytes]:
    """Split a record matrix into one bytes object per event (heap merge)."""
    if isinstance(records, list):
        return records
    rowlen = records.shape[1]
    return records.view(f"S{rowlen}").ravel().tolist()


# ----------------------------------------------------------------------------
# Streaming writer
# ----------------------------------------------------------------------------

class ChromeTraceWriter:
    """Incremental Chrome trace-event JSON writer.

    Every event write (encoded record chunks, metadata, counters) appends a
    trailing comma; ``close()`` seeks back over the final comma and writes
    the document tail, so the file is strictly valid JSON with no full
    event list ever held in memory.
    """

    def __init__(self, path: str, display_time_unit: str = "ms"):
        self.path = path
        self._fh = open(path, "wb", buffering=1 << 20)
        self._fh.write(
            b'{"displayTimeUnit":%s,"traceEvents":['
            % json.dumps(display_time_unit).encode("ascii")
        )
        self.stats: Dict[str, Any] = {
            "events": 0,
            "span_events": 0,
            "meta_events": 0,
            "counter_events": 0,
            "chunks": 0,
            "max_chunk_events": 0,
            "bytes": 0,
        }

    def write_event(self, event: Dict[str, Any]) -> None:
        """Write one non-span event (metadata "M", counter "C", ...)."""
        payload = json.dumps(event, separators=(",", ":"), allow_nan=False)
        self._fh.write(payload.encode("utf-8"))
        self._fh.write(b",")
        self.stats["events"] += 1
        ph = event.get("ph")
        if ph == "M":
            self.stats["meta_events"] += 1
        elif ph == "C":
            self.stats["counter_events"] += 1

    def write_records(self, records, count: Optional[int] = None) -> None:
        """Write an encoded span chunk: a ``(m, rowlen)`` uint8 matrix whose
        rows end in ``,`` or a list of such per-event bytes records."""
        if records is None:
            return
        if isinstance(records, list):
            if not records:
                return
            n = len(records)
            self._fh.write(b"".join(records))
        else:
            n = records.shape[0] if count is None else count
            if not n:
                return
            self._fh.write(records)
        self.stats["events"] += n
        self.stats["span_events"] += n
        self.stats["chunks"] += 1
        self.stats["max_chunk_events"] = max(self.stats["max_chunk_events"], n)

    def process_metadata(self, pid: int, name: str, sort_index: Optional[int] = None) -> None:
        self.write_event(
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": name}}
        )
        if sort_index is not None:
            self.write_event(
                {"name": "process_sort_index", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"sort_index": int(sort_index)}}
            )

    def thread_metadata(self, pid: int, tid: int, name: str) -> None:
        self.write_event(
            {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": name}}
        )

    def counter(self, pid: int, name: str, ts_us: float, value: float) -> None:
        self.write_event(
            {"name": name, "ph": "C", "pid": pid, "tid": 0, "ts": ts_us,
             "args": {name: value}}
        )

    def close(self) -> Dict[str, Any]:
        if self.stats["events"]:
            self._fh.flush()
            self._fh.seek(-1, os.SEEK_END)  # drop the trailing comma
        self._fh.write(b"]}")
        self._fh.flush()
        self.stats["bytes"] = self._fh.tell()
        self._fh.close()
        return dict(self.stats)

    def abort(self) -> None:
        """Discard the output: close the handle and remove the partial file
        (a truncated trace must not be left looking like a valid export)."""
        try:
            self._fh.close()
        except OSError:
            pass
        try:
            os.unlink(self.path)
        except OSError:
            pass


# ----------------------------------------------------------------------------
# Run-level helpers
# ----------------------------------------------------------------------------

def load_defs(run_dir: str) -> Dict[str, Any]:
    with open(os.path.join(run_dir, "defs.json")) as fh:
        return json.load(fh)


def _load_stream(run_dir: str, info: Dict[str, Any]) -> Dict[str, np.ndarray]:
    with np.load(os.path.join(run_dir, info["file"])) as z:
        return {k: z[k] for k in z.files}


def _run_topology(meta: Dict[str, Any]) -> ProcessTopology:
    topo = meta.get("topology")
    if isinstance(topo, dict):
        try:
            return ProcessTopology.from_dict(topo)
        except (TypeError, ValueError):
            pass
    rank = int(meta.get("rank", 0) or 0)
    return ProcessTopology(rank=rank, world_size=rank + 1)


def _series_from(run_dir: str, artifact: str) -> Dict[str, List]:
    """Load a ``{"series": {name: [[t_ns, value], ...]}}`` table from one of
    the run's JSON artifacts (empty if absent/unreadable)."""
    path = os.path.join(run_dir, artifact)
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        return {}
    series = doc.get("series")
    return series if isinstance(series, dict) else {}


def _counter_series(run_dir: str) -> Dict[str, List]:
    """All counter-track series of a run: user metrics (metrics.json) plus
    the memory subsystem's RSS/heap/GC/fd timelines (memory.json).  Memory
    series are ``mem.``-prefixed at the source, so the namespaces cannot
    collide."""
    series = dict(_series_from(run_dir, "metrics.json"))
    series.update(_series_from(run_dir, "memory.json"))
    return series


def _write_counters(
    writer: ChromeTraceWriter, run_dir: str, pid: int, offset_ns: int = 0
) -> None:
    """Emit Perfetto counter ("C") tracks from the run's metric + memory
    series."""
    for name, points in sorted(_counter_series(run_dir).items()):
        for point in points:
            try:
                t_ns, value = point
            except (TypeError, ValueError):
                continue
            if value is None or not isinstance(value, (int, float)):
                continue
            if not math.isfinite(value):
                continue
            writer.counter(pid, name, (int(t_ns) + offset_ns) / 1000.0, float(value))


def _sorted_streams(defs: Dict[str, Any]) -> List[Tuple[int, Dict[str, Any]]]:
    return sorted(
        ((int(tid), info) for tid, info in defs.get("streams", {}).items()),
        key=lambda kv: kv[0],
    )


# ----------------------------------------------------------------------------
# Per-run export
# ----------------------------------------------------------------------------

def export_run(
    run_dir: str, out_path: Optional[str] = None, chunk: Optional[int] = None
) -> Dict[str, Any]:
    """Export one run directory to Chrome trace JSON via the streaming engine.

    Span timestamps stay in the run's raw perf_counter timebase (matching
    the historical per-run export); metric series become counter tracks.
    Returns the writer stats (events, bytes, chunks, ...) plus ``out``.
    """
    chunk = export_chunk_size(chunk)
    defs = load_defs(run_dir)
    meta = defs.get("meta", {})
    regions = defs.get("regions", [])
    pid = int(meta.get("rank", 0) or 0)
    topology = _run_topology(meta)
    out_path = out_path or os.path.join(run_dir, "trace.json")

    writer = ChromeTraceWriter(out_path)
    try:
        writer.process_metadata(pid, topology.tag(), sort_index=topology.rank)
        for tid, info in _sorted_streams(defs):
            writer.thread_metadata(pid, tid, f"thread {tid}")
            cols = _load_stream(run_dir, info)
            n = len(cols["kind"])
            templates = SpanTemplates(regions, pid, tid)
            for lo in range(0, n, chunk):
                hi = min(lo + chunk, n)
                records, _ = encode_spans(
                    cols["kind"][lo:hi], cols["region"][lo:hi], cols["t"][lo:hi],
                    templates,
                )
                writer.write_records(records)
        _write_counters(writer, run_dir, pid)
    except BaseException:
        writer.abort()
        raise
    stats = writer.close()
    stats["out"] = out_path
    return stats


# ----------------------------------------------------------------------------
# Multi-rank k-way merge
# ----------------------------------------------------------------------------

def _stream_items(
    run_dir: str,
    info: Dict[str, Any],
    regions: List[Dict[str, Any]],
    pid: int,
    tid: int,
    offset_ns: int,
    chunk: int,
    counter: List[int],
) -> Iterator[Tuple[int, bytes]]:
    """Yield (wall_ns, record_bytes) for one stream, chunk by chunk.

    Stream columns are appended in thread time order, so each stream is a
    sorted sequence and the k-way heap merge over streams yields a globally
    clock-aligned event order with only O(chunk) formatted records alive
    per stream.
    """
    cols = _load_stream(run_dir, info)
    templates = SpanTemplates(regions, pid, tid)
    n = len(cols["kind"])
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        records, wall = encode_spans(
            cols["kind"][lo:hi], cols["region"][lo:hi], cols["t"][lo:hi],
            templates, offset_ns=offset_ns,
        )
        if records is None:
            continue
        blobs = records_to_blobs(records)
        counter[0] += len(blobs)
        yield from zip(wall.tolist(), blobs)


def merge_chrome_trace(
    entries: List[Dict[str, Any]], out_path: str, chunk: Optional[int] = None
) -> Dict[str, Any]:
    """Merge prepared per-rank entries into one clock-aligned Chrome trace.

    Each entry: ``{"run_dir", "defs", "pid", "offset_ns", "tag"}`` —
    ``offset_ns`` maps the rank's perf_counter timestamps to wall time
    (``epoch_time_ns - epoch_perf_ns``).  Returns writer stats plus
    per-run span counts and throughput.
    """
    chunk = export_chunk_size(chunk)
    t_start = time.perf_counter()
    writer = ChromeTraceWriter(out_path)
    try:
        streams: List[Iterator[Tuple[int, bytes]]] = []
        counts: Dict[str, List[int]] = {}
        for entry in entries:
            defs = entry["defs"]
            pid = int(entry["pid"])
            writer.process_metadata(pid, entry.get("tag", f"r{pid}"), sort_index=pid)
            counter = counts.setdefault(entry["run_dir"], [0])
            for tid, info in _sorted_streams(defs):
                writer.thread_metadata(pid, tid, f"thread {tid}")
                streams.append(
                    _stream_items(
                        entry["run_dir"], info, defs.get("regions", []), pid, tid,
                        int(entry.get("offset_ns", 0)), chunk, counter,
                    )
                )
            _write_counters(writer, entry["run_dir"], pid,
                            offset_ns=int(entry.get("offset_ns", 0)))

        batch: List[bytes] = []
        for _, blob in heapq.merge(*streams, key=lambda item: item[0]):
            batch.append(blob)
            if len(batch) >= chunk:
                writer.write_records(batch)
                batch = []
        writer.write_records(batch)
    except BaseException:
        writer.abort()
        raise
    stats = writer.close()
    elapsed = time.perf_counter() - t_start
    stats["out"] = out_path
    stats["elapsed_s"] = elapsed
    stats["events_per_s"] = stats["span_events"] / elapsed if elapsed > 0 else 0.0
    stats["per_run_events"] = {run: c[0] for run, c in counts.items()}
    return stats
