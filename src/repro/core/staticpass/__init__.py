"""Ahead-of-run static analysis — plan instrumentation before any event fires.

Score-P users hand-write filter files after a costly first run; the runtime
governor (repro.core.governor) re-derives the same knowledge online, paying a
budget-blowing first window before it converges.  This package closes the gap
*statically*: it walks Python source + bytecode (``ast`` + ``dis``, never
importing user code) and produces the knowledge both of those workflows had to
buy with a live run.

Two passes share one scanner (:mod:`.scanner`):

``planner`` (:mod:`.planner`, CLI ``analysis plan``)
    Classifies every function (trivial accessor / dunder / property →
    auto-exclude candidate; generator / async → PEP 669 PY_YIELD/PY_RESUME
    cost class; recursive or loop-nested call sites → hot / flush-pressure;
    pure C-call wrapper → sampler-friendly), estimates per-function event
    rates from call-graph fan-in, and emits a schema-stamped
    ``static_plan.json`` whose filter spec round-trips
    ``Filter.from_spec`` and whose predicted offenders warm-start the
    governor's escalation ladder (:mod:`.integrate`).

``linter`` (:mod:`.linter`, CLI ``analysis lint``)
    Reports measurement-API misuse with ``file:line`` diagnostics and stable
    rule ids (``SP1xx`` lifecycle, ``SP2xx`` environment, ``SP3xx``
    distortion, ``SP4xx`` concurrency); see :data:`.linter.RULES`.

``concurrency`` (:mod:`.concurrency` on :mod:`.concgraph`, CLI
``analysis concurrency``)
    Inter-procedural concurrency analysis: discovers threads / processes /
    executors / coroutines, the lock table and its acquisition order
    (including across calls), then runs the SP401–SP405 detection passes
    (deadlock-order cycles, race candidates, event-loop-blocking calls,
    fork-after-threads, unjoined work) and emits a schema-stamped
    ``concurrency_plan.json`` whose wait-point candidates seed the
    governor's sampler-friendly set.

All passes run with zero runtime overhead — nothing is imported or executed
— so they are safe as pre-deploy gates (CI runs ``analysis lint`` and the
SP4xx self-analysis over this repo itself on every push).
"""

from .concurrency import (
    CONCURRENCY_RULES,
    analyze_paths,
    build_concurrency_plan,
    load_concurrency_plan,
    render_concurrency_plan,
    save_concurrency_plan,
)
from .linter import RULES, Violation, lint_paths
from .planner import (
    ARTIFACT,
    build_plan,
    load_plan,
    plan_exclude_patterns,
    predicted_offenders,
    render_plan,
    save_plan,
    verify_plan,
)
from .integrate import apply_plan, offender_names, plan_vs_observed
from .scanner import module_name_for, scan_paths

__all__ = [
    "ARTIFACT",
    "CONCURRENCY_RULES",
    "RULES",
    "Violation",
    "analyze_paths",
    "apply_plan",
    "build_concurrency_plan",
    "build_plan",
    "lint_paths",
    "load_concurrency_plan",
    "render_concurrency_plan",
    "save_concurrency_plan",
    "load_plan",
    "module_name_for",
    "offender_names",
    "plan_exclude_patterns",
    "plan_vs_observed",
    "predicted_offenders",
    "render_plan",
    "save_plan",
    "scan_paths",
    "verify_plan",
]
