"""Plan → runtime integration: filter merging, governor seeding, reporting.

The plan's exclude patterns enter the live filter as *runtime excludes*
(the ``exclude!`` clause), the same channel the governor uses — so plan and
governor excludes compose under one precedence rule: absolute, never
re-admitted by include rules, never flipping an allow-list spec.

Governor warm start: the plan's predicted offenders (both module forms) are
handed to :meth:`Governor.seed_static_plan`, making them eligible for the
exclude rung on the first flush without waiting for observed leaf-duration
evidence — the verdict was reached statically.  The governor's document then
carries a ``static_plan`` section, and :func:`plan_vs_observed` joins it
with the plan for the report's plan-vs-observed view.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from .planner import plan_exclude_patterns, predicted_offenders


def offender_names(plan: Dict[str, Any]) -> set:
    """Both module forms of every predicted offender (``module:qualname``)."""
    names = set()
    for row in predicted_offenders(plan):
        names.add(row.get("region", ""))
        names.add(row.get("frameless_region", ""))
    names.discard("")
    return names


def apply_plan(measurement, plan: Dict[str, Any]) -> List[str]:
    """Merge a plan into a live (or not-yet-started) measurement.

    Adds the plan's exclude patterns as runtime excludes, refilters cached
    verdicts when the measurement already registered regions, stores the
    plan on the measurement (copied into the run dir at ``start()``), and
    seeds the governor.  Returns the patterns actually added."""
    added = measurement.filter.add_runtime_excludes(plan_exclude_patterns(plan))
    if added and len(measurement.regions):
        measurement.regions.refilter()
    measurement.static_plan = plan
    if measurement.governor is not None:
        measurement.governor.seed_static_plan(plan)
    return added


def plan_vs_observed(
    plan: Dict[str, Any], governor_doc: Optional[Dict[str, Any]]
) -> Dict[str, Any]:
    """Join statically-predicted offenders with what the governor observed.

    Buckets (all ``module:qualname`` region names):

    * ``pre_excluded`` — predicted offenders the plan itself already
      excluded; they never register, so the governor never has to act.
    * ``confirmed`` — predicted offenders the governor *also* excluded at
      runtime (the static verdict was right).
    * ``unconfirmed`` — predicted offenders the governor observed but left
      alone (over-prediction, or the budget never forced an action).
    * ``unpredicted`` — regions the governor excluded that the plan missed
      (under-prediction: the interesting rows for improving the planner).
    """
    predicted_rows = predicted_offenders(plan)
    predicted = offender_names(plan)
    pre_excluded = {
        row["region"]
        for row in predicted_rows
        if row.get("verdict") == "exclude"
    }
    runtime_excluded: set = set()
    observed: set = set()
    if governor_doc:
        for row in governor_doc.get("regions", []):
            observed.add(row.get("region", ""))
            if row.get("excluded"):
                runtime_excluded.add(row.get("region", ""))
        for action in governor_doc.get("actions", []):
            for step in action.get("steps", []):
                if step.get("kind") == "exclude_regions":
                    runtime_excluded.update(step.get("regions", []))
    confirmed = sorted(predicted & runtime_excluded)
    unconfirmed = sorted((predicted & observed) - runtime_excluded - pre_excluded)
    unpredicted = sorted(runtime_excluded - predicted)
    return {
        "predicted": len(predicted_rows),
        "pre_excluded": sorted(pre_excluded),
        "confirmed": confirmed,
        "unconfirmed": unconfirmed,
        "unpredicted": unpredicted,
        "governed": governor_doc is not None,
    }
