"""Shared no-execution scanner: source + bytecode view of a package.

One walk serves both the planner and the linter.  Per file it produces a
:class:`ScannedModule` carrying the parsed AST, the compiled code objects
(``compile`` + ``dis`` — still no execution: the module body is never run),
per-function :class:`FunctionInfo` records, the measurement-API import
aliases, and lint-suppression pragmas.

Module naming must match what the live registry would record, or every plan
verdict is a silent no-op (see ``tests/test_staticpass.py`` parity checks):

* framed registration reads ``frame.f_globals["__name__"]`` — the dotted
  module path.  :func:`module_name_for` reproduces it by walking up through
  ``__init__.py`` package directories (which also handles ``src/`` layouts:
  the climb stops at the first non-package directory) and, below an explicit
  scan root, treating ``__init__``-less directories as namespace packages.
* frameless registration (``sys.monitoring`` callbacks) falls back to
  ``regions._module_from_filename`` — the file stem.  The scanner reuses
  that exact function rather than reimplementing it.
"""

from __future__ import annotations

import ast
import dis
import os
import re
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from ..regions import _module_from_filename
from ..schema import MissingArtifact

#: Modules whose bindings count as "the measurement API" for alias tracking.
_API_MODULES = ("repro.core", "repro.core.measurement", "repro")
#: Names the API modules export that the linter cares about.
_API_NAMES = (
    "region",
    "init",
    "init_from_env",
    "finalize",
    "active",
    "instrument",
    "metric",
    "Measurement",
    "MeasurementConfig",
)

_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(allow|allow-file)\s*=\s*([\w\-, ]+)"
)


@dataclass
class CallSite:
    """One call expression inside a function (or module) body."""

    callee: str  # dotted best-effort name, e.g. "self.flush", "np.zeros", "f"
    line: int
    loop_depth: int  # number of enclosing for/while loops within the scope


@dataclass
class FunctionInfo:
    """Static facts about one function definition (no execution)."""

    name: str  # bare name
    qualname: str  # co_qualname-style: "Cls.meth", "f.<locals>.g"
    module: str  # dotted module name (framed registration)
    frameless_module: str  # file stem (sys.monitoring registration)
    file: str
    line: int
    is_async: bool = False
    is_generator: bool = False
    is_dunder: bool = False
    is_property: bool = False
    decorators: List[str] = field(default_factory=list)
    body_nodes: int = 0  # AST node count of the body (docstring excluded)
    has_loop: bool = False
    returns_value: bool = False
    #: Body is a single return/expression with no calls — accessor shape.
    simple_body: bool = False
    #: Body is a single call to a name not defined in the scanned set
    #: (presumed C/builtin) — sampler-friendly wrapper shape.
    wrapped_call: Optional[str] = None
    calls: List[CallSite] = field(default_factory=list)
    #: From the matched code object: number of CALL* instructions.
    bytecode_calls: int = 0
    node: Any = None  # the ast.FunctionDef (linter walks bodies)


@dataclass
class ScannedModule:
    """Everything the passes need to know about one source file."""

    path: str
    module: str
    frameless_module: str
    source: str
    lines: List[str]
    tree: Optional[ast.Module]
    functions: List[FunctionInfo]
    #: Module-body call sites (pseudo-caller for the rate estimate).
    module_calls: List[CallSite]
    #: local name -> API name for measurement-API bindings ("rmon" -> "<module>").
    api_aliases: Dict[str, str]
    #: rule names/ids suppressed for the whole file (# repro-lint: allow-file=...)
    file_suppressions: Set[str]
    #: line -> rule names/ids suppressed on that line (# repro-lint: allow=...)
    line_suppressions: Dict[int, Set[str]]
    parse_error: Optional[str] = None


#: Directory names that are source containers, never package segments.
_CONTAINER_DIRS = {
    "src", "source", "lib", "libs", "site-packages", "dist-packages",
    "test", "tests", "examples", "scripts", "tools", "bin", "python",
}
#: Files marking a project root — the climb never crosses one upward.
_PROJECT_MARKERS = ("pyproject.toml", "setup.py", "setup.cfg", ".git")


def module_name_for(path: str, root: Optional[str] = None) -> str:
    """Dotted module name the live (framed) registry would record.

    Climbs through package directories (``__init__.py`` present).  Two
    extensions cover PEP 420 namespace packages, which have no
    ``__init__.py`` to follow:

    * below an explicit scan ``root``, every directory contributes a
      segment (the caller asserted the root is the import boundary);
    * above that, a single ``__init__``-less level is accepted when it
      looks like a namespace package — an identifier name that is not a
      conventional source container (``src``, ``lib``, …) and not a
      project root (no ``pyproject.toml`` / ``.git``).  One level is the
      common real-world shape (``src/<ns>/pkg/…``) and bounding it keeps
      the climb from swallowing arbitrary parent directories.
    """
    apath = os.path.abspath(path)
    base = os.path.basename(apath)
    stem = base[:-3] if base.endswith(".py") else base
    parts = [] if stem == "__init__" else [stem]
    d = os.path.dirname(apath)
    aroot = os.path.abspath(root) if root else None
    if aroot is not None and os.path.isfile(aroot):
        aroot = os.path.dirname(aroot)
    namespace_budget = 0  # earned by climbing out of a real package level
    while True:
        name = os.path.basename(d)
        has_init = os.path.isfile(os.path.join(d, "__init__.py"))
        below_root = (
            aroot is not None and d != aroot and d.startswith(aroot + os.sep)
        )
        namespace_like = (
            namespace_budget > 0
            and name.isidentifier()
            and name not in _CONTAINER_DIRS
            and not any(
                os.path.exists(os.path.join(d, m)) for m in _PROJECT_MARKERS
            )
        )
        if not (has_init or below_root or namespace_like):
            break
        if has_init or below_root:
            namespace_budget = 1
        else:
            namespace_budget -= 1
        parts.insert(0, name)
        parent = os.path.dirname(d)
        if parent == d:
            break
        d = parent
    return ".".join(parts) if parts else stem


def iter_python_files(paths: List[str]) -> List[Tuple[str, Optional[str]]]:
    """Expand paths to ``(file, scan_root)`` pairs, deterministic order.

    Raises :class:`MissingArtifact` (CLI exit 2) for a nonexistent path or
    when the expansion finds no Python sources at all.
    """
    out: List[Tuple[str, Optional[str]]] = []
    seen: Set[str] = set()
    for p in paths:
        if not os.path.exists(p):
            raise MissingArtifact(
                f"no such file or directory: {p} — `analysis plan/lint` take "
                f"Python files or package directories"
            )
        if os.path.isfile(p):
            ap = os.path.abspath(p)
            if ap.endswith(".py") and ap not in seen:
                seen.add(ap)
                out.append((p, None))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(
                d for d in dirnames
                if not d.startswith(".") and d != "__pycache__"
            )
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                ap = os.path.abspath(full)
                if ap not in seen:
                    seen.add(ap)
                    out.append((full, p))
    if not out:
        raise MissingArtifact(
            f"no Python sources under {', '.join(paths) or '.'}"
        )
    return out


#: Bounded scan cache: when ``analysis plan`` / ``lint`` / ``concurrency``
#: run over the same tree in one process, the parse + compile + dis pass
#: happens once.  Keyed by every file's (path, mtime_ns, size, root) so any
#: edit — or a different path expansion — misses cleanly.
_SCAN_CACHE: "OrderedDict[Tuple, List[ScannedModule]]" = OrderedDict()
_SCAN_CACHE_MAX = 4


def scan_paths(paths: List[str]) -> List[ScannedModule]:
    """Scan files/directories into :class:`ScannedModule` records.

    Files that fail to parse are kept (with ``parse_error`` set) so the
    caller can report them without aborting the whole pass.  Results are
    served from a bounded in-process cache while the underlying files are
    unchanged; callers receive a fresh list over shared (read-only by
    convention) module records.
    """
    files = iter_python_files(paths)
    sig = []
    for f, root in files:
        try:
            st = os.stat(f)
            entry = (os.path.abspath(f), st.st_mtime_ns, st.st_size)
        except OSError:
            entry = (os.path.abspath(f), -1, -1)
        sig.append(entry + (os.path.abspath(root) if root else None,))
    key = tuple(sig)
    cached = _SCAN_CACHE.get(key)
    if cached is not None:
        _SCAN_CACHE.move_to_end(key)
        return list(cached)
    modules = [_scan_file(f, root) for f, root in files]
    _SCAN_CACHE[key] = modules
    while len(_SCAN_CACHE) > _SCAN_CACHE_MAX:
        _SCAN_CACHE.popitem(last=False)
    return list(modules)


def clear_scan_cache() -> None:
    """Drop the scan cache (tests and long-lived processes)."""
    _SCAN_CACHE.clear()


# ---------------------------------------------------------------------------
# per-file scan
# ---------------------------------------------------------------------------


def _scan_file(path: str, root: Optional[str]) -> ScannedModule:
    module = module_name_for(path, root)
    frameless = _module_from_filename(path)
    try:
        with open(path, encoding="utf-8", errors="replace") as fh:
            source = fh.read()
    except OSError as exc:
        raise MissingArtifact(f"unreadable source {path}: {exc}") from exc
    lines = source.splitlines()
    mod = ScannedModule(
        path=path,
        module=module,
        frameless_module=frameless,
        source=source,
        lines=lines,
        tree=None,
        functions=[],
        module_calls=[],
        api_aliases={},
        file_suppressions=set(),
        line_suppressions={},
    )
    _collect_pragmas(mod)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        mod.parse_error = f"{type(exc).__name__}: {exc.msg} (line {exc.lineno})"
        return mod
    mod.tree = tree
    mod.api_aliases = _collect_api_aliases(tree)
    bytecode_index = _index_code_objects(source, path)
    walker = _FunctionWalker(mod, bytecode_index)
    walker.walk(tree)
    return mod


def _collect_pragmas(mod: ScannedModule) -> None:
    for lineno, line in enumerate(mod.lines, start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
        if m.group(1) == "allow-file":
            mod.file_suppressions |= rules
        else:
            mod.line_suppressions.setdefault(lineno, set()).update(rules)


def _collect_api_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the measurement-API entity they bind.

    ``import repro.core as rmon`` -> ``{"rmon": "<module>"}``;
    ``from repro.core import region, init`` -> ``{"region": "region", ...}``.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in _API_MODULES:
                    aliases[(a.asname or a.name).split(".")[0]] = "<module>"
        elif isinstance(node, ast.ImportFrom):
            if node.module in _API_MODULES:
                for a in node.names:
                    if a.name in _API_NAMES:
                        aliases[a.asname or a.name] = a.name
            elif node.module == "repro" and node.level == 0:
                for a in node.names:
                    if a.name == "core":
                        aliases[a.asname or "core"] = "<module>"
    return aliases


def _index_code_objects(source: str, path: str) -> Dict[Tuple[str, int], Any]:
    """Compile (not execute) the module and index nested code objects.

    Keyed by ``(co_name, co_firstlineno)`` so AST function defs can be
    matched to their bytecode for ``dis``-level facts (call instruction
    counts, generator/coroutine flags).  Compilation failure is tolerated —
    the AST walk already captured structure.
    """
    index: Dict[Tuple[str, int], Any] = {}
    try:
        top = compile(source, path, "exec")
    except (SyntaxError, ValueError):
        return index
    stack = [top]
    while stack:
        code = stack.pop()
        index.setdefault((code.co_name, code.co_firstlineno), code)
        for const in code.co_consts:
            if isinstance(const, type(top)):
                stack.append(const)
    return index


_LOOP_NODES = (ast.For, ast.While, ast.AsyncFor)
_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted rendering of a call target expression."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return dotted_name(node.func) + "()"
    return ""


class _FunctionWalker:
    """AST walk building qualnames, call sites, and shape classification."""

    def __init__(self, mod: ScannedModule, bytecode_index: Dict[Tuple[str, int], Any]):
        self.mod = mod
        self.bytecode = bytecode_index

    def walk(self, tree: ast.Module) -> None:
        self._scope(tree.body, qual_prefix="", loop_depth=0,
                    sink=self.mod.module_calls)

    def _scope(self, body: List[ast.stmt], qual_prefix: str, loop_depth: int,
               sink: List[CallSite]) -> None:
        for stmt in body:
            self._stmt(stmt, qual_prefix, loop_depth, sink)

    def _stmt(self, stmt: ast.stmt, qual_prefix: str, loop_depth: int,
              sink: List[CallSite]) -> None:
        if isinstance(stmt, _FUNC_NODES):
            self._function(stmt, qual_prefix)
            return
        if isinstance(stmt, ast.ClassDef):
            prefix = f"{qual_prefix}{stmt.name}."
            self._scope(stmt.body, prefix, loop_depth, sink)
            return
        if isinstance(stmt, _LOOP_NODES):
            for expr_field in ("iter", "test"):
                sub = getattr(stmt, expr_field, None)
                if sub is not None:
                    self._calls_in(sub, loop_depth, sink)
            self._scope(stmt.body, qual_prefix, loop_depth + 1, sink)
            self._scope(stmt.orelse, qual_prefix, loop_depth, sink)
            return
        # Generic statement: collect calls at this depth, recurse into any
        # nested statement lists (if/with/try bodies stay at the same depth).
        for field_name, value in ast.iter_fields(stmt):
            if isinstance(value, list) and value and isinstance(value[0], ast.stmt):
                self._scope(value, qual_prefix, loop_depth, sink)
            elif isinstance(value, ast.expr):
                self._calls_in(value, loop_depth, sink)
            elif isinstance(value, list):
                for item in value:
                    if isinstance(item, ast.stmt):
                        self._scope([item], qual_prefix, loop_depth, sink)
                    elif isinstance(item, ast.expr):
                        self._calls_in(item, loop_depth, sink)
                    elif isinstance(item, (ast.withitem, ast.excepthandler)):
                        self._handler_like(item, qual_prefix, loop_depth, sink)

    def _handler_like(self, item: Any, qual_prefix: str, loop_depth: int,
                      sink: List[CallSite]) -> None:
        if isinstance(item, ast.withitem):
            self._calls_in(item.context_expr, loop_depth, sink)
        elif isinstance(item, ast.excepthandler):
            self._scope(item.body, qual_prefix, loop_depth, sink)

    def _calls_in(self, expr: ast.expr, loop_depth: int,
                  sink: List[CallSite]) -> None:
        stack: List[Tuple[ast.AST, int]] = [(expr, loop_depth)]
        while stack:
            node, depth = stack.pop()
            if isinstance(node, ast.Lambda):
                continue  # the lambda body does not run at this site
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name:
                    sink.append(CallSite(
                        callee=name,
                        line=getattr(node, "lineno", 0),
                        loop_depth=depth,
                    ))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                # Comprehension bodies iterate: calls inside run per element.
                depth += 1
            for child in ast.iter_child_nodes(node):
                stack.append((child, depth))

    # -- one function def --------------------------------------------------

    def _function(self, node: ast.stmt, qual_prefix: str) -> None:
        qualname = f"{qual_prefix}{node.name}"
        info = FunctionInfo(
            name=node.name,
            qualname=qualname,
            module=self.mod.module,
            frameless_module=self.mod.frameless_module,
            file=self.mod.path,
            line=node.lineno,
            is_async=isinstance(node, ast.AsyncFunctionDef),
            decorators=[dotted_name(d) for d in node.decorator_list],
            node=node,
        )
        info.is_dunder = (
            node.name.startswith("__") and node.name.endswith("__")
        )
        info.is_property = any(
            d in ("property", "cached_property", "functools.cached_property")
            or d.endswith(".setter") or d.endswith(".getter")
            for d in info.decorators
        )

        body = node.body
        if (body and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)):
            body = body[1:]  # docstring is not behavior

        info.body_nodes = sum(1 for _ in _walk_own(body))

        # Generator / loop / return facts — nested defs excluded.
        for sub in _walk_own(body):
            if isinstance(sub, (ast.Yield, ast.YieldFrom)):
                info.is_generator = True
            elif isinstance(sub, _LOOP_NODES):
                info.has_loop = True
            elif isinstance(sub, ast.Return) and sub.value is not None:
                info.returns_value = True

        # Call sites, with loop depth relative to this function's body.
        self._scope(body, f"{qualname}.<locals>.", 0, info.calls)

        # Shape classification of the (docstring-stripped) body.
        if len(body) == 1:
            stmt = body[0]
            expr = None
            if isinstance(stmt, ast.Return):
                expr = stmt.value
            elif isinstance(stmt, ast.Expr):
                expr = stmt.value
            elif isinstance(stmt, ast.Pass):
                info.simple_body = True
            if expr is not None:
                calls = [n for n in ast.walk(expr) if isinstance(n, ast.Call)]
                if not calls and info.body_nodes <= 12:
                    info.simple_body = True
                elif (len(calls) == 1 and isinstance(expr, ast.Call)
                      and expr is calls[0]):
                    info.wrapped_call = dotted_name(expr.func)

        code = self.bytecode.get((node.name, node.lineno))
        if code is None:
            # Decorated defs: co_firstlineno may point at the first decorator.
            for deco in node.decorator_list:
                code = self.bytecode.get((node.name, deco.lineno))
                if code is not None:
                    break
        if code is not None:
            info.bytecode_calls = sum(
                1 for ins in dis.get_instructions(code)
                if ins.opname.startswith("CALL")
            )
            flags = code.co_flags
            if flags & 0x20 or flags & 0x200:  # CO_GENERATOR | CO_ASYNC_GENERATOR
                info.is_generator = True
            if flags & 0x80:  # CO_COROUTINE
                info.is_async = True

        self.mod.functions.append(info)
        # Module-level fan-in: a def statement itself is not a call; nested
        # defs are reached through the recursion above.


def _walk_own(body: List[ast.stmt]):
    """Walk statements, not descending into nested function definitions."""
    stack: List[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FUNC_NODES):
                continue
            stack.append(child)
