"""Measurement-API linter — static misuse detection with stable rule ids.

Rules (ids are stable; renumbering is a breaking change):

========  ===========================  =============================================
id        name                         catches
========  ===========================  =============================================
SP101     region-not-entered           ``region(...)`` created but never entered:
                                       a bare expression statement, or assigned to
                                       a name that is never used again — the
                                       enter/exit pair never fires, the region
                                       silently records nothing.
SP102     measurement-not-finalized    a module starts measurement (``init(...)``
                                       or ``Measurement(...)`` + ``.start()``)
                                       but never references ``finalize`` —
                                       buffers never drain, artifacts are
                                       incomplete unless the atexit hook saves it.
SP201     foreign-hook-install         ``sys.settrace`` / ``sys.setprofile`` /
                                       ``threading.settrace`` with a non-None
                                       tool, or ``sys.monitoring`` tool
                                       registration — collides with the active
                                       instrumenter (last writer wins, silently).
SP202     thread-before-install        a thread is started lexically before the
                                       instrumenter installs in the same scope —
                                       per-thread hooks miss it forever.
SP301     blocking-call-in-hot-region  a blocking call (sleep, subprocess,
                                       blocking I/O) inside a ``with region(...)``
                                       block classified hot (loop-nested or in a
                                       hot function) — the wait time is charged
                                       to the region and dilates every iteration.
========  ===========================  =============================================

The SP4xx concurrency rules (lock-order inversion, race candidates,
blocking-in-coroutine, fork-after-threads, unjoined work) live in
:mod:`.concurrency` and are folded into this linter's rule set — one
``lint_paths`` call runs both families over a single shared scan.

Suppression pragmas (line- or file-scoped, by rule id or name)::

    sys.setprofile(cb)  # repro-lint: allow=SP201
    # repro-lint: allow-file=foreign-hook-install

Diagnostics are ``file:line: id name: message`` — one line per violation,
deterministic order.  The CLI (``analysis lint``) exits 1 when violations
remain, 0 when clean, 2 on a bad path.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from .classify import classify_modules
from .concgraph import BLOCKING_CALLS as _BLOCKING_CALLS
from .concurrency import CONCURRENCY_RULES, analyze_modules
from .scanner import (
    ScannedModule,
    _FUNC_NODES,
    dotted_name,
    scan_paths,
)

#: Stable rule registry: id -> name.  SP1xx lifecycle, SP2xx environment,
#: SP3xx distortion, SP4xx concurrency (defined in :mod:`.concurrency`).
RULES = {
    "SP101": "region-not-entered",
    "SP102": "measurement-not-finalized",
    "SP201": "foreign-hook-install",
    "SP202": "thread-before-install",
    "SP301": "blocking-call-in-hot-region",
    **CONCURRENCY_RULES,
}

_FOREIGN_HOOKS = {
    ("sys", "settrace"),
    ("sys", "setprofile"),
    ("threading", "settrace"),
    ("threading", "setprofile"),
}
_MONITORING_TOOLS = {
    "use_tool_id",
    "register_callback",
    "set_events",
    "set_local_events",
}


@dataclass(frozen=True)
class Violation:
    rule_id: str
    file: str
    line: int
    message: str

    @property
    def rule(self) -> str:
        return RULES[self.rule_id]

    def format(self) -> str:
        return f"{self.file}:{self.line}: {self.rule_id} {self.rule}: {self.message}"


def lint_paths(paths: List[str]) -> List[Violation]:
    """Lint files/directories; returns suppression-filtered violations in
    ``(file, line, rule)`` order.  Raises :class:`MissingArtifact` for a
    bad path (CLI exit 2)."""
    modules = scan_paths(paths)
    hot_functions = {
        (c.info.file, c.info.qualname)
        for c in classify_modules(modules)
        if "hot" in c.classes
    }
    out: List[Violation] = []
    for mod in modules:
        if mod.tree is None:
            continue  # parse errors are the planner's report, not lint rules
        linter = _ModuleLinter(mod, hot_functions)
        out.extend(linter.run())
    # SP4xx: the concurrency passes run over the same scan (already
    # suppression-filtered by analyze_modules).
    _model, findings = analyze_modules(modules)
    out.extend(
        Violation(rule_id=f["rule"], file=f["file"], line=f["line"],
                  message=f["message"])
        for f in findings
    )
    return sorted(out, key=lambda v: (v.file, v.line, v.rule_id))


class _ModuleLinter:
    def __init__(self, mod: ScannedModule, hot_functions: Set[Tuple[str, str]]):
        self.mod = mod
        self.hot = hot_functions
        self.violations: List[Violation] = []
        #: module uses the measurement API at all (gates method-call rules
        #: like ``m.region(...)`` so unrelated ``.region`` attrs stay quiet).
        self.uses_api = bool(mod.api_aliases)

    # -- entry -------------------------------------------------------------

    def run(self) -> List[Violation]:
        tree = self.mod.tree
        self._lifecycle(tree)
        scopes = [("<module>", tree.body, None)]
        for fn in self.mod.functions:
            if fn.node is not None:
                scopes.append((fn.qualname, fn.node.body, fn))
        for qualname, body, fn in scopes:
            self._scope_rules(qualname, body, fn)
        return self._suppress(self.violations)

    def _suppress(self, violations: List[Violation]) -> List[Violation]:
        out = []
        for v in violations:
            keys = {v.rule_id, v.rule}
            if keys & self.mod.file_suppressions:
                continue
            if keys & self.mod.line_suppressions.get(v.line, set()):
                continue
            out.append(v)
        return out

    def _emit(self, rule_id: str, node: ast.AST, message: str) -> None:
        self.violations.append(
            Violation(
                rule_id=rule_id,
                file=self.mod.path,
                line=getattr(node, "lineno", 0),
                message=message,
            )
        )

    # -- API-call resolution ----------------------------------------------

    def _api_call(self, call: ast.Call) -> Optional[str]:
        """Resolve a call to a measurement-API entry point name, if any."""
        func = call.func
        aliases = self.mod.api_aliases
        if isinstance(func, ast.Name):
            bound = aliases.get(func.id)
            return bound if bound and bound != "<module>" else None
        if isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name) and aliases.get(base.id) == "<module>":
                return func.attr
            # rmon bound as repro.core: ``repro.core.init(...)`` renders as
            # Attribute chains; resolve through the dotted text.
            text = dotted_name(func)
            for prefix in ("repro.core.", "core."):
                if text.startswith(prefix):
                    return text[len(prefix):]
        return None

    # -- SP102: measurement lifecycle (module granularity) -----------------

    def _lifecycle(self, tree: ast.Module) -> None:
        starts: List[ast.Call] = []
        has_constructor = False
        has_start_method = False
        references_finalize = False
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                api = self._api_call(node)
                if api in ("init", "init_from_env"):
                    starts.append(node)
                elif api == "Measurement":
                    has_constructor = True
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "start"):
                    has_start_method = True
            if isinstance(node, ast.Name) and node.id == "finalize":
                references_finalize = True
            elif isinstance(node, ast.Attribute) and node.attr == "finalize":
                references_finalize = True
            elif isinstance(node, _FUNC_NODES) and node.name == "finalize":
                references_finalize = True
        if has_constructor and has_start_method and not starts:
            # Measurement(...) ... .start() — same lifecycle obligation.
            for node in ast.walk(tree):
                if (isinstance(node, ast.Call)
                        and self._api_call(node) == "Measurement"):
                    starts.append(node)
                    break
        if starts and not references_finalize:
            self._emit(
                "SP102",
                starts[0],
                "measurement is started here but the module never calls "
                "finalize() — buffers only drain on interpreter exit",
            )

    # -- per-scope rules ---------------------------------------------------

    def _scope_rules(self, qualname: str, body: List[ast.stmt], fn) -> None:
        self._region_not_entered(body)
        self._thread_before_install(body)
        self._foreign_hooks(body)
        self._blocking_in_hot_region(qualname, body, fn)

    def _is_region_call(self, call: ast.Call) -> bool:
        if self._api_call(call) == "region":
            return True
        func = call.func
        return (
            self.uses_api
            and isinstance(func, ast.Attribute)
            and func.attr == "region"
        )

    def _region_not_entered(self, body: List[ast.stmt]) -> None:
        statements = list(_own_statements(body))
        for stmt in statements:
            if (isinstance(stmt, ast.Expr)
                    and isinstance(stmt.value, ast.Call)
                    and self._is_region_call(stmt.value)):
                self._emit(
                    "SP101",
                    stmt,
                    "region(...) is never entered — wrap it in a `with` "
                    "block or the enter/exit pair never fires",
                )
            elif (isinstance(stmt, ast.Assign)
                    and isinstance(stmt.value, ast.Call)
                    and self._is_region_call(stmt.value)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                name = stmt.targets[0].id
                used = any(
                    isinstance(n, ast.Name) and n.id == name
                    and n is not stmt.targets[0]
                    for s in statements
                    for n in ast.walk(s)
                )
                if not used:
                    self._emit(
                        "SP101",
                        stmt,
                        f"region handle {name!r} is assigned but never "
                        f"entered (unused) — the region records nothing",
                    )

    def _thread_before_install(self, body: List[ast.stmt]) -> None:
        install_line = None
        thread_names: Set[str] = set()
        thread_starts: List[ast.AST] = []
        # Pass 1: install points + names bound to threading.Thread(...).
        for node in _scope_walk(body):
            if isinstance(node, ast.Assign):
                if (isinstance(node.value, ast.Call)
                        and dotted_name(node.value.func)
                        in ("threading.Thread", "Thread")
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    thread_names.add(node.targets[0].id)
            elif isinstance(node, ast.Call):
                if self._api_call(node) in ("init", "init_from_env"):
                    line = node.lineno
                    install_line = min(install_line or line, line)
        if install_line is None:
            return
        # Pass 2: .start() on a known thread name or an inline Thread(...).
        for node in _scope_walk(body):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "start"):
                continue
            base = node.func.value
            if isinstance(base, ast.Name) and base.id in thread_names:
                thread_starts.append(node)
            elif (isinstance(base, ast.Call)
                  and dotted_name(base.func) in ("threading.Thread", "Thread")):
                thread_starts.append(node)
        for node in thread_starts:
            if node.lineno < install_line:
                self._emit(
                    "SP202",
                    node,
                    "thread started before the instrumenter installs — "
                    "per-thread hooks never cover it; move init() first",
                )

    def _foreign_hooks(self, body: List[ast.stmt]) -> None:
        for node in _scope_walk(body):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not isinstance(func, ast.Attribute):
                continue
            text = dotted_name(func)
            parts = tuple(text.split("."))
            if parts in _FOREIGN_HOOKS:
                if node.args and _is_none(node.args[0]):
                    continue  # clearing a hook is benign
                self._emit(
                    "SP201",
                    node,
                    f"{text}(...) replaces the active instrumenter's "
                    f"hook (last writer wins, silently) — use the "
                    f"measurement API instead",
                )
            elif (
                len(parts) >= 3
                and parts[0] == "sys"
                and parts[1] == "monitoring"
                and parts[-1] in _MONITORING_TOOLS
            ):
                self._emit(
                    "SP201",
                    node,
                    f"{text}(...) registers a sys.monitoring tool that "
                    f"collides with the PEP 669 instrumenters",
                )

    def _blocking_in_hot_region(self, qualname: str, body: List[ast.stmt],
                                fn) -> None:
        fn_is_hot = fn is not None and (fn.file, fn.qualname) in self.hot
        for with_node, loop_nested in _region_withs(body, self._is_region_call):
            if not (loop_nested or fn_is_hot):
                continue
            for stmt in with_node.body:
                for node in _walk_no_defs(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    text = dotted_name(node.func)
                    if text in _BLOCKING_CALLS:
                        self._emit(
                            "SP301",
                            node,
                            f"blocking call {text}(...) inside a hot region "
                            f"— the wait is charged to the region and "
                            f"dilates every iteration",
                        )


# ---------------------------------------------------------------------------
# small AST walkers
# ---------------------------------------------------------------------------


def _is_none(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def _own_statements(body: List[ast.stmt]):
    """All statements of a scope, not descending into nested defs."""
    stack: List[ast.stmt] = list(body)
    while stack:
        stmt = stack.pop()
        yield stmt
        if isinstance(stmt, _FUNC_NODES + (ast.ClassDef,)):
            continue
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                stack.append(child)
            else:
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        stack.append(sub)


def _scope_walk(body: List[ast.stmt]):
    """Every node of a scope exactly once, not descending into nested
    defs/classes (their bodies are linted as their own scopes).  The guard
    is on the popped node, not its children: a def at the top of ``body``
    must not be expanded either."""
    stack: List[ast.AST] = list(body)
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, _FUNC_NODES + (ast.ClassDef,)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _walk_no_defs(node: ast.AST):
    """ast.walk that does not descend into nested function definitions."""
    stack: List[ast.AST] = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, _FUNC_NODES):
                continue
            stack.append(child)


def _region_withs(body: List[ast.stmt], is_region_call):
    """Yield ``(With, loop_nested)`` for region-with blocks in a scope."""
    stack: List[Tuple[ast.stmt, bool]] = [(s, False) for s in body]
    while stack:
        stmt, in_loop = stack.pop()
        if isinstance(stmt, _FUNC_NODES + (ast.ClassDef,)):
            continue
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            if any(
                isinstance(item.context_expr, ast.Call)
                and is_region_call(item.context_expr)
                for item in stmt.items
            ):
                yield stmt, in_loop
        nested_loop = in_loop or isinstance(
            stmt, (ast.For, ast.While, ast.AsyncFor)
        )
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                stack.append((child, nested_loop))
            else:
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        stack.append((sub, nested_loop))
