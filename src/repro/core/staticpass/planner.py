"""Static instrumentation plan — ``static_plan.json``.

The planner turns the scanner + classifier output into the artifact the
measurement stack consumes (see docs/ARTIFACTS.md):

* a filter spec built from ``exclude!`` clauses only, so it round-trips
  ``Filter.from_spec`` and merges into any user spec under the established
  absolute-exclude precedence (it can only ever *remove* regions — an
  include-only allow-list stays one);
* every exclude pattern is emitted in both module forms — the dotted module
  path (framed registration) and the file stem (frameless ``sys.monitoring``
  registration) — so one plan works under every instrumenter family;
* predicted offenders (the ``hot`` class, ranked by estimated rate) and
  per-cost-class weights, which warm-start the governor's escalation ladder
  (:mod:`.integrate`).

Like every artifact, the plan is schema-stamped (``report_schema_version``)
and :func:`load_plan` raises :class:`MissingArtifact` — CLI exit 2 — when
missing or unreadable.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from ..filtering import Filter
from ..schema import MissingArtifact, stamp
from .classify import COST_WEIGHTS, Classified, classify_modules
from .scanner import ScannedModule, scan_paths

ARTIFACT = "static_plan.json"

#: Cap on predicted-offender rows kept in the plan document.
_MAX_OFFENDERS = 50


def _fnmatch_escape(name: str) -> str:
    from ..governor import _fnmatch_escape as esc  # single escaping seam

    return esc(name)


def build_plan(paths: List[str]) -> Dict[str, Any]:
    """Scan ``paths`` and build the plan document (schema-stamped dict).

    The concurrency section (finding counts + wait-point candidates the
    governor treats as sampler-friendly) rides along from the same scan —
    the scanner cache means no file is parsed twice."""
    from .concurrency import analyze_modules, summarize_for_static_plan

    modules = scan_paths(paths)
    classified = classify_modules(modules)
    plan = _assemble(paths, modules, classified)
    model, findings = analyze_modules(modules)
    plan["concurrency"] = summarize_for_static_plan(model, findings)
    return plan


def _assemble(
    paths: List[str],
    modules: List[ScannedModule],
    classified: List[Classified],
) -> Dict[str, Any]:
    records: List[Dict[str, Any]] = []
    patterns: List[str] = []
    seen_patterns = set()
    verdict_counts = {"keep": 0, "exclude": 0, "sample": 0}
    for c in classified:
        fn = c.info
        verdict_counts[c.verdict] = verdict_counts.get(c.verdict, 0) + 1
        records.append(
            {
                "module": fn.module,
                "frameless_module": fn.frameless_module,
                "name": fn.qualname,
                "file": fn.file,
                "line": fn.line,
                "classes": list(c.classes),
                "cost_class": c.cost_class,
                "cost_weight": COST_WEIGHTS.get(c.cost_class, 1.0),
                "est_rate": round(c.est_rate, 3),
                "verdict": c.verdict,
            }
        )
        if c.verdict == "exclude":
            for mod_name in {fn.module, fn.frameless_module}:
                pat = f"{_fnmatch_escape(mod_name)}.{_fnmatch_escape(fn.qualname)}"
                if pat not in seen_patterns:
                    seen_patterns.add(pat)
                    patterns.append(pat)
    offenders = sorted(
        (c for c in classified if "hot" in c.classes),
        key=lambda c: -c.est_rate,
    )[:_MAX_OFFENDERS]
    errors = [
        {"file": m.path, "error": m.parse_error}
        for m in modules
        if m.parse_error
    ]
    spec = Filter(runtime_exclude=list(patterns)).to_spec()
    return stamp(
        {
            "generator": "repro.core.staticpass",
            "roots": list(paths),
            "files": len(modules),
            "functions": len(records),
            "verdicts": verdict_counts,
            "records": records,
            "filter": {"spec": spec, "patterns": patterns},
            "predicted_offenders": [
                {
                    "region": f"{c.info.module}:{c.info.qualname}",
                    "frameless_region": (
                        f"{c.info.frameless_module}:{c.info.qualname}"
                    ),
                    "est_rate": round(c.est_rate, 3),
                    "classes": list(c.classes),
                    "verdict": c.verdict,
                }
                for c in offenders
            ],
            "calibration_seed": {"cost_weights": dict(COST_WEIGHTS)},
            "errors": errors,
        }
    )


# ---------------------------------------------------------------------------
# persistence + consumers
# ---------------------------------------------------------------------------


def save_plan(plan: Dict[str, Any], path: str) -> str:
    out_dir = os.path.dirname(os.path.abspath(path))
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(plan, fh, indent=1)
    return path


def load_plan(path: str) -> Dict[str, Any]:
    """Read a plan; directory arguments resolve to ``static_plan.json``
    inside.  Raises :class:`MissingArtifact` (CLI exit 2) when absent,
    unreadable, or not a plan document."""
    if os.path.isdir(path):
        path = os.path.join(path, ARTIFACT)
    if not os.path.exists(path):
        raise MissingArtifact(
            f"no static plan at {path or '.'} — generate one with "
            f"`python -m repro.core.analysis plan <package>`"
        )
    try:
        with open(path) as fh:
            plan = json.load(fh)
    except (OSError, ValueError) as exc:
        raise MissingArtifact(f"unreadable static plan {path}: {exc}") from exc
    if not isinstance(plan, dict) or "filter" not in plan:
        raise MissingArtifact(
            f"{path} is not a static plan (no filter section) — regenerate "
            f"with `python -m repro.core.analysis plan`"
        )
    return plan


def plan_exclude_patterns(plan: Dict[str, Any]) -> List[str]:
    """The plan's absolute-exclude patterns (both module forms, deduped)."""
    return list(plan.get("filter", {}).get("patterns", []))


def predicted_offenders(plan: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Predicted offender rows, highest estimated rate first."""
    return list(plan.get("predicted_offenders", []))


def verify_plan(plan: Dict[str, Any]) -> None:
    """Assert the plan's spec round-trips ``Filter.from_spec`` and its
    verdicts survive the round trip (the ``analysis plan --smoke`` gate).

    Self-suppressed modules (the measurement core drops its own regions
    unconditionally) are skipped for keep-verdict checks — their verdict is
    decided by the core filter, not the plan."""
    spec = plan.get("filter", {}).get("spec", "")
    flt = Filter.from_spec(spec)
    assert flt.to_spec() == spec, "plan spec must round-trip Filter.to_spec"
    # Either module form of any excluded record; a keep record colliding
    # with one of these (same stem + function name in another package) is
    # legitimately caught by the shared pattern, so it is not a verdict
    # violation.
    excluded_forms = {
        (m, r["name"])
        for r in plan.get("records", [])
        if r["verdict"] == "exclude"
        for m in (r["module"], r["frameless_module"])
    }
    for r in plan.get("records", []):
        for mod_name in (r["module"], r["frameless_module"]):
            verdict = flt.decide(mod_name, r["name"], r["file"])
            if r["verdict"] == "exclude":
                assert not verdict, (
                    f"planned exclude not filtered: {mod_name}.{r['name']}"
                )
            elif (
                (mod_name, r["name"]) not in excluded_forms
                and not mod_name.startswith("repro.core")
                and "repro/core/" not in r["file"].replace(os.sep, "/")
            ):
                assert verdict, (
                    f"planned keep filtered out: {mod_name}.{r['name']}"
                )


def render_plan(plan: Dict[str, Any], top: int = 15) -> str:
    """Human-readable plan summary (the ``analysis plan`` stdout)."""
    v = plan.get("verdicts", {})
    out = [
        f"scanned {plan.get('files', 0)} files, "
        f"{plan.get('functions', 0)} functions: "
        f"{v.get('exclude', 0)} exclude, {v.get('sample', 0)} sample, "
        f"{v.get('keep', 0)} keep"
    ]
    for err in plan.get("errors", []):
        out.append(f"  ! {err['file']}: {err['error']}")
    offenders = predicted_offenders(plan)
    if offenders:
        out.append(f"{'est_rate':>10s} {'verdict':>8s}  predicted offender")
        for row in offenders[:top]:
            out.append(
                f"{row['est_rate']:10.1f} {row['verdict']:>8s}  "
                f"{row['region']} [{','.join(row['classes'])}]"
            )
    spec = plan.get("filter", {}).get("spec", "")
    if spec:
        shown = spec if len(spec) <= 200 else spec[:200] + "…"
        out.append(f"filter spec ({len(plan['filter']['patterns'])} patterns): {shown}")
    else:
        out.append("filter spec: (empty — nothing auto-excluded)")
    conc = plan.get("concurrency")
    if conc:
        counts = conc.get("findings", {})
        flagged = sum(counts.values())
        out.append(
            f"concurrency: {conc.get('entrypoints', 0)} entrypoints, "
            f"{conc.get('locks', 0)} locks, "
            f"{len(conc.get('wait_points', []))} wait points, "
            f"{flagged} SP4xx findings"
            + (" — run `analysis concurrency` for details" if flagged else "")
        )
    return "\n".join(out)
