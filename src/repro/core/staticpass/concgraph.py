"""Concurrency-structure discovery — the model the SP4xx passes run on.

One walk over the scanned set (:mod:`.scanner` output; pure ast, user code
never imported) produces a :class:`ConcurrencyModel`:

* **import canonicalization** — per-module alias tables so ``mp.Process``,
  ``Thread`` (from-import) and ``threading.Thread`` all resolve to one
  canonical dotted name before any set membership is tested;
* **lock table** — every ``threading.Lock()`` / ``RLock`` / ``Condition`` /
  ``Semaphore`` (+ ``multiprocessing`` / ``asyncio`` variants) creation
  site, identified as ``module:NAME`` (module globals), ``module:Cls.attr``
  (``self.attr = Lock()`` in a method, or a class-body assignment) or
  ``module:func.<locals>.name`` (function locals);
* **acquisition sites** — ``with lock:`` blocks and explicit
  ``lock.acquire()`` / ``release()`` pairs, each recorded with the set of
  locks *already held* at that point (the lock-order graph's edges);
* **call edges** — every resolved intra-package call site, annotated with
  the lexically-held lock set, so lock context propagates across calls;
* **spawn sites** — ``threading.Thread(target=…)``, ``multiprocessing.
  Process(target=…)``, executor ``submit``/``map``, ``asyncio.run`` /
  ``create_task`` / ``to_thread``, plus ``threading.Thread`` subclasses'
  ``run`` methods — with handle binding, ``start``/``join``/``shutdown``
  tracking and a per-scope ordered event list (thread starts vs forks);
* **entrypoints + reachability** — one entrypoint per distinct spawn
  target plus ``<main>`` (module bodies and functions no scanned code
  calls), each with the set of reachable scopes and, per scope, the locks
  *guaranteed* held on every call path (intersection over paths — the
  sound direction for race suppression).

Everything here is an approximation by construction (names, not objects;
statement order, not data flow) — the passes in :mod:`.concurrency` turn it
into findings that say "candidate", never "proof".
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from .classify import _callee_keys, _defined_names
from .scanner import _FUNC_NODES, FunctionInfo, ScannedModule, dotted_name

#: Canonical blocking-call set, shared with the linter's SP301 (raw dotted
#: text) and SP403 (canonicalized through the import table).
BLOCKING_CALLS = {
    "time.sleep",
    "sleep",
    "subprocess.run",
    "subprocess.call",
    "subprocess.check_call",
    "subprocess.check_output",
    "subprocess.Popen",
    "socket.create_connection",
    "urllib.request.urlopen",
    "requests.get",
    "requests.post",
    "requests.request",
    "select.select",
    "input",
}

#: Canonical constructor names that create a lock-like object.
LOCK_CTORS = {
    "threading.Lock": "Lock",
    "threading.RLock": "RLock",
    "threading.Condition": "Condition",
    "threading.Semaphore": "Semaphore",
    "threading.BoundedSemaphore": "BoundedSemaphore",
    "multiprocessing.Lock": "Lock",
    "multiprocessing.RLock": "RLock",
    "asyncio.Lock": "Lock",
    "asyncio.Condition": "Condition",
    "asyncio.Semaphore": "Semaphore",
}

_THREAD_CTORS = {"threading.Thread"}
_PROCESS_CTORS = {"multiprocessing.Process", "multiprocessing.context.Process"}
_EXECUTOR_CTORS = {
    "concurrent.futures.ThreadPoolExecutor",
    "concurrent.futures.ProcessPoolExecutor",
    "concurrent.futures.thread.ThreadPoolExecutor",
}
#: Direct fork-the-process calls (``multiprocessing`` start sites are
#: derived from process/pool spawns instead, where the default Linux start
#: method is fork).
_FORK_CALLS = {"os.fork", "os.forkpty"}
_POOL_CTORS = {"multiprocessing.Pool", "multiprocessing.pool.Pool"}

#: Top-level modules whose imports are tracked for canonicalization.
_TRACKED_ROOTS = {
    "threading", "multiprocessing", "concurrent", "asyncio", "os", "time",
    "queue", "socket", "subprocess", "select", "urllib", "requests",
}


@dataclass(frozen=True)
class Site:
    """One source location inside a scope (``module:qualname`` key)."""

    file: str
    line: int
    scope: str

    def where(self) -> str:
        return f"{self.file}:{self.line}"


@dataclass
class LockDef:
    lock_id: str
    kind: str  # Lock / RLock / Condition / Semaphore / BoundedSemaphore
    attr: Optional[str]  # attribute name for self.X / class-body locks
    site: Site


@dataclass
class Acquire:
    lock_id: str
    site: Site
    held_before: Tuple[str, ...]
    via: str  # "with" | "acquire"


@dataclass
class CallEdge:
    caller: str
    callee: str  # resolved scope key
    site: Site
    held: Tuple[str, ...]


@dataclass
class Spawn:
    kind: str  # thread | process | executor | executor-task | task | to_thread
    targets: Tuple[str, ...]  # resolved scope keys (may be empty)
    target_text: str
    site: Site
    handle: Optional[Tuple[str, ...]] = None  # ("local", scope, name) | ("attr", module, name)
    started: bool = False
    joined: bool = False
    shutdown: bool = False
    managed: bool = False  # created as a `with` context manager
    daemon: bool = False
    start_site: Optional[Site] = None


@dataclass
class GlobalWrite:
    var: str  # "module:NAME"
    site: Site
    held: Tuple[str, ...]


@dataclass
class BlockingCall:
    callee: str  # canonical dotted name
    site: Site


@dataclass
class Entrypoint:
    name: str  # "<main>" | "thread:<key>" | "process:<key>" | ...
    kind: str
    roots: Tuple[str, ...]
    site: Optional[Site]
    #: scope key -> locks guaranteed held on *every* scanned path from the
    #: roots (intersection semantics; empty set means "maybe unlocked").
    reachable: Dict[str, frozenset] = field(default_factory=dict)


@dataclass
class ConcurrencyModel:
    modules: List[ScannedModule]
    functions: Dict[str, FunctionInfo]
    locks: Dict[str, LockDef]
    acquires: List[Acquire]
    edges: Dict[str, List[CallEdge]]
    spawns: List[Spawn]
    global_writes: List[GlobalWrite]
    blocking: Dict[str, List[BlockingCall]]  # scope -> direct blocking sites
    #: per-scope ordered events: ("start"|"fork"|"call", payload, Site)
    events: Dict[str, List[Tuple[str, Any, Site]]]
    entrypoints: Dict[str, Entrypoint]
    #: every scope any call site resolved to, at any confidence — scopes in
    #: here are "called somewhere" and not free-standing main entrypoints.
    called: Set[str]
    #: wait-point candidate rows (region/kind/site), deduped.
    wait_points: List[Dict[str, Any]]
    errors: List[Dict[str, str]]

    def function_key(self, fn: FunctionInfo) -> str:
        return f"{fn.module}:{fn.qualname}"


def _fn_key(fn: FunctionInfo) -> str:
    return f"{fn.module}:{fn.qualname}"


def _module_scope(mod: ScannedModule) -> str:
    return f"{mod.module}:<module>"


# ---------------------------------------------------------------------------
# import canonicalization
# ---------------------------------------------------------------------------


def import_table(tree: ast.Module) -> Dict[str, str]:
    """local name -> canonical dotted prefix for tracked stdlib modules."""
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                root = a.name.split(".")[0]
                if root not in _TRACKED_ROOTS:
                    continue
                if a.asname:
                    table[a.asname] = a.name
                else:
                    # `import concurrent.futures` binds `concurrent`.
                    table[root] = root
        elif isinstance(node, ast.ImportFrom):
            if node.level or not node.module:
                continue
            if node.module.split(".")[0] not in _TRACKED_ROOTS:
                continue
            for a in node.names:
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def canonical(table: Dict[str, str], text: str) -> str:
    """Rewrite ``text``'s leading segment through the import table."""
    if not text:
        return text
    head, sep, rest = text.partition(".")
    mapped = table.get(head)
    if mapped is None:
        return text
    return f"{mapped}.{rest}" if rest else mapped


# ---------------------------------------------------------------------------
# model construction
# ---------------------------------------------------------------------------


def build_model(modules: List[ScannedModule]) -> ConcurrencyModel:
    functions: Dict[str, FunctionInfo] = {}
    for mod in modules:
        for fn in mod.functions:
            functions[_fn_key(fn)] = fn
    defined = _defined_names(list(functions.values()))

    model = ConcurrencyModel(
        modules=modules,
        functions=functions,
        locks={},
        acquires=[],
        edges={},
        spawns=[],
        global_writes=[],
        blocking={},
        events={},
        entrypoints={},
        called=set(),
        wait_points=[],
        errors=[
            {"file": m.path, "error": m.parse_error}
            for m in modules
            if m.parse_error
        ],
    )

    builders = []
    for mod in modules:
        if mod.tree is None:
            continue
        table = import_table(mod.tree)
        builders.append((mod, table))
        _collect_locks(model, mod, table)

    # Attribute-name index over the lock table (``self.X`` / ``obj.X``
    # acquisitions resolve through it when the defining class is elsewhere).
    attr_index: Dict[str, List[str]] = {}
    for lock in model.locks.values():
        if lock.attr:
            attr_index.setdefault(lock.attr, []).append(lock.lock_id)
    for ids in attr_index.values():
        ids.sort()

    for mod, table in builders:
        walker = _ScopeWalker(model, mod, table, defined, attr_index)
        walker.walk_module()

    _resolve_spawn_lifecycle(model)
    _build_entrypoints(model, defined)
    _collect_wait_points(model)
    return model


def _class_of(qualname: str) -> Optional[str]:
    """Enclosing class path of a method qualname (None for plain funcs)."""
    if "." not in qualname:
        return None
    head = qualname.rsplit(".", 1)[0]
    if head.endswith("<locals>") or "<locals>" in head.split(".")[-1]:
        return None
    return head


def _collect_locks(model: ConcurrencyModel, mod: ScannedModule,
                   table: Dict[str, str]) -> None:
    """Pass A: every lock-creation assignment in the module."""

    def lock_kind(value: ast.expr) -> Optional[str]:
        if isinstance(value, ast.Call):
            return LOCK_CTORS.get(canonical(table, dotted_name(value.func)))
        return None

    def add(lock_id: str, kind: str, attr: Optional[str], line: int) -> None:
        model.locks.setdefault(
            lock_id,
            LockDef(lock_id=lock_id, kind=kind, attr=attr,
                    site=Site(mod.path, line, _module_scope(mod))),
        )

    # Module body + class bodies (execute at import time).
    def scan_body(body: List[ast.stmt], prefix: str) -> None:
        for stmt in body:
            if isinstance(stmt, ast.ClassDef):
                scan_body(stmt.body, f"{prefix}{stmt.name}.")
            elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                value = stmt.value
                kind = lock_kind(value) if value is not None else None
                if kind is None:
                    continue
                for t in targets:
                    if isinstance(t, ast.Name):
                        attr = t.id if prefix else None
                        add(f"{mod.module}:{prefix}{t.id}", kind, attr,
                            stmt.lineno)

    if mod.tree is not None:
        scan_body(mod.tree.body, "")

    # Function bodies: self.attr = Lock() (instance locks, identified by the
    # enclosing class) and local name = Lock().
    for fn in mod.functions:
        if fn.node is None:
            continue
        cls = _class_of(fn.qualname)
        for stmt in ast.walk(fn.node):
            if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                continue
            targets = (
                stmt.targets if isinstance(stmt, ast.Assign)
                else [stmt.target]
            )
            value = stmt.value
            kind = lock_kind(value) if value is not None else None
            if kind is None:
                continue
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id in ("self", "cls")):
                    owner = cls or fn.qualname
                    add(f"{mod.module}:{owner}.{t.attr}", kind, t.attr,
                        stmt.lineno)
                elif isinstance(t, ast.Name):
                    add(f"{mod.module}:{fn.qualname}.<locals>.{t.id}", kind,
                        None, stmt.lineno)


# ---------------------------------------------------------------------------
# pass B: per-scope walk (held locks, spawns, writes, events)
# ---------------------------------------------------------------------------


class _ScopeWalker:
    """Statement-ordered walk of every scope of one module.

    Tracks the lexically-held lock set (``with`` nesting + explicit
    ``acquire``/``release``), binds spawn handles, and appends the ordered
    ``start``/``fork``/``call`` event stream the SP404 pass replays."""

    def __init__(self, model: ConcurrencyModel, mod: ScannedModule,
                 table: Dict[str, str], defined: Dict[str, List[str]],
                 attr_index: Dict[str, List[str]]):
        self.model = model
        self.mod = mod
        self.table = table
        self.defined = defined
        self.attr_index = attr_index
        # module-level names assigned in the module body (shared-state
        # candidates for SP402's subscript/attribute store detection).
        self.module_names: Set[str] = set()
        if mod.tree is not None:
            for stmt in mod.tree.body:
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            self.module_names.add(t.id)
                elif isinstance(stmt, ast.AnnAssign):
                    if isinstance(stmt.target, ast.Name):
                        self.module_names.add(stmt.target.id)

    # -- public -------------------------------------------------------------

    def walk_module(self) -> None:
        if self.mod.tree is None:
            return
        self._walk_scope(_module_scope(self.mod), self.mod.tree.body,
                         fn=None, is_async=False)
        for fn in self.mod.functions:
            if fn.node is None:
                continue
            self._walk_scope(_fn_key(fn), fn.node.body, fn=fn,
                             is_async=fn.is_async)

    # -- per-scope state ----------------------------------------------------

    def _walk_scope(self, scope: str, body: List[ast.stmt],
                    fn: Optional[FunctionInfo], is_async: bool) -> None:
        self.scope = scope
        self.fn = fn
        self.is_async = is_async
        self.held: List[str] = []
        self.globals_decl: Set[str] = set()
        self.local_locks: Dict[str, str] = {}
        self.local_handles: Dict[str, Spawn] = {}
        # ctor Call nodes already registered as spawns — the generic
        # expression walk must not register them a second time.
        self._consumed: Set[int] = set()
        self.events = self.model.events.setdefault(scope, [])
        if fn is not None:
            prefix = f"{self.mod.module}:{fn.qualname}.<locals>."
            for lock_id in self.model.locks:
                if lock_id.startswith(prefix):
                    self.local_locks[lock_id[len(prefix):]] = lock_id
        self._body(body)

    def _site(self, node: ast.AST) -> Site:
        return Site(self.mod.path, getattr(node, "lineno", 0), self.scope)

    # -- statements ---------------------------------------------------------

    def _body(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, _FUNC_NODES):
            return  # nested defs are their own scopes
        if isinstance(stmt, ast.ClassDef):
            # Class bodies at this scope execute inline (locks were taken in
            # pass A); methods are separate scopes.
            self._body([s for s in stmt.body
                        if not isinstance(s, _FUNC_NODES)])
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            self._with(stmt)
            return
        if isinstance(stmt, ast.Global):
            self.globals_decl.update(stmt.names)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._assign(stmt)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr(stmt.iter)
            self._body(stmt.body)
            self._body(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self._expr(stmt.test)
            self._body(stmt.body)
            self._body(stmt.orelse)
            return
        if isinstance(stmt, ast.If):
            self._expr(stmt.test)
            self._body(stmt.body)
            self._body(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self._body(stmt.body)
            for h in stmt.handlers:
                self._body(h.body)
            self._body(stmt.orelse)
            self._body(stmt.finalbody)
            return
        # Generic statement: expressions at this point, nested statement
        # lists (match cases, TryStar, ...) recursively.
        for _name, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                self._expr(value)
            elif isinstance(value, list):
                for item in value:
                    if isinstance(item, ast.stmt):
                        self._stmt(item)
                    elif isinstance(item, ast.expr):
                        self._expr(item)
                    elif hasattr(item, "body") and isinstance(
                            getattr(item, "body"), list):
                        self._body([s for s in item.body
                                    if isinstance(s, ast.stmt)])

    def _with(self, stmt: ast.stmt) -> None:
        pushed: List[str] = []
        for item in stmt.items:
            ctx = item.context_expr
            # Executor created as a context manager never leaks.
            spawn = self._spawn_from_call(ctx) if isinstance(ctx, ast.Call) else None
            if spawn is not None:
                spawn.managed = True
                spawn.started = True
                self._bind_optional_vars(item.optional_vars, spawn)
            self._expr(ctx)
            lock_id = self._resolve_lock_expr(ctx)
            if lock_id is not None:
                self.model.acquires.append(Acquire(
                    lock_id=lock_id, site=self._site(ctx),
                    held_before=tuple(self.held), via="with",
                ))
                self.held.append(lock_id)
                pushed.append(lock_id)
        self._body(stmt.body)
        for lock_id in reversed(pushed):
            self.held.remove(lock_id)

    def _bind_optional_vars(self, target: Optional[ast.expr],
                            spawn: Spawn) -> None:
        if isinstance(target, ast.Name):
            spawn.handle = ("local", self.scope, target.id)
            self.local_handles[target.id] = spawn

    def _assign(self, stmt: ast.stmt) -> None:
        value = getattr(stmt, "value", None)
        targets = (
            stmt.targets if isinstance(stmt, ast.Assign)
            else [stmt.target]
        )
        spawn = (
            self._spawn_from_call(value)
            if isinstance(value, ast.Call) else None
        )
        if spawn is not None:
            for t in targets:
                if isinstance(t, ast.Name):
                    spawn.handle = ("local", self.scope, t.id)
                    self.local_handles[t.id] = spawn
                elif (isinstance(t, ast.Attribute)
                      and isinstance(t.value, ast.Name)
                      and t.value.id in ("self", "cls")):
                    spawn.handle = ("attr", self.mod.module, t.attr)
        if value is not None:
            self._expr(value)
        for t in targets:
            self._write_target(t, stmt)

    def _write_target(self, target: ast.expr, stmt: ast.stmt) -> None:
        """Record shared-state writes (SP402 candidates)."""
        if self.fn is None:
            return  # module-body assignments are initialization, not races
        if isinstance(target, ast.Name):
            if target.id in self.globals_decl:
                self._global_write(target.id, stmt)
        elif isinstance(target, (ast.Attribute, ast.Subscript)):
            base = target.value
            if isinstance(base, ast.Name):
                name = base.id
                if name in self.globals_decl or (
                        name in self.module_names
                        and name not in self.local_handles):
                    self._global_write(name, stmt)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._write_target(el, stmt)

    def _global_write(self, name: str, stmt: ast.stmt) -> None:
        self.model.global_writes.append(GlobalWrite(
            var=f"{self.mod.module}:{name}",
            site=self._site(stmt),
            held=tuple(self.held),
        ))

    # -- expressions --------------------------------------------------------

    def _expr(self, expr: Optional[ast.expr]) -> None:
        if expr is None:
            return
        stack: List[ast.AST] = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.Lambda,) + _FUNC_NODES):
                continue  # deferred bodies don't run at this site
            if isinstance(node, ast.Call):
                self._call(node)
            for child in ast.iter_child_nodes(node):
                stack.append(child)

    def _call(self, call: ast.Call) -> None:
        if id(call) in self._consumed:
            return  # already registered as a spawn by the owning statement
        text = dotted_name(call.func)
        canon = canonical(self.table, text)
        site = self._site(call)

        # Spawn constructors used inline: Thread(...).start().
        if isinstance(call.func, ast.Attribute):
            base = call.func.value
            attr = call.func.attr
            if attr == "start" and isinstance(base, ast.Call):
                spawn = self._spawn_from_call(base)
                if spawn is not None:
                    spawn.started = True
                    spawn.start_site = site
                    self._spawn_event(spawn, site)
                    return
            if attr in ("start", "join", "shutdown", "cancel"):
                handle = self._handle_for(base)
                if handle is not None:
                    if attr == "start":
                        handle.started = True
                        handle.start_site = site
                        self._spawn_event(handle, site)
                    elif attr == "join":
                        handle.joined = True
                        self.events.append(("join", handle, site))
                    elif attr == "shutdown":
                        handle.shutdown = True
                    return
                if attr == "join":
                    # join on a name we can't bind (collection-mediated
                    # handles): remember it — SP405 treats any unbound join
                    # in a scope as covering that scope's anonymous spawns.
                    self.events.append(("join", None, site))
            if attr in ("submit", "map") and self._looks_like_executor(base):
                targets = self._resolve_targets(call.args[:1])
                spawn = Spawn(
                    kind="executor-task", targets=targets,
                    target_text=dotted_name(call.args[0]) if call.args else "",
                    site=site, started=True,
                )
                self.model.spawns.append(spawn)
                self.events.append(("start", spawn, site))
            if attr == "acquire":
                lock_id = self._resolve_lock_expr(base)
                if lock_id is not None:
                    self.model.acquires.append(Acquire(
                        lock_id=lock_id, site=site,
                        held_before=tuple(self.held), via="acquire",
                    ))
                    self.held.append(lock_id)
                    return
            if attr == "release":
                lock_id = self._resolve_lock_expr(base)
                if lock_id is not None and lock_id in self.held:
                    self.held.remove(lock_id)
                    return

        # asyncio spawn forms.
        if canon in ("asyncio.run", "asyncio.create_task",
                     "asyncio.ensure_future"):
            kind = "async-main" if canon == "asyncio.run" else "task"
            for arg in call.args:
                if isinstance(arg, ast.Call):
                    targets = self._resolve_targets([arg.func])
                    if targets:
                        self.model.spawns.append(Spawn(
                            kind=kind, targets=targets,
                            target_text=dotted_name(arg.func), site=site,
                            started=True,
                        ))
        elif canon == "asyncio.gather":
            for arg in call.args:
                if isinstance(arg, ast.Call):
                    targets = self._resolve_targets([arg.func])
                    if targets:
                        self.model.spawns.append(Spawn(
                            kind="task", targets=targets,
                            target_text=dotted_name(arg.func), site=site,
                            started=True,
                        ))
        elif canon == "asyncio.to_thread":
            targets = self._resolve_targets(call.args[:1])
            if targets:
                spawn = Spawn(
                    kind="to_thread", targets=targets,
                    target_text=dotted_name(call.args[0]), site=site,
                    started=True,
                )
                self.model.spawns.append(spawn)
                self.events.append(("start", spawn, site))

        # Fork-the-process sites.
        if canon in _FORK_CALLS or canon in _POOL_CTORS:
            self.events.append(("fork", canon, site))

        # Blocking calls (canonicalized).
        if canon in BLOCKING_CALLS or text in BLOCKING_CALLS:
            self.model.blocking.setdefault(self.scope, []).append(
                BlockingCall(callee=canon, site=site)
            )

        # Spawn ctor used as a bare expression (no handle, never started
        # here — starts on the same call chain were handled above).
        spawn = self._spawn_from_call_no_register(call)
        if spawn is not None:
            self.model.spawns.append(spawn)

        # Resolved intra-package call edge.  Only *strong* resolutions
        # (same-class self-calls, uniquely-defined names, module-qualified
        # names) become graph edges — weak tail matches on attribute calls
        # of unknown objects (``stats.update``, ``buf.append``) manufacture
        # paths between unrelated subsystems and poison every transitive
        # pass.  Weak matches still mark the callee as "called somewhere"
        # so it is not mistaken for a main-thread entrypoint.
        for key, strong in self._resolve_conf(call.func):
            self.model.called.add(key)
            if not strong:
                continue
            self.model.edges.setdefault(self.scope, []).append(CallEdge(
                caller=self.scope, callee=key, site=site,
                held=tuple(self.held),
            ))
            self.events.append(("call", key, site))

    # -- resolution helpers --------------------------------------------------

    def _spawn_from_call(self, call: Optional[ast.expr]) -> Optional[Spawn]:
        """Register and return a Spawn when ``call`` constructs one.  The
        ctor node is marked consumed; the generic walk still visits its
        argument expressions."""
        spawn = self._spawn_from_call_no_register(call)
        if spawn is not None:
            self.model.spawns.append(spawn)
            self._consumed.add(id(call))
        return spawn

    def _spawn_from_call_no_register(
        self, call: Optional[ast.expr]
    ) -> Optional[Spawn]:
        if not isinstance(call, ast.Call):
            return None
        canon = canonical(self.table, dotted_name(call.func))
        if canon in _THREAD_CTORS:
            kind = "thread"
        elif canon in _PROCESS_CTORS:
            kind = "process"
        elif canon in _EXECUTOR_CTORS:
            kind = "executor"
        elif canon in _POOL_CTORS:
            kind = "process"
        else:
            return None
        target_text = ""
        targets: Tuple[str, ...] = ()
        daemon = False
        for kw in call.keywords:
            if kw.arg == "target":
                target_text = dotted_name(kw.value)
                targets = self._resolve_targets([kw.value])
            elif kw.arg == "daemon":
                daemon = (isinstance(kw.value, ast.Constant)
                          and kw.value.value is True)
        return Spawn(kind=kind, targets=targets, target_text=target_text,
                     site=self._site(call), daemon=daemon)

    def _spawn_event(self, spawn: Spawn, site: Site) -> None:
        if spawn.kind in ("thread", "executor", "executor-task", "to_thread"):
            self.events.append(("start", spawn, site))
        elif spawn.kind == "process":
            # Default Linux start method is fork: the fork happens here.
            self.events.append(("fork", "multiprocessing.Process.start", site))

    def _handle_for(self, base: ast.expr) -> Optional[Spawn]:
        if isinstance(base, ast.Name):
            return self.local_handles.get(base.id)
        if (isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id in ("self", "cls")):
            for spawn in self.model.spawns:
                if spawn.handle == ("attr", self.mod.module, base.attr):
                    return spawn
        return None

    def _looks_like_executor(self, base: ast.expr) -> bool:
        handle = self._handle_for(base)
        if handle is not None:
            return handle.kind == "executor"
        # Unbound: accept names that read like an executor/pool.
        text = dotted_name(base).rsplit(".", 1)[-1].lower()
        return "executor" in text or "pool" in text

    def _resolve_targets(self, exprs: List[ast.expr]) -> Tuple[str, ...]:
        """All resolutions (any confidence) — used for spawn targets, where
        the target expression names the function directly."""
        keys: List[str] = []
        for expr in exprs:
            for key, _strong in self._resolve_conf(expr):
                if key not in keys:
                    keys.append(key)
        local = [k for k in keys if k.startswith(self.mod.module + ":")]
        return tuple(local or keys)

    def _resolve_conf(self, expr: ast.expr) -> List[Tuple[str, bool]]:
        """Resolve a call target to ``(scope_key, strong)`` candidates.

        Strong means the analyzer can defend the edge: a ``self.meth`` call
        inside the defining class, a bare name the scanned set defines
        unambiguously (after same-module preference), or a ``module.func``
        reference whose module segment matches a scanned module.  Everything
        else — tail matches on attribute calls of unknown objects — is weak:
        the name coincidence carries no evidence the objects are related.
        """
        # self.meth / cls.meth inside a method body.
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id in ("self", "cls")
                and self.fn is not None):
            cls = _class_of(self.fn.qualname)
            if cls is not None:
                own = f"{self.mod.module}:{cls}.{expr.attr}"
                if own in self.model.functions:
                    return [(own, True)]
                # Inherited / dynamic: method-shaped matches only, weak.
                return [
                    (key, False)
                    for key in _callee_keys(expr.attr, self.defined)
                    if key.endswith("." + expr.attr)
                ]
        # module.func (or pkg.module.func) against scanned module names.
        if isinstance(expr, ast.Attribute):
            text = dotted_name(expr)
            if text and "." in text and "()" not in text:
                mod_part, attr = text.rsplit(".", 1)
                hits = []
                for mod in self.model.modules:
                    if (mod.module == mod_part
                            or mod.module.endswith("." + mod_part)):
                        key = f"{mod.module}:{attr}"
                        if key in self.model.functions:
                            hits.append((key, True))
                if hits:
                    return hits
            # Unknown-object method call: weak, method-shaped matches only.
            return [
                (key, False)
                for key in _callee_keys(expr.attr, self.defined)
                if key.endswith("." + expr.attr)
            ]
        # Bare name.
        name = dotted_name(expr)
        if not name or "()" in name:
            return []
        keys = _callee_keys(name, self.defined)
        local = [k for k in keys if k.startswith(self.mod.module + ":")]
        picked = local or keys
        strong = len(picked) == 1
        return [(k, strong) for k in picked]

    def _resolve_lock_expr(self, expr: ast.expr) -> Optional[str]:
        if isinstance(expr, ast.Name):
            if expr.id in self.local_locks:
                return self.local_locks[expr.id]
            module_lock = f"{self.mod.module}:{expr.id}"
            if module_lock in self.model.locks:
                return module_lock
            return None
        if isinstance(expr, ast.Attribute):
            base = expr.value
            if isinstance(base, ast.Name) and base.id in ("self", "cls"):
                cls = _class_of(self.fn.qualname) if self.fn else None
                if cls:
                    own = f"{self.mod.module}:{cls}.{expr.attr}"
                    if own in self.model.locks:
                        return own
                candidates = self.attr_index.get(expr.attr, [])
                same_mod = [c for c in candidates
                            if c.startswith(self.mod.module + ":")]
                pick = same_mod or candidates
                return pick[0] if pick else None
            # module.LOCK or obj.lock: dotted module-global, else attr index.
            text = dotted_name(expr)
            if "." in text:
                mod_part, attr = text.rsplit(".", 1)
                for mod in self.model.modules:
                    if (mod.module == mod_part
                            or mod.module.endswith("." + mod_part)):
                        lock_id = f"{mod.module}:{attr}"
                        if lock_id in self.model.locks:
                            return lock_id
            candidates = self.attr_index.get(expr.attr, [])
            return candidates[0] if candidates else None
        return None


# ---------------------------------------------------------------------------
# spawn lifecycle + entrypoints + wait points
# ---------------------------------------------------------------------------


def _resolve_spawn_lifecycle(model: ConcurrencyModel) -> None:
    """Post-pass join resolution: attr-handle joins anywhere in the module
    already marked their spawn; a scope containing an *unbindable* join
    (collection-mediated handles) covers that scope's unjoined spawns."""
    scopes_with_loose_join: Set[str] = set()
    for scope, events in model.events.items():
        for kind, payload, _site in events:
            if kind == "join" and payload is None:
                scopes_with_loose_join.add(scope)
    for spawn in model.spawns:
        if spawn.joined or not spawn.started:
            continue
        if spawn.site.scope in scopes_with_loose_join:
            spawn.joined = True


def _build_entrypoints(model: ConcurrencyModel,
                       defined: Dict[str, List[str]]) -> None:
    eps: Dict[str, Entrypoint] = {}

    def add(name: str, kind: str, roots: Tuple[str, ...],
            site: Optional[Site]) -> None:
        if not roots:
            return
        if name in eps:
            return
        eps[name] = Entrypoint(name=name, kind=kind, roots=roots, site=site)

    for spawn in model.spawns:
        if not spawn.targets:
            continue
        kind = {
            "thread": "thread", "process": "process",
            "executor-task": "thread", "to_thread": "thread",
            "task": "task", "async-main": "async-main",
        }.get(spawn.kind, spawn.kind)
        for key in spawn.targets:
            add(f"{kind}:{key}", kind, (key,), spawn.site)

    # threading.Thread subclasses: the run() method is a thread entrypoint.
    for mod in model.modules:
        if mod.tree is None:
            continue
        table = import_table(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases = {canonical(table, dotted_name(b)) for b in node.bases}
            if bases & (_THREAD_CTORS | _PROCESS_CTORS):
                run_key = f"{mod.module}:{node.name}.run"
                if run_key in model.functions:
                    kind = "thread" if bases & _THREAD_CTORS else "process"
                    add(f"{kind}:{run_key}", kind, (run_key,),
                        Site(mod.path, node.lineno, _module_scope(mod)))

    # <main>: module bodies + functions nothing scanned calls and no spawn
    # targets (callable from outside the scanned set, presumed main-thread).
    spawn_targets = {k for ep in eps.values() for k in ep.roots}
    called: Set[str] = set(model.called)
    for edges in model.edges.values():
        for e in edges:
            called.add(e.callee)
    main_roots = [_module_scope(m) for m in model.modules if m.tree is not None]
    for key, fn in model.functions.items():
        if key in called or key in spawn_targets:
            continue
        if fn.is_async:
            continue  # a bare coroutine function is not main-callable work
        main_roots.append(key)
    eps["<main>"] = Entrypoint(
        name="<main>", kind="main", roots=tuple(main_roots), site=None,
    )

    for ep in eps.values():
        ep.reachable = _reach_with_held(model, ep.roots)
    model.entrypoints = eps


def _reach_with_held(model: ConcurrencyModel,
                     roots: Tuple[str, ...]) -> Dict[str, frozenset]:
    """BFS over call edges; per scope, the intersection of locks held along
    every discovered path (monotone-shrinking, terminates)."""
    held_at: Dict[str, frozenset] = {}
    work: List[str] = []
    for r in roots:
        held_at[r] = frozenset()
        work.append(r)
    guard = 0
    while work and guard < 100_000:
        guard += 1
        scope = work.pop()
        base = held_at[scope]
        for edge in model.edges.get(scope, []):
            new = base | frozenset(edge.held)
            cur = held_at.get(edge.callee)
            if cur is None:
                held_at[edge.callee] = new
                work.append(edge.callee)
            else:
                inter = cur & new
                if inter != cur:
                    held_at[edge.callee] = inter
                    work.append(edge.callee)
    return held_at


def _region_of(model: ConcurrencyModel, scope: str) -> Tuple[str, str]:
    """(framed, frameless) region names for a scope key."""
    fn = model.functions.get(scope)
    if fn is None:
        module, _, name = scope.partition(":")
        return scope, scope
    return (f"{fn.module}:{fn.qualname}",
            f"{fn.frameless_module}:{fn.qualname}")


def _collect_wait_points(model: ConcurrencyModel) -> None:
    """Wait-point candidates: sites where a thread parks (lock acquire,
    join, blocking call).  Deduped per (region, kind); these seed the
    governor's sampler-friendly set — regions whose time is waiting lose
    nothing to sampling, but excluding them would erase the wait-state
    signal entirely."""
    rows: Dict[Tuple[str, str], Dict[str, Any]] = {}

    def add(scope: str, kind: str, site: Site) -> None:
        region, frameless = _region_of(model, scope)
        key = (region, kind)
        if key not in rows:
            rows[key] = {
                "region": region,
                "frameless_region": frameless,
                "kind": kind,
                "file": site.file,
                "line": site.line,
            }

    for acq in model.acquires:
        add(acq.site.scope, "lock-acquire", acq.site)
    for scope, events in model.events.items():
        for kind, _payload, site in events:
            if kind == "join":
                add(scope, "join", site)
    for scope, calls in model.blocking.items():
        for b in calls:
            add(scope, "blocking-call", b.site)
    model.wait_points = sorted(
        rows.values(), key=lambda r: (r["file"], r["line"], r["kind"])
    )
