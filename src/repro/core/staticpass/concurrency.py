"""Static concurrency analyzer — SP4xx detection passes + concurrency_plan.json.

Runs entirely on the :mod:`.concgraph` model (pure ast, user code never
imported).  Five passes become stable lint rules:

========  ==========================  ==============================================
id        name                        catches
========  ==========================  ==============================================
SP401     lock-order-inversion        a cycle in the lock-order graph: some path
                                      acquires A then B while another acquires B
                                      then A (including across calls) — two
                                      threads interleaving those paths deadlock.
SP402     race-candidate              module state written from ≥2 distinct
                                      concurrent entrypoints with no common lock
                                      guaranteed held on every path — a lost-
                                      update / torn-read candidate.
SP403     blocking-call-in-coroutine  a blocking call (SP301's set) inside an
                                      ``async def`` without ``to_thread`` /
                                      executor hand-off — it parks the whole
                                      event loop, not just this coroutine.
SP404     fork-after-threads          ``os.fork`` / ``multiprocessing`` start
                                      reachable after a thread start: the child
                                      inherits locked locks but not the threads
                                      that would release them.
SP405     unjoined-thread             a started thread/process never joined, or
                                      an executor neither ``with``-managed nor
                                      shut down — work leaks past the scope that
                                      owns it (daemon threads included: they die
                                      mid-write at interpreter exit).
========  ==========================  ==============================================

Every finding is a *candidate* with a call-path witness (``file:line: note``
lines) — names, not objects; paths, not proofs.  Suppression reuses the
linter pragmas (``# repro-lint: allow=SP401`` / ``allow-file=...``).

The artifact (``concurrency_plan.json``) is schema-stamped and carries the
entrypoint table, lock table, wait-point candidates (the governor's
sampler-friendly seeds) and per-rule findings.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Set, Tuple

from .. import schema
from .concgraph import (
    ConcurrencyModel,
    Site,
    Spawn,
    build_model,
    _region_of,
)
from .scanner import ScannedModule, scan_paths

#: Stable rule registry (ids are stable; renumbering is a breaking change).
CONCURRENCY_RULES = {
    "SP401": "lock-order-inversion",
    "SP402": "race-candidate",
    "SP403": "blocking-call-in-coroutine",
    "SP404": "fork-after-threads",
    "SP405": "unjoined-thread",
}

ARTIFACT = "concurrency_plan.json"
_GENERATOR = "repro.core.staticpass.concurrency"

#: Entrypoint kinds that run concurrently with something else (``<main>``
#: counts: main races against any spawned entrypoint).
_CONCURRENT_KINDS = {"thread", "process", "task", "main"}

#: Call-graph closure depth bounds (witnesses stay readable; the model is
#: an approximation anyway — deep chains add noise faster than signal).
_TRANS_ACQUIRE_DEPTH = 4
_TRANS_BLOCKING_DEPTH = 3


class Finding(dict):
    """One SP4xx finding — a dict (JSON-ready) with attribute sugar."""

    @property
    def rule_id(self) -> str:
        return self["rule"]

    @property
    def rule(self) -> str:
        return CONCURRENCY_RULES[self["rule"]]

    @property
    def file(self) -> str:
        return self["file"]

    @property
    def line(self) -> int:
        return self["line"]

    @property
    def message(self) -> str:
        return self["message"]

    def format(self) -> str:
        return (
            f"{self['file']}:{self['line']}: {self['rule']} "
            f"{CONCURRENCY_RULES[self['rule']]}: {self['message']}"
        )


def _finding(rule: str, site: Site, message: str,
             witness: List[str],
             entrypoints: Optional[List[str]] = None) -> Finding:
    return Finding(
        rule=rule,
        rule_name=CONCURRENCY_RULES[rule],
        file=site.file,
        line=site.line,
        message=message,
        witness=witness,
        entrypoints=sorted(entrypoints or []),
    )


def _w(site: Site, note: str) -> str:
    return f"{site.where()}: {note}"


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def analyze_paths(paths: List[str]) -> Tuple[ConcurrencyModel, List[Finding]]:
    """Scan + model + all passes; findings are suppression-filtered and
    sorted.  Raises :class:`MissingArtifact` for a bad path (CLI exit 2)."""
    modules = scan_paths(paths)
    return analyze_modules(modules)


def analyze_modules(
    modules: List[ScannedModule],
) -> Tuple[ConcurrencyModel, List[Finding]]:
    model = build_model(modules)
    findings = analyze_model(model)
    by_path: Dict[str, ScannedModule] = {m.path: m for m in modules}
    kept = [f for f in findings if not _suppressed(f, by_path.get(f.file))]
    kept.sort(key=lambda f: (f["file"], f["line"], f["rule"]))
    return model, kept


def analyze_model(model: ConcurrencyModel) -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(_pass_lock_order(model))
    findings.extend(_pass_races(model))
    findings.extend(_pass_blocking_in_coroutine(model))
    findings.extend(_pass_fork_after_threads(model))
    findings.extend(_pass_unjoined(model))
    return findings


def _suppressed(f: Finding, mod: Optional[ScannedModule]) -> bool:
    if mod is None:
        return False
    keys = {f["rule"], f["rule_name"]}
    if keys & mod.file_suppressions:
        return True
    return bool(keys & mod.line_suppressions.get(f["line"], set()))


# ---------------------------------------------------------------------------
# SP401 — lock-order inversion
# ---------------------------------------------------------------------------


def _trans_acquires(model: ConcurrencyModel) -> Dict[str, Dict[str, Site]]:
    """scope -> {lock_id: first acquire site reachable within the depth
    bound} (the scope's own acquires plus its callees', transitively)."""
    direct: Dict[str, Dict[str, Site]] = {}
    for acq in model.acquires:
        direct.setdefault(acq.site.scope, {}).setdefault(
            acq.lock_id, acq.site
        )
    closure = {scope: dict(locks) for scope, locks in direct.items()}
    for _ in range(_TRANS_ACQUIRE_DEPTH):
        changed = False
        for scope, edges in model.edges.items():
            mine = closure.setdefault(scope, {})
            for edge in edges:
                for lock_id, site in closure.get(edge.callee, {}).items():
                    if lock_id not in mine:
                        mine[lock_id] = edge.site  # witness: the call site
                        changed = True
        if not changed:
            break
    return closure


def _pass_lock_order(model: ConcurrencyModel) -> List[Finding]:
    # Edge table: (held_lock -> acquired_lock) -> list of witness sites.
    edges: Dict[Tuple[str, str], List[Tuple[Site, str]]] = {}

    def add_edge(a: str, b: str, site: Site, note: str) -> None:
        if a == b:
            return  # re-entrant acquire (RLock) is not an ordering edge
        edges.setdefault((a, b), []).append((site, note))

    # Local edges: acquire B while lexically holding A.
    for acq in model.acquires:
        for held in acq.held_before:
            add_edge(held, acq.lock_id, acq.site,
                     f"acquires {_short(acq.lock_id)} while holding "
                     f"{_short(held)}")
    # Inter-procedural edges: call out while holding A into code that
    # (transitively) acquires B.
    trans = _trans_acquires(model)
    for scope, scope_edges in model.edges.items():
        for edge in scope_edges:
            if not edge.held:
                continue
            for lock_id, _site in trans.get(edge.callee, {}).items():
                for held in edge.held:
                    add_edge(held, lock_id, edge.site,
                             f"calls into {_scope_name(edge.callee)} which "
                             f"acquires {_short(lock_id)} while holding "
                             f"{_short(held)}")

    # Cycle detection: SCCs of the lock-order graph with ≥2 locks.
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
    findings: List[Finding] = []
    for scc in _sccs(graph):
        if len(scc) < 2:
            continue
        cyc_edges = sorted(
            (pair, sites) for pair, sites in edges.items()
            if pair[0] in scc and pair[1] in scc
        )
        witness: List[str] = []
        scopes: Set[str] = set()
        first_site: Optional[Site] = None
        for (_a, _b), sites in cyc_edges:
            for site, note in sites:
                witness.append(_w(site, note))
                scopes.add(site.scope)
                if first_site is None or (site.file, site.line) < (
                        first_site.file, first_site.line):
                    first_site = site
        if first_site is None:
            continue
        if len(scopes) < 2 and not _multi_entry(model, scopes):
            # One scope acquiring in both orders can only deadlock against
            # itself if ≥2 entrypoints run it — otherwise stay quiet.
            continue
        names = " ↔ ".join(sorted(_short(l) for l in scc))
        findings.append(_finding(
            "SP401", first_site,
            f"lock-order inversion between {names} — two threads "
            f"interleaving these paths deadlock",
            witness,
            _entrypoints_reaching(model, scopes),
        ))
    return findings


def _multi_entry(model: ConcurrencyModel, scopes: Set[str]) -> bool:
    return len(_entrypoints_reaching(model, scopes)) >= 2


def _entrypoints_reaching(model: ConcurrencyModel,
                          scopes: Set[str]) -> List[str]:
    out = []
    for name, ep in model.entrypoints.items():
        if any(s in ep.reachable for s in scopes):
            out.append(name)
    return sorted(out)


def _sccs(graph: Dict[str, Set[str]]) -> List[Set[str]]:
    """Tarjan, iterative (analysis must not recurse on user-sized graphs)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    out: List[Set[str]] = []
    counter = [0]

    for root in sorted(graph):
        if root in index:
            continue
        work: List[Tuple[str, Any]] = [(root, iter(sorted(graph[root])))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            node, it = work[-1]
            advanced = False
            for succ in it:
                if succ not in index:
                    index[succ] = low[succ] = counter[0]
                    counter[0] += 1
                    stack.append(succ)
                    on_stack.add(succ)
                    work.append((succ, iter(sorted(graph[succ]))))
                    advanced = True
                    break
                if succ in on_stack:
                    low[node] = min(low[node], index[succ])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc: Set[str] = set()
                while True:
                    top = stack.pop()
                    on_stack.discard(top)
                    scc.add(top)
                    if top == node:
                        break
                out.append(scc)
    return out


def _short(lock_id: str) -> str:
    return lock_id.split(":", 1)[-1]


def _scope_name(scope: str) -> str:
    return scope.split(":", 1)[-1]


# ---------------------------------------------------------------------------
# SP402 — race candidates
# ---------------------------------------------------------------------------


def _pass_races(model: ConcurrencyModel) -> List[Finding]:
    by_var: Dict[str, List] = {}
    for w in model.global_writes:
        by_var.setdefault(w.var, []).append(w)

    findings: List[Finding] = []
    for var in sorted(by_var):
        writes = by_var[var]
        # (entrypoint, write, effective held set) rows: a write counts for
        # an entrypoint when its scope is reachable from it; the effective
        # held set is what's lexically held plus what's guaranteed held on
        # every call path in.
        rows: List[Tuple[str, Any, frozenset]] = []
        for w in writes:
            for name, ep in model.entrypoints.items():
                if ep.kind not in _CONCURRENT_KINDS:
                    continue
                guaranteed = ep.reachable.get(w.site.scope)
                if guaranteed is None:
                    continue
                rows.append((name, w, frozenset(w.held) | guaranteed))
        eps = {name for name, _w_, _h in rows}
        if len(eps) < 2:
            continue
        if not (eps - {"<main>"}):
            continue  # needs at least one spawned entrypoint in the mix
        common = None
        for _name, _w_, held in rows:
            common = held if common is None else (common & held)
        if common:
            continue  # some lock protects every path
        witness: List[str] = []
        seen: Set[Tuple[str, int, str]] = set()
        first: Optional[Site] = None
        for name, w, held in sorted(
                rows, key=lambda r: (r[1].site.file, r[1].site.line, r[0])):
            key = (w.site.file, w.site.line, name)
            if key in seen:
                continue
            seen.add(key)
            if first is None:
                first = w.site
            held_note = (
                f" holding {{{', '.join(_short(l) for l in sorted(held))}}}"
                if held else " with no lock held"
            )
            witness.append(
                _w(w.site, f"written via entrypoint {name}{held_note}")
            )
        if first is None:
            continue
        findings.append(_finding(
            "SP402", first,
            f"{_short(var)} is written from {len(eps)} entrypoints with no "
            f"common lock — lost-update candidate",
            witness,
            sorted(eps),
        ))
    return findings


# ---------------------------------------------------------------------------
# SP403 — blocking call in coroutine
# ---------------------------------------------------------------------------


def _pass_blocking_in_coroutine(model: ConcurrencyModel) -> List[Finding]:
    # Transitive blocking closure over sync callees (async callees are
    # awaited — their own scopes get their own findings).
    blocks: Dict[str, Tuple[Site, List[str]]] = {}
    for scope, calls in model.blocking.items():
        b = calls[0]
        blocks[scope] = (b.site, [_w(b.site, f"calls {b.callee}(...)")])
    for _ in range(_TRANS_BLOCKING_DEPTH):
        changed = False
        for scope, edges in model.edges.items():
            if scope in blocks:
                continue
            fn = model.functions.get(scope)
            if fn is not None and fn.is_async:
                continue  # async callees don't propagate: they're awaited
            for edge in sorted(edges, key=lambda e: (e.site.file,
                                                     e.site.line)):
                hit = blocks.get(edge.callee)
                if hit is None:
                    continue
                blocks[scope] = (
                    edge.site,
                    [_w(edge.site, f"calls {_scope_name(edge.callee)}")]
                    + hit[1],
                )
                changed = True
                break
        if not changed:
            break

    findings: List[Finding] = []
    for scope, fn in sorted(model.functions.items()):
        if not fn.is_async:
            continue
        # Direct blocking calls: one finding per site.
        for b in model.blocking.get(scope, []):
            findings.append(_finding(
                "SP403", b.site,
                f"blocking call {b.callee}(...) inside async def "
                f"{fn.qualname} parks the whole event loop — use "
                f"await asyncio.to_thread(...) or an executor",
                [_w(b.site, f"calls {b.callee}(...) in coroutine "
                    f"{fn.qualname}")],
                _entrypoints_reaching(model, {scope}),
            ))
        if scope in model.blocking:
            continue  # direct findings subsume the transitive path
        # Transitive: a sync callee chain that blocks.
        for edge in sorted(model.edges.get(scope, []),
                           key=lambda e: (e.site.file, e.site.line)):
            callee_fn = model.functions.get(edge.callee)
            if callee_fn is not None and callee_fn.is_async:
                continue
            hit = blocks.get(edge.callee)
            if hit is None:
                continue
            findings.append(_finding(
                "SP403", edge.site,
                f"async def {fn.qualname} reaches a blocking call via "
                f"{_scope_name(edge.callee)} — the event loop parks for "
                f"the full wait",
                [_w(edge.site, f"coroutine {fn.qualname} calls "
                    f"{_scope_name(edge.callee)}")] + hit[1],
                _entrypoints_reaching(model, {scope}),
            ))
            break  # one witness chain per coroutine is enough
    return findings


# ---------------------------------------------------------------------------
# SP404 — fork after threads
# ---------------------------------------------------------------------------


def _pass_fork_after_threads(model: ConcurrencyModel) -> List[Finding]:
    # Transitive "this scope starts a thread" / "this scope forks" sets.
    starts: Dict[str, Site] = {}
    forks: Dict[str, Tuple[Site, str]] = {}
    for scope, events in model.events.items():
        for kind, payload, site in events:
            if kind == "start" and isinstance(payload, Spawn):
                if payload.kind in ("thread", "executor", "executor-task",
                                    "to_thread"):
                    starts.setdefault(scope, site)
            elif kind == "fork":
                forks.setdefault(scope, (site, str(payload)))
    for closure, label in ((starts, "start"), (forks, "fork")):
        for _ in range(_TRANS_ACQUIRE_DEPTH):
            changed = False
            for scope, edges in model.edges.items():
                if scope in closure:
                    continue
                for edge in edges:
                    hit = closure.get(edge.callee)
                    if hit is None:
                        continue
                    closure[scope] = (
                        edge.site if label == "start"
                        else (edge.site, f"via {_scope_name(edge.callee)}")
                    )
                    changed = True
                    break
            if not changed:
                break

    findings: List[Finding] = []
    for scope in sorted(model.events):
        events = model.events[scope]
        live: List[Tuple[Spawn, Site]] = []
        abstract_start: Optional[Site] = None
        reported = False
        for kind, payload, site in events:
            if reported:
                break
            if kind == "start" and isinstance(payload, Spawn):
                if payload.kind in ("thread", "executor", "executor-task",
                                    "to_thread"):
                    live.append((payload, site))
            elif kind == "join":
                if payload is None:
                    live = []
                    abstract_start = None
                else:
                    live = [(s, st) for (s, st) in live if s is not payload]
            elif kind == "call":
                if payload in starts and abstract_start is None:
                    # The callee (transitively) starts a thread that is
                    # still running when it returns — unless it also joins,
                    # which the loose-join handling above models per scope.
                    abstract_start = starts[payload]
            fork_info = None
            if kind == "fork":
                fork_info = (site, str(payload))
            elif kind == "call" and payload in forks:
                f_site, f_note = forks[payload]
                fork_info = (site, f"reaches fork ({f_note}) "
                             f"in {_scope_name(payload)}")
            if fork_info is None:
                continue
            started_at = live[0][1] if live else abstract_start
            if started_at is None:
                continue
            f_site, f_note = fork_info
            findings.append(_finding(
                "SP404", f_site,
                "fork after thread start — the child inherits lock states "
                "but not the threads that would release them",
                [_w(started_at, "thread started here"),
                 _w(f_site, f_note if "reaches" in f_note
                    else f"{f_note} forks the process")],
                _entrypoints_reaching(model, {scope}),
            ))
            reported = True  # one finding per scope
    return findings


# ---------------------------------------------------------------------------
# SP405 — unjoined thread / leaked executor
# ---------------------------------------------------------------------------


def _pass_unjoined(model: ConcurrencyModel) -> List[Finding]:
    findings: List[Finding] = []
    for spawn in model.spawns:
        site = spawn.site
        eps = _entrypoints_reaching(model, {site.scope})
        if spawn.kind == "executor":
            if spawn.managed or spawn.shutdown:
                continue
            findings.append(_finding(
                "SP405", site,
                "executor is neither `with`-managed nor shut down — worker "
                "threads leak past the scope that owns them",
                [_w(site, "executor created here, no shutdown() on any "
                    "scanned path")],
                eps,
            ))
        elif spawn.kind in ("thread", "process"):
            if not spawn.started or spawn.joined:
                continue
            what = "thread" if spawn.kind == "thread" else "process"
            extra = (" (daemon: it dies mid-write at interpreter exit)"
                     if spawn.daemon else "")
            start = spawn.start_site or site
            findings.append(_finding(
                "SP405", start,
                f"{what} started but never joined on any scanned path"
                f"{extra} — shutdown order is unowned",
                [_w(site, f"{what} created here"),
                 _w(start, "started here, no matching join()")],
                eps,
            ))
    return findings


# ---------------------------------------------------------------------------
# artifact
# ---------------------------------------------------------------------------


def build_concurrency_plan(paths: List[str]) -> Dict[str, Any]:
    """Scan + analyze + assemble the stamped ``concurrency_plan.json``."""
    modules = scan_paths(paths)
    model, findings = analyze_modules(modules)
    return assemble_plan(paths, model, findings)


def assemble_plan(paths: List[str], model: ConcurrencyModel,
                  findings: List[Finding]) -> Dict[str, Any]:
    rule_counts = {rid: 0 for rid in CONCURRENCY_RULES}
    for f in findings:
        rule_counts[f["rule"]] += 1
    entrypoints = []
    for name in sorted(model.entrypoints):
        ep = model.entrypoints[name]
        entrypoints.append({
            "name": name,
            "kind": ep.kind,
            "roots": sorted(ep.roots)[:50],
            "site": ep.site.where() if ep.site else None,
            "reachable_scopes": len(ep.reachable),
        })
    locks = [
        {
            "id": lock.lock_id,
            "kind": lock.kind,
            "file": lock.site.file,
            "line": lock.site.line,
        }
        for _lid, lock in sorted(model.locks.items())
    ]
    doc = {
        "generator": _GENERATOR,
        "roots": [os.path.abspath(p) for p in paths],
        "files": len(model.modules),
        "functions": len(model.functions),
        "entrypoints": entrypoints,
        "locks": locks,
        "wait_points": model.wait_points[:200],
        "findings": [dict(f) for f in findings],
        "rule_counts": rule_counts,
        "errors": model.errors,
    }
    return schema.stamp(doc)


def save_concurrency_plan(doc: Dict[str, Any], path: str) -> str:
    out = path
    if os.path.isdir(path) or path.endswith(os.sep):
        os.makedirs(path, exist_ok=True)
        out = os.path.join(path, ARTIFACT)
    else:
        parent = os.path.dirname(os.path.abspath(out))
        os.makedirs(parent, exist_ok=True)
    with open(out, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")
    return out


def load_concurrency_plan(path: str) -> Dict[str, Any]:
    """Load + validate; raises :class:`MissingArtifact` (CLI exit 2)."""
    p = path
    if os.path.isdir(p):
        p = os.path.join(p, ARTIFACT)
    if not os.path.isfile(p):
        raise schema.MissingArtifact(
            f"no concurrency plan at {path} — run `analysis concurrency "
            f"<paths> --out {ARTIFACT}` first"
        )
    try:
        with open(p, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as exc:
        raise schema.MissingArtifact(
            f"unreadable concurrency plan {p}: {exc}"
        ) from exc
    if not isinstance(doc, dict) or doc.get("generator") != _GENERATOR:
        raise schema.MissingArtifact(
            f"{p} is not a concurrency plan (generator mismatch)"
        )
    return doc


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------


def render_concurrency_plan(doc: Dict[str, Any], top: int = 10) -> str:
    lines = [
        f"concurrency plan over {doc.get('files', 0)} files / "
        f"{doc.get('functions', 0)} functions",
        f"  entrypoints: {len(doc.get('entrypoints', []))}  "
        f"locks: {len(doc.get('locks', []))}  "
        f"wait points: {len(doc.get('wait_points', []))}",
    ]
    counts = doc.get("rule_counts", {})
    summary = "  ".join(
        f"{rid}:{counts.get(rid, 0)}" for rid in sorted(CONCURRENCY_RULES)
    )
    lines.append(f"  findings: {summary}")
    for ep in doc.get("entrypoints", [])[:top]:
        roots = ", ".join(ep.get("roots", [])[:3]) or "-"
        lines.append(
            f"  entry {ep['name']} [{ep['kind']}] "
            f"reaches {ep.get('reachable_scopes', 0)} scopes ({roots})"
        )
    findings = doc.get("findings", [])
    for f in findings[:top]:
        lines.append(f"  {f['file']}:{f['line']}: {f['rule']} "
                     f"{f['rule_name']}: {f['message']}")
        for wline in f.get("witness", [])[:4]:
            lines.append(f"      {wline}")
    if len(findings) > top:
        lines.append(f"  ... and {len(findings) - top} more findings")
    errors = doc.get("errors", [])
    if errors:
        lines.append(f"  parse errors: {len(errors)}")
    return "\n".join(lines)


def summarize_for_static_plan(model: ConcurrencyModel,
                              findings: List[Finding]) -> Dict[str, Any]:
    """Compact concurrency section embedded in ``static_plan.json`` —
    counts plus the wait-point rows the governor seeds from."""
    rule_counts = {rid: 0 for rid in CONCURRENCY_RULES}
    for f in findings:
        rule_counts[f["rule"]] += 1
    return {
        "entrypoints": len(model.entrypoints),
        "locks": len(model.locks),
        "findings": rule_counts,
        "wait_points": model.wait_points[:200],
    }
