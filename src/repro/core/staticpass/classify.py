"""Function classification + call-graph event-rate estimation.

Maps each scanned function to the cost classes the governor reasons about
at runtime, before anything runs:

``trivial``
    Accessor-shaped: property getters, dunders, and single-expression
    bodies with no calls.  Instrumenting these is all overhead (the
    paper's filter-file motivation) — auto-exclude candidates.
``generator`` / ``async``
    Under PEP 669 every suspension fires PY_YIELD/PY_RESUME in addition to
    the start/return pair, so their per-call event weight doubles.
``hot``
    Recursive, or called from loop-nested call sites — the flush-pressure
    class the governor's offender search discovers online.
``cwrapper``
    Body is a single call to a name outside the scanned set (presumed
    C/builtin).  Sampler-friendly: the wrapped work is invisible to the
    Python instrumenters anyway, so sampling loses nothing.

The event-rate estimate propagates call-graph fan-in: every function gets a
base weight of 1 (anything may call it from outside the scanned set), plus
the weight of each scanned call site scaled by ``LOOP_WEIGHT ** loop_depth``.
A few damped iterations make cycles converge; the result is a unitless
*relative* rate — enough to rank offenders and size cost tiers, which is all
the governor needs to start warm.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from .scanner import FunctionInfo, ScannedModule

#: Assumed iterations represented by one loop level of a call site.
LOOP_WEIGHT = 64.0
#: Fan-in propagation rounds (damped; cycles converge, they don't blow up).
_ROUNDS = 4
_DAMPING = 0.5
_RATE_CAP = 1e12

#: Relative per-call event-pair weight by cost class (the calibration seed:
#: multiply by the calibrated pair cost to project a function's cost).
COST_WEIGHTS = {
    "default": 1.0,
    # PY_YIELD/PY_RESUME fire per suspension on top of PY_START/PY_RETURN;
    # one yield per call is the conservative floor.
    "yield": 2.0,
}

#: ``simple_body`` functions at or under this AST size are trivial.
TRIVIAL_MAX_NODES = 12
#: Relative rate above which a trivial/hot function is worth excluding.
EXCLUDE_MIN_RATE = 2.0


@dataclass
class Classified:
    """One function with its classes, verdict, and rate estimate."""

    info: FunctionInfo
    classes: List[str] = field(default_factory=list)
    cost_class: str = "default"
    est_rate: float = 1.0
    verdict: str = "keep"  # keep | exclude | sample


def classify_modules(modules: List[ScannedModule]) -> List[Classified]:
    """Classify every function across the scanned set (shared by planner
    and linter; the linter only consumes the ``hot`` tag)."""
    functions: List[FunctionInfo] = [
        fn for mod in modules for fn in mod.functions
    ]
    defined = _defined_names(functions)
    rates = _estimate_rates(modules, functions, defined)

    out: List[Classified] = []
    for fn in functions:
        c = Classified(info=fn, est_rate=rates.get(_key(fn), 1.0))
        if fn.is_property:
            c.classes.append("property")
        if fn.is_dunder:
            c.classes.append("dunder")
        if fn.simple_body and fn.body_nodes <= TRIVIAL_MAX_NODES:
            c.classes.append("trivial")
        if fn.is_generator:
            c.classes.append("generator")
            c.cost_class = "yield"
        if fn.is_async:
            c.classes.append("async")
            c.cost_class = "yield"
        if _is_recursive(fn, functions):
            c.classes.append("recursive")
        if "recursive" in c.classes or _loop_fanin(fn, modules, functions):
            c.classes.append("hot")
        if fn.wrapped_call and not _resolves_local(fn.wrapped_call, defined):
            c.classes.append("cwrapper")
        c.verdict = _verdict(c)
        out.append(c)
    return out


def _verdict(c: Classified) -> str:
    trivial_shape = (
        "trivial" in c.classes
        or (("property" in c.classes or "dunder" in c.classes)
            and c.info.body_nodes <= TRIVIAL_MAX_NODES)
    )
    small = c.info.body_nodes <= 2 * TRIVIAL_MAX_NODES
    if trivial_shape and ("hot" in c.classes or c.est_rate >= EXCLUDE_MIN_RATE):
        return "exclude"
    if "hot" in c.classes and small and not c.info.has_loop:
        # Loop-nested tiny leaves: the flush-pressure shape the governor
        # excludes first at runtime; exclude them for free instead.
        return "exclude"
    if "cwrapper" in c.classes or "hot" in c.classes:
        return "sample"
    return "keep"


# ---------------------------------------------------------------------------
# call-graph helpers
# ---------------------------------------------------------------------------


def _key(fn: FunctionInfo) -> str:
    return f"{fn.module}:{fn.qualname}"


def _defined_names(functions: List[FunctionInfo]) -> Dict[str, List[str]]:
    """bare/qualified name -> keys of scanned functions carrying it."""
    names: Dict[str, List[str]] = {}
    for fn in functions:
        for alias in {fn.name, fn.qualname}:
            names.setdefault(alias, []).append(_key(fn))
    return names


def _resolves_local(callee: str, defined: Dict[str, List[str]]) -> bool:
    tail = callee.rsplit(".", 1)[-1]
    return callee in defined or tail in defined


def _callee_keys(callee: str, defined: Dict[str, List[str]]) -> List[str]:
    if callee in defined:
        return defined[callee]
    tail = callee.rsplit(".", 1)[-1]
    return defined.get(tail, [])


def _estimate_rates(
    modules: List[ScannedModule],
    functions: List[FunctionInfo],
    defined: Dict[str, List[str]],
) -> Dict[str, float]:
    """Damped fan-in propagation over the intra-package call graph."""
    rates = {_key(fn): 1.0 for fn in functions}
    # Static edge list: (callee_key, caller_key_or_None, loop_depth).
    edges = []
    for mod in modules:
        for site in mod.module_calls:
            for key in _callee_keys(site.callee, defined):
                edges.append((key, None, site.loop_depth))
    for fn in functions:
        for site in fn.calls:
            for key in _callee_keys(site.callee, defined):
                edges.append((key, _key(fn), site.loop_depth))
    for _ in range(_ROUNDS):
        incoming: Dict[str, float] = {k: 0.0 for k in rates}
        for callee, caller, depth in edges:
            caller_rate = 1.0 if caller is None else rates.get(caller, 1.0)
            incoming[callee] += caller_rate * (LOOP_WEIGHT ** depth)
        for key in rates:
            target = 1.0 + incoming[key]
            rates[key] = min(
                rates[key] + _DAMPING * (target - rates[key]), _RATE_CAP
            )
    return rates


def _is_recursive(fn: FunctionInfo, functions: List[FunctionInfo]) -> bool:
    """Direct recursion, or a two-cycle with another scanned function."""
    own = {fn.name, fn.qualname}
    callees = {site.callee.rsplit(".", 1)[-1] for site in fn.calls}
    if own & callees:
        return True
    for other in functions:
        if other is fn:
            continue
        if other.name in callees or other.qualname in callees:
            other_callees = {s.callee.rsplit(".", 1)[-1] for s in other.calls}
            if own & other_callees:
                return True
    return False


def _loop_fanin(
    fn: FunctionInfo,
    modules: List[ScannedModule],
    functions: List[FunctionInfo],
) -> bool:
    """Any scanned call site targeting ``fn`` sits inside a loop?"""
    targets = {fn.name, fn.qualname}
    for mod in modules:
        for site in mod.module_calls:
            if site.loop_depth > 0 and site.callee.rsplit(".", 1)[-1] in targets:
                return True
    for other in functions:
        for site in other.calls:
            if site.loop_depth > 0 and site.callee.rsplit(".", 1)[-1] in targets:
                return True
    return False
