"""JAX integration — step regions, device metrics, collective accounting.

The paper instruments MPI/pthread/CUDA activity alongside Python regions.
The XLA analogue: device work is compiled, so there is no per-kernel host
callback — instead we (a) tag host-side dispatch with user regions +
``jax.named_scope`` (region names survive into HLO metadata, the moral
equivalent of Score-P's region handles crossing the language boundary),
and (b) attach AOT cost-model numbers (FLOPs, bytes, per-collective bytes)
as metrics on the step region, giving profiles the device dimension the
paper gets from CUPTI.
"""

from __future__ import annotations

import re
import time
from contextlib import contextmanager
from functools import wraps
from typing import Any, Callable, Dict, Optional

from . import measurement as _m

try:  # jax is an optional dependency of the core (monitoring works without it)
    import jax
except Exception:  # pragma: no cover
    jax = None


@contextmanager
def annotate(name: str):
    """Host region + XLA named scope in one context manager."""
    if jax is None:
        with _m.region(name, module="jax"):
            yield
        return
    with _m.region(name, module="jax"), jax.named_scope(name):
        yield


def instrument_step(fn: Callable, name: str, *, block: bool = True) -> Callable:
    """Wrap a (possibly jitted) step function with host-side step regions.

    Records ``<name>`` as a region per call and a ``<name>.ms`` metric.  With
    ``block=True`` the wrapper calls ``block_until_ready`` on the result so
    the region covers device execution, not just dispatch (async dispatch
    would otherwise make steps look free — the JAX-flavored pitfall of the
    paper's host-side methodology).
    """

    @wraps(fn)
    def wrapper(*args, **kwargs):
        m = _m.active()
        if m is None:
            return fn(*args, **kwargs)
        t0 = time.perf_counter_ns()
        with m.region(name, module="jax.step"):
            out = fn(*args, **kwargs)
            if block and jax is not None:
                out = jax.block_until_ready(out)
        m.metric(f"{name}.ms", (time.perf_counter_ns() - t0) / 1e6)
        return out

    return wrapper


# ----------------------------------------------------------------------------
# AOT (compiled) artifact accounting — also reused by the roofline harness.
# ----------------------------------------------------------------------------

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# Matches the op *application* (name followed by its operand paren), sync or
# async: "all-reduce(...)", "all-reduce-start(...)", "all-gather-done(...)".
# Anchoring on "(" keeps lhs instruction names ("%all-reduce-start.1 = ...")
# and operand references ("...(%all-reduce-start.2)") from matching.
_HLO_OP_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
# One "dtype[dims]" shape; async-start results are tuples of these.
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Parse per-collective byte counts from (post-SPMD) HLO text.

    Bytes are *wire-estimate* bytes: result-shape bytes scaled by the ring
    factor for the op and its replica-group size g —
    all-reduce 2(g-1)/g, all-gather/reduce-scatter (g-1)/g, all-to-all
    (g-1)/g, collective-permute 1.  Conventions documented in DESIGN.md §7.

    Async forms are handled: ``*-start`` ops count (their result tuple's
    largest element is the transferred buffer — for all-gather-start the
    tuple is (input, output) and the gathered output is the byte count that
    matches the sync form), while the paired ``*-done`` ops are skipped so
    an async-ified collective is counted exactly once.
    """
    out: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0} for op in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        match = _HLO_OP_RE.search(line)
        if not match:
            continue
        op, suffix = match.group(1), match.group(2)
        if suffix == "-done":
            continue  # completion half of a counted *-start
        eq = line.find("=")
        if eq < 0 or eq > match.start():
            continue  # operand reference, not an instruction result
        shapes = _SHAPE_RE.findall(line[eq + 1 : match.start()])
        if not shapes:
            continue
        sizes = [_shape_bytes(dtype, dims) for dtype, dims in shapes]
        # Async-start result tuples: the element matching the sync form's
        # result is the largest (all-gather's gathered output; all-reduce /
        # collective-permute buffers dwarf the u32[] context scalars) —
        # except reduce-scatter, whose scattered result is the *smallest*
        # real shape, so max() would overcount by the group-size factor.
        nbytes = min(sizes) if op == "reduce-scatter" else max(sizes)
        g = _group_size(line)
        if op == "all-reduce":
            factor = 2.0 * (g - 1) / g if g > 1 else 0.0
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            factor = (g - 1) / g if g > 1 else 0.0
        else:  # collective-permute
            factor = 1.0
        rec = out[op]
        rec["count"] += 1
        rec["result_bytes"] += nbytes
        rec["wire_bytes"] += nbytes * factor
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        # iota format [n_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_BRACES_RE.search(line)
    if m:
        # explicit format {{0,1,2,3},{...}} — first group's cardinality
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def compiled_metrics(compiled: Any) -> Dict[str, float]:
    """Extract flops / bytes / collective bytes from a compiled executable."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    stats = collective_stats(compiled.as_text())
    coll_wire = sum(rec["wire_bytes"] for rec in stats.values())
    coll_count = sum(rec["count"] for rec in stats.values())
    mem = compiled.memory_analysis()
    out = {
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_wire_bytes": float(coll_wire),
        "collective_ops": float(coll_count),
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            out[attr] = float(getattr(mem, attr, 0) or 0)
    return out


def record_compiled(name: str, compiled: Any) -> Dict[str, float]:
    """Attach compiled-artifact metrics to the active measurement."""
    metrics = compiled_metrics(compiled)
    m = _m.active()
    if m is not None:
        for key, value in metrics.items():
            m.metric(f"{name}.{key}", value)
    return metrics
