"""Overhead-estimation methodology (paper §3).

The paper models instrumented runtime as ``t = α + β·N`` where α is the
one-time cost of enabling instrumentation (environment setup, measurement
start/finalize) and β the per-iteration cost, fit with ``numpy.polyfit`` over
the *median* of repeated wall-clock measurements per iteration count.  This
module embeds the paper's two test kernels (Listings 3 and 4) verbatim and
provides the subprocess-isolated measurement + fit used by
``benchmarks/overhead_case1.py`` / ``overhead_case2.py``.
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# Paper Listing 3 — test case 1: loop only.
CASE1_SRC = """\
import sys

result = 0
iterations = int(sys.argv[1])
iteration_list = list(range(iterations))
for i in iteration_list:
    result += 1
assert result == iterations
"""

# Paper Listing 4 — test case 2: function calls.
CASE2_SRC = """\
import sys

def add(val):
    return val + 1

result = 0
iterations = int(sys.argv[1])
iteration_list = list(range(iterations))
for i in iteration_list:
    result = add(result)
assert result == iterations
"""

CASES = {"case1": CASE1_SRC, "case2": CASE2_SRC}


def fit_linear(ns: Sequence[float], medians: Sequence[float]) -> Tuple[float, float]:
    """Fit ``t = alpha + beta * N`` (paper: numpy.polyfit on medians).

    Returns (alpha_seconds, beta_seconds_per_iteration).
    """
    beta, alpha = np.polyfit(np.asarray(ns, dtype=np.float64), np.asarray(medians, dtype=np.float64), 1)
    return float(alpha), float(beta)


@dataclass
class OverheadResult:
    case: str
    instrumenter: str  # "none" == paper's None (no repro module at all)
    ns: List[int]
    medians: List[float]
    alpha: float
    beta: float

    def as_row(self) -> Dict[str, object]:
        return {
            "case": self.case,
            "instrumenter": self.instrumenter,
            "alpha_s": self.alpha,
            "beta_us": self.beta * 1e6,
        }


def _write_case(case: str, dirpath: str) -> str:
    path = os.path.join(dirpath, f"{case}.py")
    with open(path, "w") as fh:
        fh.write(CASES[case])
    return path


def run_once(
    case_path: str,
    n: int,
    instrumenter: Optional[str],
    run_dir: str,
    substrates: str = "profiling",
    extra_args: Sequence[str] = (),
) -> float:
    """One subprocess execution; returns wall-clock seconds.

    ``instrumenter=None`` reproduces the paper's *None* row: the plain
    interpreter without the measurement module.  Otherwise the target runs
    under ``python -m repro.scorep`` exactly as a user would launch it.
    α therefore includes interpreter start + measurement start/finalize,
    matching the paper's definition.
    """
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "")
    if instrumenter is None:
        cmd = [sys.executable, case_path, str(n)]
    else:
        cmd = [
            sys.executable,
            "-m",
            "repro.scorep",
            f"--instrumenter={instrumenter}",
            f"--substrates={substrates}",
            f"--run-dir={run_dir}",
            "--no-chrome",
            *extra_args,
            case_path,
            str(n),
        ]
    t0 = time.perf_counter()
    proc = subprocess.run(cmd, env=env, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE)
    t1 = time.perf_counter()
    if proc.returncode != 0:
        raise RuntimeError(
            f"overhead case failed ({' '.join(cmd)}): {proc.stderr.decode()[-2000:]}"
        )
    return t1 - t0


def measure_case(
    case: str,
    instrumenter: Optional[str],
    ns: Sequence[int],
    repeats: int = 7,
    substrates: str = "profiling",
    extra_args: Sequence[str] = (),
) -> OverheadResult:
    """Paper §3 protocol: ``repeats`` runs per N, median, linear fit.

    The paper uses 51 repetitions; benchmarks default lower for CI speed and
    accept ``--repeats 51`` for the full protocol.
    """
    medians: List[float] = []
    with tempfile.TemporaryDirectory(prefix="repro-overhead-") as tmp:
        case_path = _write_case(case, tmp)
        for n in ns:
            times = []
            for rep in range(repeats):
                run_dir = os.path.join(tmp, f"run-{case}-{instrumenter}-{n}-{rep}")
                times.append(
                    run_once(case_path, n, instrumenter, run_dir, substrates, extra_args)
                )
            medians.append(float(np.median(times)))
    alpha, beta = fit_linear(list(ns), medians)
    return OverheadResult(
        case=case,
        instrumenter=instrumenter or "none-baseline",
        ns=list(ns),
        medians=medians,
        alpha=alpha,
        beta=beta,
    )


def measure_inprocess_beta(
    case: str,
    instrumenter: str,
    ns: Sequence[int],
    repeats: int = 5,
    buffer_strategy: str = "list",
    sampling_period: int = 97,
    substrates: Sequence[str] = (),
    flush_threshold: int = 1 << 16,
    budget: float = 0.0,
) -> Tuple[float, float]:
    """In-process variant: isolates β from interpreter/JAX startup noise.

    Used by the event-throughput benchmark and the §Perf hillclimb loop where
    only the per-event cost is under study.  Compiles the case source once and
    times exec() under an installed instrumenter.  ``substrates`` defaults to
    none (pure event-path cost); ``benchmarks/memory_overhead.py`` passes
    ``("memory",)`` to measure the heap collector's flush-time share.
    ``budget > 0`` enables the overhead governor: its calibration probe and
    escalation transient are per-run constants, so they land in α and the
    fitted β reflects the governed steady state.
    """
    from .measurement import MeasurementConfig, Measurement

    src = CASES[case]
    code = compile(src, f"<{case}>", "exec")
    medians = []
    for n in ns:
        times = []
        for _ in range(repeats):
            cfg = MeasurementConfig(
                instrumenter=instrumenter,
                substrates=tuple(substrates),
                run_dir=tempfile.mkdtemp(prefix="repro-beta-"),
                buffer_strategy=buffer_strategy,
                sampling_period=sampling_period,
                flush_threshold=flush_threshold,
                budget=budget,
            )
            m = Measurement(cfg)
            glb = {"__name__": "__overhead__"}
            argv_saved = sys.argv
            sys.argv = ["case", str(n)]  # case sources read sys.argv[1]
            try:
                t0 = time.perf_counter()
                m.start()
                exec(code, glb)
                m.stop()
                t1 = time.perf_counter()
            finally:
                sys.argv = argv_saved
                m.finalize()
            times.append(t1 - t0)
        medians.append(float(np.median(times)))
    return fit_linear(list(ns), medians)
