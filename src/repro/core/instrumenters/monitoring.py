"""``sys.monitoring`` instrumenter (PEP 669) — beyond-paper optimization.

The paper (2020) predates CPython 3.12's ``sys.monitoring``, which was built
precisely to lower the cost that the paper measures for ``sys.setprofile``:
callbacks are registered per event kind, receive the code object directly
(no frame materialization on the fast path), and can be disabled per
location.  This instrumenter is the modern re-implementation of the paper's
``profile`` instrumenter; ``benchmarks/overhead_case2.py`` quantifies the β
improvement (EXPERIMENTS.md §Perf).

Events observed: PY_START/PY_RETURN (+ PY_UNWIND for exceptional exits and
PY_YIELD/PY_RESUME so generator suspension balances like ``sys.setprofile``'s
call/return semantics).  C-function events are intentionally not subscribed —
subscribing ``CALL`` would reintroduce per-call argument materialization and
most of the cost this instrumenter exists to avoid.
"""

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_ENTER, EV_EXIT
from .base import Instrumenter

_TOOL_NAME = "repro-monitor"


class MonitoringInstrumenter(Instrumenter):
    name = "monitoring"
    events_supported = ("call", "return")
    # Governor downgrade rung: exhaustive PEP 669 events -> counting sampler.
    downgrade_to = "sampling"

    def __init__(self) -> None:
        self._measurement = None
        self._installed = False
        self._tool_id = None
        self._nfiltered: list = [0]

    def filtered_calls(self) -> int:
        return self._nfiltered[0]

    def _make_callbacks(self, measurement):
        regions = measurement.regions
        by_code = regions.by_code
        register_code = regions.register_code
        clock = time.perf_counter_ns
        get_ident = threading.get_ident
        # thread ident -> bound append of that thread's buffer
        appends = {}
        buffers = {}

        def _bind(ident):
            buf = measurement.thread_buffer()
            buffers[ident] = buf
            appends[ident] = buf.events.append
            return appends[ident]

        def _maybe_flush(ident):
            buf = buffers[ident]
            if len(buf.events) >= buf.flush_threshold:
                buf.flush()
                appends[ident] = buf.events.append

        nfiltered = self._nfiltered

        def on_start(code, instruction_offset):
            t = clock()
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid >= 0:
                ident = get_ident()
                append = appends.get(ident)
                if append is None:
                    append = _bind(ident)
                append((EV_ENTER, rid, t, 0))
                _maybe_flush(ident)
            else:
                # Verdict-miss count for the governor's residual-cost
                # observation.
                nfiltered[0] += 1

        def on_return(code, instruction_offset, retval):
            t = clock()
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid >= 0:
                ident = get_ident()
                append = appends.get(ident)
                if append is None:
                    append = _bind(ident)
                append((EV_EXIT, rid, t, 0))
                _maybe_flush(ident)

        def on_unwind(code, instruction_offset, exception):
            on_return(code, instruction_offset, None)

        return on_start, on_return, on_unwind

    def install(self, measurement) -> None:
        mon = sys.monitoring
        tool_id = mon.PROFILER_ID
        if mon.get_tool(tool_id) is not None:  # pragma: no cover - defensive
            mon.free_tool_id(tool_id)
        mon.use_tool_id(tool_id, _TOOL_NAME)
        self._tool_id = tool_id
        self._measurement = measurement
        on_start, on_return, on_unwind = self._make_callbacks(measurement)
        ev = mon.events
        mon.register_callback(tool_id, ev.PY_START, on_start)
        mon.register_callback(tool_id, ev.PY_RESUME, on_start)
        mon.register_callback(tool_id, ev.PY_RETURN, on_return)
        mon.register_callback(tool_id, ev.PY_YIELD, on_return)
        mon.register_callback(tool_id, ev.PY_UNWIND, on_unwind)
        mon.set_events(
            tool_id, ev.PY_START | ev.PY_RESUME | ev.PY_RETURN | ev.PY_YIELD | ev.PY_UNWIND
        )
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        mon = sys.monitoring
        ev = mon.events
        mon.set_events(self._tool_id, 0)
        for kind in (ev.PY_START, ev.PY_RESUME, ev.PY_RETURN, ev.PY_YIELD, ev.PY_UNWIND):
            mon.register_callback(self._tool_id, kind, None)
        mon.free_tool_id(self._tool_id)
        self._installed = False
