"""``sys.monitoring`` instrumenter (PEP 669) — beyond-paper optimization.

The paper (2020) predates CPython 3.12's ``sys.monitoring``, which was built
precisely to lower the cost that the paper measures for ``sys.setprofile``:
callbacks are registered per event kind, receive the code object directly
(no frame materialization on the fast path), and can be disabled per
location.  This instrumenter is the modern re-implementation of the paper's
``profile`` instrumenter; ``benchmarks/overhead_case2.py`` quantifies the β
improvement (EXPERIMENTS.md §Perf).

Events observed: PY_START/PY_RETURN (+ PY_UNWIND for exceptional exits and
PY_YIELD/PY_RESUME so generator suspension balances like ``sys.setprofile``'s
call/return semantics).  C-function events are intentionally not subscribed —
subscribing ``CALL`` would reintroduce per-call argument materialization and
most of the cost this instrumenter exists to avoid.

Filtered regions cost zero after the first hit: callbacks return
``sys.monitoring.DISABLE`` for code objects whose filter verdict is
``FILTERED``, so the interpreter stops dispatching that (code, location)
entirely — no callback, no dict lookup, nothing.  The exception is
``PY_UNWIND``, which CPython defines as not locally disableable (returning
DISABLE from it raises ValueError); its callback does the balancing work and
returns None — exceptional exits from filtered code stay a per-event cost,
but they are rare by construction.  DISABLE state lives on the code object
and survives ``free_tool_id``, so ``install`` calls ``restart_events()`` to
clear verdicts left over from a previous measurement (or calibration probe)
in the same process; a registered :meth:`RegionRegistry.add_refilter_hook`
re-arms events whenever the governor tightens the filter on a live
measurement, giving every tool a fresh first hit under the new verdicts.
"""

from __future__ import annotations

import sys
import threading
import time

from ..buffer import EV_ENTER, EV_EXIT
from .base import Instrumenter

_TOOL_NAME = "repro-monitor"


def acquire_tool_id(mon, name: str) -> int:
    """Claim a free PEP 669 tool id, never stealing a foreign tool.

    Prefers ``PROFILER_ID`` (this *is* a profiler), then walks the remaining
    ids 0..5; an id whose ``get_tool`` is non-None belongs to someone else
    (debugger, coverage, another profiler) and is skipped — ``free_tool_id``
    on it would silently unregister that tool.  Raises ``RuntimeError``
    naming the holders when all six ids are taken.
    """
    candidates = [mon.PROFILER_ID] + [i for i in range(6) if i != mon.PROFILER_ID]
    for tool_id in candidates:
        if mon.get_tool(tool_id) is not None:
            continue
        try:
            mon.use_tool_id(tool_id, name)
        except ValueError:  # lost a race for the id; try the next one
            continue
        return tool_id
    holders = ", ".join(
        f"{i}={mon.get_tool(i)!r}" for i in range(6) if mon.get_tool(i) is not None
    )
    raise RuntimeError(
        f"no free sys.monitoring tool id for {name!r} (all in use: {holders})"
    )


class MonitoringInstrumenter(Instrumenter):
    name = "monitoring"
    events_supported = ("call", "return")
    # Governor downgrade rung: exhaustive PEP 669 events -> counting sampler.
    downgrade_to = "sampling"
    # Filtered verdicts cost nothing per call: the callback returns DISABLE
    # on first hit and the interpreter never dispatches that location again.
    zero_cost_filtered = True

    def __init__(self) -> None:
        self._measurement = None
        self._installed = False
        self._tool_id = None
        self._regions = None
        self._nfiltered: list = [0]

    def filtered_calls(self) -> int:
        return self._nfiltered[0]

    def _make_callbacks(self, measurement):
        mon = sys.monitoring
        DISABLE = mon.DISABLE
        regions = measurement.regions
        by_code = regions.by_code
        register_code = regions.register_code
        clock = time.perf_counter_ns
        get_ident = threading.get_ident
        # thread ident -> bound append of that thread's buffer
        appends = {}
        buffers = {}

        def _bind(ident):
            buf = measurement.thread_buffer()
            buffers[ident] = buf
            appends[ident] = buf.events.append
            return appends[ident]

        def _maybe_flush(ident):
            buf = buffers[ident]
            if len(buf.events) >= buf.flush_threshold:
                buf.flush()
                appends[ident] = buf.events.append

        nfiltered = self._nfiltered

        def on_start(code, instruction_offset):
            t = clock()
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid >= 0:
                ident = get_ident()
                append = appends.get(ident)
                if append is None:
                    append = _bind(ident)
                append((EV_ENTER, rid, t, 0))
                _maybe_flush(ident)
                return None
            # Verdict-miss count for the governor's residual-cost
            # observation: at most one per (code, location) per
            # restart_events epoch — DISABLE retires the location.
            nfiltered[0] += 1
            return DISABLE

        def on_return(code, instruction_offset, retval):
            t = clock()
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid >= 0:
                ident = get_ident()
                append = appends.get(ident)
                if append is None:
                    append = _bind(ident)
                append((EV_EXIT, rid, t, 0))
                _maybe_flush(ident)
                return None
            return DISABLE

        def on_unwind(code, instruction_offset, exception):
            # PY_UNWIND is not locally disableable (returning DISABLE raises
            # ValueError), so exceptional exits always pay the callback; the
            # filtered path just declines to record.
            rid = by_code.get(code)
            if rid is None:
                rid = register_code(code, None)
            if rid >= 0:
                t = clock()
                ident = get_ident()
                append = appends.get(ident)
                if append is None:
                    append = _bind(ident)
                append((EV_EXIT, rid, t, 0))
                _maybe_flush(ident)

        return on_start, on_return, on_unwind

    def _rearm(self) -> None:
        """Refilter hook: re-enable every DISABLEd location so tightened
        verdicts get their one fresh hit (and then go dark again)."""
        if self._installed:
            sys.monitoring.restart_events()

    def install(self, measurement) -> None:
        mon = sys.monitoring
        tool_id = acquire_tool_id(mon, _TOOL_NAME)
        self._tool_id = tool_id
        self._measurement = measurement
        self._regions = measurement.regions
        on_start, on_return, on_unwind = self._make_callbacks(measurement)
        ev = mon.events
        mon.register_callback(tool_id, ev.PY_START, on_start)
        mon.register_callback(tool_id, ev.PY_RESUME, on_start)
        mon.register_callback(tool_id, ev.PY_RETURN, on_return)
        mon.register_callback(tool_id, ev.PY_YIELD, on_return)
        mon.register_callback(tool_id, ev.PY_UNWIND, on_unwind)
        mon.set_events(
            tool_id, ev.PY_START | ev.PY_RESUME | ev.PY_RETURN | ev.PY_YIELD | ev.PY_UNWIND
        )
        # DISABLE state is per (code, location) and survives tool-id reuse:
        # a previous measurement (or the calibration probe) in this process
        # may have retired locations this measurement must observe.
        mon.restart_events()
        self._regions.add_refilter_hook(self._rearm)
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._installed = False
        if self._regions is not None:
            self._regions.remove_refilter_hook(self._rearm)
            self._regions = None
        mon = sys.monitoring
        ev = mon.events
        mon.set_events(self._tool_id, 0)
        for kind in (ev.PY_START, ev.PY_RESUME, ev.PY_RETURN, ev.PY_YIELD, ev.PY_UNWIND):
            mon.register_callback(self._tool_id, kind, None)
        mon.free_tool_id(self._tool_id)
        self._tool_id = None
