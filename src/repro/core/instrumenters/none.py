"""Null instrumenter — the paper's "None" baseline.

Measurement is initialized (substrates open, user regions and metrics still
work) but no CPython hook is installed, so automatic function events cost
nothing.  This is both the baseline of the overhead study and the right
production setting for workloads that only want user regions + JAX step
metrics.
"""

from __future__ import annotations

from .base import Instrumenter


class NoneInstrumenter(Instrumenter):
    name = "none"
    events_supported = ()
    downgrade_to = None  # governor ladder floor: nothing cheaper exists

    def install(self, measurement) -> None:  # noqa: ARG002 - interface
        pass

    def uninstall(self) -> None:
        pass
