"""Instrumenter interface.

An instrumenter registers with a CPython event source and converts its
callbacks into measurement events appended to the calling thread's buffer.
The paper evaluates two (``sys.setprofile`` and ``sys.settrace``); this
implementation adds ``sampling`` (the paper's future-work item) and
``monitoring`` (``sys.monitoring``, PEP 669 — the modern low-overhead hook
that postdates the paper), plus the ``none`` baseline.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from typing import TYPE_CHECKING, Tuple

if TYPE_CHECKING:  # pragma: no cover
    from ..measurement import Measurement


class Instrumenter(ABC):
    """Converts CPython runtime events into buffered measurement events."""

    #: registry key, e.g. "profile"
    name: str = "?"
    #: event kinds this instrumenter can observe (paper Table 1)
    events_supported: Tuple[str, ...] = ()

    @abstractmethod
    def install(self, measurement: "Measurement") -> None:
        """Register with the interpreter; events flow after this returns."""

    @abstractmethod
    def uninstall(self) -> None:
        """Deregister; no events flow after this returns."""
