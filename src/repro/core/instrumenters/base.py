"""Instrumenter interface.

An instrumenter registers with a CPython event source and converts its
callbacks into measurement events appended to the calling thread's buffer.
The paper evaluates two (``sys.setprofile`` and ``sys.settrace``); this
implementation adds ``sampling`` (the paper's future-work item) and
``monitoring`` (``sys.monitoring``, PEP 669 — the modern low-overhead hook
that postdates the paper), plus the ``none`` baseline.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from typing import TYPE_CHECKING, Tuple

if TYPE_CHECKING:  # pragma: no cover
    from ..measurement import Measurement


class Instrumenter(ABC):
    """Converts CPython runtime events into buffered measurement events."""

    #: registry key, e.g. "profile"
    name: str = "?"
    #: event kinds this instrumenter can observe (paper Table 1)
    events_supported: Tuple[str, ...] = ()
    #: next rung of the overhead governor's downgrade ladder (``None`` =
    #: nothing cheaper exists).  Set per subclass.
    downgrade_to: "str | None" = None
    #: True when filtered verdicts stop costing anything after the first hit
    #: (PEP 669 instrumenters return ``sys.monitoring.DISABLE`` and the
    #: interpreter retires the location).  The governor's projection model
    #: then prices excluded regions at zero instead of the calibrated
    #: filtered-path cost, which is what makes excluding offenders a real
    #: fix rather than a cost shuffle.
    zero_cost_filtered: bool = False

    @abstractmethod
    def install(self, measurement: "Measurement") -> None:
        """Register with the interpreter; events flow after this returns."""

    @abstractmethod
    def uninstall(self) -> None:
        """Deregister; no events flow after this returns."""

    # -- governor hooks (runtime overhead control) --------------------------

    def set_period(self, period: int) -> bool:
        """Mutate the sampling period of a live instrumenter.

        Returns ``False`` when the instrumenter has no period to mutate
        (every event source except the counting sampler); the governor then
        skips the period rung of its escalation ladder.
        """
        return False

    def cost_multiplier(self) -> float:
        """Hook invocations per *appended* event (governor cost accounting).

        1.0 for exhaustive instrumenters; the counting sampler overrides
        this with its period (each appended event stands for ``period``
        unsampled hook invocations that still paid the fast-path cost).
        """
        return 1.0

    def filtered_calls(self) -> int:
        """Call events whose region verdict was ``FILTERED`` since install.

        Filtered hooks never reach a buffer, so their residual cost is
        invisible to flush-based accounting; instrumenters count them on the
        verdict-miss path (one integer increment there, zero cost on the
        recorded path) so the governor's watchdog can observe the post-
        exclusion hook rate.  Sampler counts are in *sampled* calls — scale
        by :meth:`cost_multiplier` like any appended event.
        """
        return 0
